from .pipeline import (ByteTokenizer, RequestGenerator, SyntheticCorpus,
                       batches)

__all__ = ["ByteTokenizer", "RequestGenerator", "SyntheticCorpus", "batches"]
