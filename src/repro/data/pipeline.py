"""Data substrate: byte-level tokenizer, synthetic corpus, request stream.

Deterministic, host-shardable (each data-parallel host pulls its own slice
by ``(host_id, n_hosts)``), dependency-free. The synthetic corpus is a
mixture of Zipf-distributed "words" with Markov structure — enough signal
for a ~100M model's loss to fall measurably in a few hundred steps (the
end-to-end training example).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


class ByteTokenizer:
    """UTF-8 bytes + specials. Vocab fits every assigned arch's table."""

    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    def __init__(self, vocab: int = 259):
        assert vocab >= 256 + self.OFFSET
        self.vocab = vocab

    def encode(self, text: str, *, bos: bool = True, eos: bool = False
               ) -> List[int]:
        ids = [b + self.OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids) -> str:
        bs = bytes(max(0, int(i) - self.OFFSET) for i in ids
                   if int(i) >= self.OFFSET)
        return bs.decode("utf-8", errors="replace")


@dataclasses.dataclass
class SyntheticCorpus:
    """Zipf-Markov token stream with a fixed vocabulary."""

    vocab: int
    seed: int = 0
    n_states: int = 64
    branch: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # each state emits from a Zipf head and picks a next state
        self._emit = rng.integers(3, self.vocab,
                                  size=(self.n_states, self.branch))
        probs = 1.0 / np.arange(1, self.branch + 1) ** 1.2
        self._probs = probs / probs.sum()
        self._next = rng.integers(0, self.n_states,
                                  size=(self.n_states, self.branch))

    def stream(self, *, host_id: int = 0, n_hosts: int = 1,
               seed: Optional[int] = None) -> Iterator[int]:
        rng = np.random.default_rng((seed or self.seed) * n_hosts + host_id
                                    + 1)
        state = int(rng.integers(0, self.n_states))
        while True:
            j = int(rng.choice(self.branch, p=self._probs))
            yield int(self._emit[state, j])
            state = int(self._next[state, j])


def batches(corpus: SyntheticCorpus, batch: int, seq_len: int, *,
            host_id: int = 0, n_hosts: int = 1, seed: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token-prediction batches: labels are tokens shifted by one."""
    streams = [corpus.stream(host_id=host_id * batch + i,
                             n_hosts=n_hosts * batch, seed=seed)
               for i in range(batch)]
    while True:
        chunk = np.array([[next(s) for _ in range(seq_len + 1)]
                          for s in streams], dtype=np.int32)
        yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    arrival_s: float
    session: Optional[str] = None   # multi-turn key for KV parking


class RequestGenerator:
    """Seeded arrival traces of variable-length prompts (serving
    benchmarks): Poisson (default) or bursty arrivals, prompt lengths
    drawn from a range or a discrete mix.

    ``lengths`` replaces the ``prompt_len`` range with a discrete choice
    set (e.g. ``(8, 16, 48)``) — serving benchmarks use this to mix
    short/long prompts while keeping the set of jitted prefill shapes
    small. ``pattern="bursty"`` releases requests in back-to-back groups
    of ``burst`` separated by ``burst_gap_s`` of silence — the adversarial
    arrival process for admission control (a Poisson trace rarely fills
    every slot at once; a burst always does).
    """

    def __init__(self, vocab: int, *, rate_per_s: float = 4.0,
                 prompt_len: Tuple[int, int] = (16, 256),
                 max_new: int = 64, seed: int = 0,
                 lengths: Optional[Tuple[int, ...]] = None):
        self.vocab = vocab
        self.rate = rate_per_s
        self.prompt_len = prompt_len
        self.lengths = lengths
        self.max_new = max_new
        self.rng = np.random.default_rng(seed)

    def generate(self, n: int, *, pattern: str = "poisson",
                 burst: int = 4, burst_gap_s: float = 0.25
                 ) -> List[Request]:
        if pattern not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival pattern {pattern!r}")
        t = 0.0
        out = []
        for i in range(n):
            if pattern == "poisson":
                t += self.rng.exponential(1.0 / self.rate)
            elif i > 0 and i % burst == 0:
                t += burst_gap_s       # whole burst shares one instant
            if self.lengths is not None:
                length = int(self.rng.choice(self.lengths))
            else:
                length = int(self.rng.integers(*self.prompt_len))
            prompt = self.rng.integers(3, self.vocab, size=length,
                                       dtype=np.int32)
            lo = max(1, min(8, self.max_new))
            out.append(Request(uid=i, prompt=prompt,
                               max_new_tokens=int(self.rng.integers(
                                   lo, self.max_new + 1)),
                               arrival_s=t))
        return out
