"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires the substrate end to end: config -> model -> data pipeline ->
FSDP×TP train step -> checkpoint/restart. ``--smoke`` uses the reduced
config so the loop runs on one CPU; the full config path is exactly what
the dry-run lowers for the production mesh.

Fault tolerance: checkpoints every ``--ckpt-every`` steps via the atomic
CheckpointManager; on restart the latest complete checkpoint is restored
(``--resume``). Kill the process mid-run and rerun with --resume to see it
continue from the last saved step.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import SyntheticCorpus, batches
from ..models import init_params
from ..runtime.checkpoint import CheckpointManager
from ..runtime.optim import AdamW
from ..runtime.train import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param example)")
    ap.add_argument("--n-layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = dataclasses.replace(cfg, **over)

    print(f"arch={cfg.name} params={cfg.total_params()/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_def = AdamW(lr=args.lr, warmup_steps=20)
    opt = opt_def.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_def, grad_dtype=None,
                                      remat=False,
                                      microbatch=args.microbatch))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        got, (params, opt) = mgr.restore_latest((params, opt))
        if got is not None:
            start = got
            print(f"resumed from step {start}")

    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=args.seed)
    it = batches(corpus, args.batch, args.seq, seed=args.seed)
    # fast-forward the stream on resume (determinism across restarts)
    for _ in range(start):
        next(it)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % 10 == 0 or step == start:
            dt = time.time() - t0
            print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt))
            print(f"checkpointed step {step + 1}")
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
