"""ShapeDtypeStruct stand-ins for every (arch × shape × step) cell.

No device allocation happens here — params, optimizer state, caches and
batches are all ``jax.eval_shape`` / ``ShapeDtypeStruct`` trees, which is
what ``jit(...).lower()`` needs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig, ShapeSpec
from ..models import model as M
from ..runtime import serve
from ..runtime.optim import AdamW

#: decode context is bounded by the arch's own window/limits
def decode_context(cfg: ModelConfig, shape: ShapeSpec) -> int:
    S = shape.seq_len
    if cfg.attn_window:
        S = min(S, cfg.attn_window) if cfg.family != "hybrid" else S
    if cfg.max_decode_len:
        S = min(S, cfg.max_decode_len)
    return S


def params_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))


def ring_params_shapes(cfg: ModelConfig, n_stages: int, k: int, tp: int,
                       dtype=jnp.bfloat16, quant: int = 0):
    def build():
        p = M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
        p = serve.pad_vocab(p, cfg, tp)
        p["blocks"] = serve.pad_and_permute(p["blocks"], cfg, n_stages, k)
        if quant:
            p, _skipped = serve.quantize_ring_params(p, cfg, tp=tp)
        return p
    return jax.eval_shape(build)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16, *, ring: Optional[Tuple[int, int]] = None):
    def build():
        c = M.init_cache(cfg, batch, max_len, dtype=dtype)
        if ring is not None:
            n_stages, k = ring
            c["layers"] = serve.pad_and_permute(c["layers"], cfg, n_stages, k)
        return c
    return jax.eval_shape(build)


def opt_shapes(params_like, optimizer: Optional[AdamW] = None):
    optimizer = optimizer or AdamW()
    return jax.eval_shape(optimizer.init, params_like)


def batch_shapes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for one cell (excluding params/cache/opt)."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sd((B, S), jnp.int32),
               "labels": sd((B, S), jnp.int32)}
        if cfg.frontend:
            out["embeds"] = sd((B, cfg.n_frontend_tokens, cfg.d_model),
                               jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sd((B, S), jnp.int32)}
        if cfg.frontend:
            out["embeds"] = sd((B, cfg.n_frontend_tokens, cfg.d_model),
                               jnp.bfloat16)
        return out
    # decode: one new token against a seq_len context
    return {"tokens": sd((B, 1), jnp.int32),
            "ln": sd((B,), jnp.int32)}


def input_specs(arch_or_cfg, shape_name: str) -> Dict[str, Any]:
    """Public helper: full ShapeDtypeStruct set for a cell (params, cache,
    batch) — the pattern the dry-run and the roofline benchmarks share."""
    from ..configs import get_config
    cfg = (arch_or_cfg if isinstance(arch_or_cfg, ModelConfig)
           else get_config(arch_or_cfg))
    shape = SHAPES[shape_name]
    out = {"batch": batch_shapes(cfg, shape),
           "params": params_shapes(cfg)}
    if shape.kind != "train":
        ctx = decode_context(cfg, shape)
        out["cache"] = cache_shapes(cfg, shape.global_batch, ctx)
    return out
