import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512"
                           ).strip()
# ^ MUST precede any jax import/initialization: jax locks the device count
#   on first init. This flag is dry-run-only; tests/benches see 1 device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell and both production meshes
(single-pod 16×16 and multi-pod 2×16×16), ``jit(step).lower(...).compile()``
must succeed with ShapeDtypeStruct stand-ins (no allocation). Memory and
cost analyses plus the collective-op histogram are recorded for
EXPERIMENTS.md §Dry-run and the §Roofline benchmarks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape decode_32k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ASSIGNED_ARCHS, SHAPES, get_config
from ..configs.base import ModelConfig, ShapeSpec
from ..runtime import serve
from ..runtime.optim import AdamW
from ..runtime.train import jitted_train_step
from . import specs as SP
from .mesh import make_production_mesh

_DTYPES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
           "u8": 1, "pred": 1, "s16": 2, "u16": 2, "f64": 8, "s64": 8,
           "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Histogram of collective ops in the optimized HLO.

    Bytes are the op's result bytes (all-gather: gathered size; all-reduce:
    tensor size). Ops are attributed to ``nested`` when they occur inside a
    non-entry computation (scan/while bodies execute once per trip — the
    roofline multiplies those by the known trip count).
    """
    ops: Dict[str, Dict[str, float]] = {}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if line and not line[0].isspace() and "{" in line:
            if not line.startswith("ENTRY"):
                in_entry = False
        stripped = line.strip()
        for op in _COLLECTIVES:
            # match '<shape> op(' or '<shape> op-start(' but not fusions
            if re.search(rf"\) {op}(-start)?\(", stripped) or \
                    re.search(rf"\]{{?[^=]*}}? {op}(-start)?\(", stripped) or \
                    f" {op}(" in stripped or f" {op}-start(" in stripped:
                lhs = stripped.split("=")[0] if "=" in stripped else stripped
                rhs_head = stripped.split("=", 1)[-1].split("(", 1)[0]
                nbytes = _shape_bytes(rhs_head)
                key = op + ("" if in_entry else "@nested")
                rec = ops.setdefault(key, {"count": 0, "bytes": 0})
                rec["count"] += 1
                rec["bytes"] += nbytes
                break
    return ops


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        return {"error": str(e)}


# --------------------------------------------------------------------------- #
#  cell construction
# --------------------------------------------------------------------------- #

def decode_path(cfg: ModelConfig, shape: ShapeSpec, mesh) -> str:
    n_pods = mesh.shape.get("pod", 1)
    n_stages = mesh.shape["data"]
    b_pod = shape.global_batch // n_pods
    if shape.global_batch % n_pods:
        return "gspmd"
    if serve.ring_supported(cfg, b_pod, n_stages):
        return "ring"
    return "gspmd"


def lower_cell(arch: str, shape_name: str, mesh, *,
               ring_k: int = 1, microbatch: Optional[int] = None,
               train_style: str = "fsdp", ring_quant: int = 0):
    """Build and lower one cell. Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_pods = mesh.shape.get("pod", 1)
    meta: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "mesh": dict(mesh.shape), "kind": shape.kind}

    if shape.kind == "train":
        params = SP.params_shapes(cfg)
        opt = SP.opt_shapes(params)
        batch = SP.batch_shapes(cfg, shape)
        step = jitted_train_step(cfg, mesh, params,
                                 microbatch=microbatch,
                                 has_embeds="embeds" in batch,
                                 style=train_style,
                                 donate=False)
        lowered = step.lower(params, opt, batch)
        meta["path"] = f"gspmd-train({train_style})"
        return lowered, meta

    if shape.kind == "prefill":
        params = SP.params_shapes(cfg)
        ctx = SP.decode_context(cfg, shape)
        cache = SP.cache_shapes(cfg, shape.global_batch, ctx)
        batch = SP.batch_shapes(cfg, shape)
        fn = serve.gspmd_prefill(cfg, mesh, params, cache,
                                 has_embeds="embeds" in batch)
        args = (params, cache, batch["tokens"])
        if "embeds" in batch:
            args = args + (batch["embeds"],)
        lowered = fn.lower(*args)
        meta["path"] = "gspmd-prefill"
        return lowered, meta

    # decode
    path = decode_path(cfg, shape, mesh)
    ctx = SP.decode_context(cfg, shape)
    batch = SP.batch_shapes(cfg, shape)
    if path == "ring":
        n_stages = mesh.shape["data"]
        tp = mesh.shape["model"]
        plan = serve.RingPlan.make(cfg, n_stages, k=ring_k)
        params = SP.ring_params_shapes(cfg, n_stages, plan.k, tp,
                                       quant=ring_quant)
        cache = SP.cache_shapes(cfg, shape.global_batch // n_pods, ctx,
                                ring=(n_stages, plan.k))
        step = serve.build_ring_serve_step(cfg, mesh, plan)(params, cache)
        # tokens/ln are per-pod shards stacked back to global batch
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        ln = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        if n_pods > 1:
            cache = SP.cache_shapes(cfg, shape.global_batch, ctx,
                                    ring=(n_stages, plan.k))
        lowered = step.lower(tok, ln, params, cache)
        q = f",q{ring_quant}" if ring_quant else ""
        meta["path"] = f"ring(k={plan.k},w={plan.w},Lpad={plan.L_pad}{q})"
        meta["ring"] = {"k": plan.k, "w": plan.w, "M": n_stages,
                        "L_pad": plan.L_pad, "quant": ring_quant,
                        "n_steps": plan.k * n_stages + n_stages - 1}
        if ring_quant:
            meta["weight_bytes_per_param"] = 0.60   # int4 + bf16/64 scales
        return lowered, meta

    params = SP.params_shapes(cfg)
    cache = SP.cache_shapes(cfg, shape.global_batch, ctx)
    fn = serve.gspmd_decode_step(cfg, mesh, params, cache)
    lowered = fn.lower(params, cache, batch["tokens"])
    meta["path"] = "gspmd-decode"
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             ring_k: int = 1, microbatch: Optional[int] = None,
             train_style: str = "fsdp", ring_quant: int = 0,
             keep_text: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, ring_k=ring_k,
                               microbatch=microbatch,
                               train_style=train_style,
                               ring_quant=ring_quant)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    text = compiled.as_text()
    rec = dict(meta)
    rec.update({
        "mesh_kind": mesh_kind,
        "ok": True,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": _mem_analysis(compiled),
        "cost": _cost_analysis(compiled),
        "collectives": parse_collectives(text),
    })
    cfg = get_config(arch)
    rec["model"] = {
        "total_params": cfg.total_params(),
        "active_params": cfg.total_active_params(),
        "n_layers": cfg.n_layers,
    }
    if keep_text:
        rec["hlo"] = text
    return rec


def iter_cells():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            yield arch, shape.name


def _run_subprocess(arch, shape, mk, args) -> Dict[str, Any]:
    """One cell in a fresh process: jit caches and compiler RSS are freed
    between cells, and a pathological cell cannot take down the sweep."""
    import subprocess
    import tempfile
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mk,
           "--ring-k", str(args.ring_k), "--out", tmp, "--single-process"]
    if args.microbatch:
        cmd += ["--microbatch", str(args.microbatch)]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)          # child sets its own 512-device flag
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=3600)
    try:
        with open(tmp) as f:
            recs = json.load(f)
        os.unlink(tmp)
        return recs[0]
    except Exception:
        return {"arch": arch, "shape": shape, "mesh_kind": mk, "ok": False,
                "error": f"subprocess rc={proc.returncode}",
                "stderr": proc.stderr[-1500:]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ring-k", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--single-process", action="store_true",
                    help="run cells in-process (default for single cells)")
    args = ap.parse_args(argv)

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    in_process = args.single_process or (len(cells) == 1
                                         and len(meshes) == 1)

    results = []
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch} × {shape} × {mk}"
            if in_process:
                try:
                    rec = run_cell(arch, shape, mk, ring_k=args.ring_k,
                                   microbatch=args.microbatch)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh_kind": mk,
                           "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
            else:
                rec = _run_subprocess(arch, shape, mk, args)
            if rec.get("ok"):
                ca = rec.get("cost", {})
                print(f"OK   {tag:58s} path={rec['path']} "
                      f"flops={ca.get('flops', float('nan')):.3e} "
                      f"compile={rec.get('compile_s')}s", flush=True)
            else:
                failures += 1
                print(f"FAIL {tag:58s} {rec.get('error')}", flush=True)
            results.append(rec)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\n{len(results) - failures}/{len(results)} cells OK "
          f"-> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
