# NOTE: do not import .dryrun here — it sets XLA_FLAGS at import time and
# must only be imported as the top-level entry point of its own process.
from .mesh import make_debug_mesh, make_production_mesh  # noqa
