"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count before any jax
initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading
    2-pod data-parallel axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_stages: int = 4, tp: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    >= n_stages * tp [* 2])."""
    if multi_pod:
        return jax.make_mesh((2, n_stages, tp), ("pod", "data", "model"))
    return jax.make_mesh((n_stages, tp), ("data", "model"))


# -- hardware constants (TPU v5e target) ------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
CHIP_HBM_BYTES = 16 * (1 << 30)
