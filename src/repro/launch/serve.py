"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Runs batched requests through prefill + piped-ring decode. On CPU the
debug mesh is (data=4, model=2) over 8 forced host devices (set
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on a real pod the
same code takes the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import RequestGenerator
from ..models import init_cache, init_params, prefill
from ..runtime import serve as RS
from ..runtime.telemetry import clock
from .mesh import make_debug_mesh, make_production_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ring-k", type=int, default=1)
    ap.add_argument("--verify-tokens", type=int, default=0,
                    help="T>1: also time a T-token speculative verify "
                         "pass through the ring (weights streamed once "
                         "per pass) against T single-token steps")
    ap.add_argument("--ctx", type=int, default=64)
    ap.add_argument("--mesh", choices=("debug", "prod"), default="debug")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--stream-window", type=int, default=0,
                    help="W>0: also run weight-streaming decode (mmap "
                         "layer store + async prefetcher keeping W layers "
                         "resident) and, on the ring path, the streamed "
                         "ring driver; reports TPOT and peak resident "
                         "parameter bytes vs the fully-resident run")
    ap.add_argument("--store-quant", choices=("none", "q4"), default="none",
                    help="q4: persist the layer store with packed int4 "
                         "weights + bf16 group scales (v2 manifest) and "
                         "stream the packed bytes through the prefetch "
                         "window, dequantizing per layer at use — ~4x "
                         "fewer streamed bytes/layer than bf16")
    ap.add_argument("--chaos", choices=("none", "transient", "failover"),
                    default="none",
                    help="fault-injection smoke: 'transient' injects "
                         "retryable disk faults into the streamed "
                         "layer-wise decode and requires byte-identical "
                         "recovery; 'failover' kills a ring stage "
                         "mid-decode and requires the elastic re-solve "
                         "to resume with zero tokens lost (both exit "
                         "nonzero on a failed recovery)")
    ap.add_argument("--chaos-faults", type=int, default=3,
                    help="consecutive transient faults to inject "
                         "(capped at --io-retries: retries re-hit the "
                         "fault window)")
    ap.add_argument("--io-retries", type=int, default=3,
                    help="IOPolicy: max retries per I/O op before the "
                         "error is classified fatal")
    ap.add_argument("--io-backoff-ms", type=float, default=10.0,
                    help="IOPolicy: base exponential-backoff delay")
    ap.add_argument("--io-deadline-s", type=float, default=30.0,
                    help="IOPolicy: per-op deadline; a stalled read "
                         "surfaces as StallTimeout instead of hanging")
    ap.add_argument("--paged-kv", action="store_true",
                    help="also run continuous batching over the paged KV "
                         "cache (block-pool allocator + prefix reuse + "
                         "host offload) against the dense-cache engine "
                         "on the same requests; fails on any token "
                         "mismatch and reports KV high-water vs the "
                         "dense envelope")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="N",
                    help="with --paged-kv: admit prompts in N-token "
                         "chunks computed straight into the block pool, "
                         "interleaving one decode step for the active "
                         "slots between chunks so a long admit never "
                         "stalls decode for the whole prompt (0 = "
                         "whole-prompt scratch prefill); tokens must stay "
                         "byte-identical to the unchunked run")
    ap.add_argument("--kv-quant-kernel", action="store_true",
                    help="with --paged-kv: store KV pages int8 with "
                         "per-vector scales and attend through the fused "
                         "dequant-in-kernel paged flash kernels (pages "
                         "are read packed, never inflated to bf16 in "
                         "HBM; jnp dequant oracle off-TPU)")
    ap.add_argument("--device-budget", type=float, default=0.0,
                    metavar="MB",
                    help="with --paged-kv: cap device-tier KV bytes; the "
                         "paged pool sizes itself to the budget and the "
                         "tier manager audits that the high-water never "
                         "exceeds it (0 = unbounded)")
    ap.add_argument("--host-budget", type=float, default=0.0,
                    metavar="MB",
                    help="with --paged-kv: cap host-tier bytes (offloaded"
                         " + parked pages); refusals spill the coldest "
                         "pages to the disk tier (0 = unbounded)")
    ap.add_argument("--park-idle-s", type=float, default=None,
                    metavar="S",
                    help="with --paged-kv: enable session parking — "
                         "finished sessions keep their KV on host, "
                         "demote to per-session disk files after S idle "
                         "seconds, and restore byte-identically on the "
                         "next admit; runs a split-run parity check")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="capture a unified runtime trace (spans from "
                         "prefetchers, offloader, decode steps, faults, "
                         "failovers) and write Chrome-trace JSON here — "
                         "open it at https://ui.perfetto.dev")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="print a rolling metrics line every N decode "
                         "tokens: stall attribution (with --trace) and "
                         "request/step percentiles (with --metrics-out)")
    ap.add_argument("--metrics-out", default=None, metavar="OUT.json",
                    help="collect serving metrics (request lifecycle "
                         "percentiles, engine counters, subsystem "
                         "gauges) in a MetricsRegistry and write the "
                         "JSON snapshot here — check it with `python -m "
                         "repro.runtime.metrics --validate OUT.json`")
    args = ap.parse_args(argv)

    from ..runtime.telemetry import NULL_TRACER, Tracer
    tracer = Tracer() if args.trace else NULL_TRACER
    metrics = None
    if args.metrics_out:
        from ..runtime.metrics import MetricsRegistry
        metrics = MetricsRegistry()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if args.mesh == "prod":
        mesh = make_production_mesh()
        stages = 16
        tp = 16
    else:
        mesh = make_debug_mesh(args.stages, args.tp)
        stages, tp = args.stages, args.tp

    B = args.batch
    if not RS.ring_supported(cfg, B, stages):
        print(f"{cfg.name}: ring unsupported for B={B}, M={stages} "
              f"(family={cfg.family}) — GSPMD decode path")
        ring = False
    else:
        ring = True

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    gen = RequestGenerator(cfg.vocab, seed=1,
                           prompt_len=(args.prompt_len,
                                       args.prompt_len + 1))
    reqs = gen.generate(B)
    prompts = jnp.asarray(np.stack([r.prompt for r in reqs]))

    # prefill on the plain path (batch prompts, same length)
    cache = init_cache(cfg, B, args.ctx, dtype=jnp.float32)
    t0 = clock()
    logits, cache = prefill(params, cfg, prompts, cache)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None]
    ttft = clock() - t0
    print(f"prefill: {B}×{args.prompt_len} tokens in {ttft*1e3:.0f} ms")
    if metrics is not None:
        metrics.observe("request/ttft_s", ttft)

    if ring:
        plan = RS.RingPlan.make(cfg, stages, k=args.ring_k)
        pr = RS.pad_vocab(dict(params), cfg, tp)
        pr["blocks"] = RS.pad_and_permute(params["blocks"], cfg, stages,
                                          plan.k)
        cache["layers"] = RS.pad_and_permute(cache["layers"], cfg, stages,
                                             plan.k)
        step = RS.build_ring_serve_step(cfg, mesh, plan)(pr, cache)
        ln = cache["len"]
        out_tokens = [nxt]
        t0 = clock()
        for t in range(args.new_tokens):
            ts = clock()
            with tracer.token_step(t, track="decode"):
                with tracer.phase("compute"):
                    logits, cache = step(nxt, ln, pr, cache)
                    ln = ln + 1
                    nxt = jnp.argmax(logits[:, 0, :cfg.vocab],
                                     -1)[:, None]
                    nxt = jax.block_until_ready(nxt)
            if metrics is not None:
                metrics.observe("decode/step_s", clock() - ts)
                metrics.inc("tokens/generated", B)
            out_tokens.append(nxt)
            _metrics_tick(tracer, args, t, metrics)
        dt = clock() - t0
        print(f"ring decode (k={plan.k}, w={plan.w}, M={stages}, TP={tp}): "
              f"{args.new_tokens} tokens × {B} seqs in {dt:.2f}s "
              f"-> {dt / args.new_tokens * 1e3:.1f} ms/token/batch")

        T = args.verify_tokens
        if T > 1 and cfg.family != "ssm":
            vstep = RS.build_ring_serve_step(cfg, mesh, plan,
                                             n_tokens=T)(pr, cache)
            vt = jnp.tile(nxt, (1, T))
            logits, cache = vstep(vt, ln, pr, cache)   # compile + warm
            jax.block_until_ready(logits)
            iters = 3
            t0 = clock()
            for _ in range(iters):
                logits, cache = vstep(vt, ln, pr, cache)
                jax.block_until_ready(logits)
            dtv = (clock() - t0) / iters
            per_tok = dt / args.new_tokens
            print(f"verify pass (T={T}): {dtv * 1e3:.1f} ms vs "
                  f"{T}×{per_tok * 1e3:.1f} ms single steps -> "
                  f"amortization {T * per_tok / dtv:.2f}x")
    else:
        step = RS.gspmd_decode_step(cfg, mesh, params, cache)
        t0 = clock()
        for t in range(args.new_tokens):
            logits, cache = step(params, cache, nxt)
            nxt = jnp.argmax(logits[:, 0], -1)[:, None]
        dt = clock() - t0
        print(f"gspmd decode: {args.new_tokens} × {B} in {dt:.2f}s")

    if args.stream_window > 0 and cfg.family in ("dense", "moe", "vlm",
                                                 "ssm"):
        _stream_smoke(cfg, params, prompts, args,
                      ring_ctx=(mesh, stages, tp) if ring else None,
                      tracer=tracer)
    if args.paged_kv:
        pcfg = cfg
        if args.kv_quant_kernel and cfg.kv_dtype != "int8":
            pcfg = dataclasses.replace(cfg, kv_dtype="int8")
        if cfg.family not in ("dense", "moe", "vlm"):
            print(f"paged-kv: unsupported family {cfg.family} — skipped")
        elif pcfg.kv_dtype == "int8" and pcfg.mla:
            print("paged-kv: int8 MLA latent pages unsupported — skipped")
        else:
            _paged_smoke(pcfg, params, args, tracer=tracer,
                         metrics=metrics)
    if args.chaos != "none":
        if cfg.family not in ("dense", "moe", "vlm", "ssm"):
            print(f"chaos: unsupported family {cfg.family} — skipped")
        else:
            _chaos_smoke(cfg, params, prompts, args,
                         ring_ctx=(mesh, stages, tp) if ring else None,
                         tracer=tracer)
    print("sample token ids:", np.asarray(nxt).ravel()[:8].tolist())
    if args.trace:
        from ..runtime.telemetry import format_summary
        tracer.export_chrome_trace(args.trace)
        summ = tracer.summary()
        if summ.get("n"):
            print("stall attribution:", format_summary(summ))
        print(f"trace: {len(tracer.events())} events on "
              f"{len(tracer.tracks())} tracks -> {args.trace} "
              f"(open at https://ui.perfetto.dev)")
    if metrics is not None:
        from ..runtime.metrics import validate_metrics_snapshot
        path = metrics.export_json(args.metrics_out)
        info = validate_metrics_snapshot(path)
        print(f"metrics: {info['counters']} counters, "
              f"{info['gauges']} gauges, {info['histograms']} "
              f"histograms -> {path}")
        print(_percentile_line(metrics) or "metrics: no samples yet")
    return 0


def _percentile_line(metrics) -> str:
    """One line of request/step percentiles for the console."""
    pcts = metrics.percentile_summary()
    parts = []
    for key, label in (("request/ttft_s", "ttft"),
                       ("request/tpot_s", "tpot"),
                       ("request/queue_wait_s", "queue"),
                       ("decode/step_s", "step")):
        if f"{key}/p50" in pcts:
            parts.append(f"{label} p50/p99 "
                         f"{pcts[f'{key}/p50'] * 1e3:.1f}/"
                         f"{pcts[f'{key}/p99'] * 1e3:.1f} ms")
    if "request/prefill_chunks/p50" in pcts:
        parts.append(f"prefill chunks p50/p99 "
                     f"{pcts['request/prefill_chunks/p50']:.0f}/"
                     f"{pcts['request/prefill_chunks/p99']:.0f}")
    stall = metrics._counters.get("decode/interleave_stall_s")
    if stall is not None and stall.value > 0:
        parts.append(f"interleave stall {stall.value * 1e3:.1f} ms")
    return "; ".join(parts)


def _metrics_tick(tracer, args, t: int, metrics=None) -> None:
    """Print a periodic rolling line (--metrics-interval): stall
    attribution when tracing, request/step percentiles when metering."""
    n = args.metrics_interval
    if n <= 0 or (t + 1) % n != 0:
        return
    if args.trace:
        from ..runtime.telemetry import format_summary
        summ = tracer.summary(last_n=n)
        if summ.get("n"):
            print(f"[token {t + 1}] {format_summary(summ)}")
    if metrics is not None:
        line = _percentile_line(metrics)
        if line:
            print(f"[token {t + 1}] {line}")


def _io_policy(args):
    from ..runtime.iopolicy import IOPolicy

    return IOPolicy(max_retries=args.io_retries,
                    backoff_base_s=args.io_backoff_ms / 1e3,
                    backoff_max_s=max(args.io_backoff_ms / 1e3, 0.1),
                    op_deadline_s=args.io_deadline_s,
                    get_timeout_s=2 * args.io_deadline_s)


def _chaos_smoke(cfg, params, prompts, args, *, ring_ctx=None,
                 tracer=None) -> None:
    """Fault-injection smoke: recovery is the pass criterion."""
    import shutil
    import tempfile

    from ..models import decode_step_layerwise
    from ..runtime.faults import FaultInjector, FaultSpec, FaultyStore
    from ..runtime.paramstore import ParamStore, save_param_store
    from ..runtime.streaming import StreamingParamSource

    policy = _io_policy(args)
    B = prompts.shape[0]
    sdir = tempfile.mkdtemp(prefix="chaos_store_")
    try:
        save_param_store(params, cfg, sdir)
        if args.chaos == "transient":
            def decode(store, pol=None):
                with StreamingParamSource(store, window=2,
                                          policy=pol) as src:
                    c = init_cache(cfg, B, args.ctx, dtype=jnp.float32)
                    lg, c = prefill(params, cfg, prompts, c)
                    tok = jnp.argmax(lg[:, -1], -1)[:, None]
                    out = [np.asarray(tok)]
                    for _ in range(args.new_tokens):
                        lg, c = decode_step_layerwise(src, cfg, c, tok)
                        tok = jnp.argmax(lg[:, 0], -1)[:, None]
                        out.append(np.asarray(tok))
                    return np.concatenate(out, 1), src.stats()

            clean, _ = decode(ParamStore(sdir))
            n = min(args.chaos_faults, policy.max_retries)
            inj = FaultInjector([FaultSpec(op="layer_read", after=4,
                                           times=n)])
            chaos, st = decode(FaultyStore(ParamStore(sdir), inj),
                               policy)
            if not np.array_equal(clean, chaos):
                raise SystemExit("chaos transient: tokens DIVERGED "
                                 "after retry recovery")
            print(f"chaos transient: {len(inj.fired)} injected disk "
                  f"faults absorbed by retry/backoff "
                  f"({st.retries} retries in PrefetchStats); tokens "
                  f"byte-identical to the clean run")
        else:   # failover
            from ..runtime.failover import ElasticRingServer

            if ring_ctx is None:
                print("chaos failover: ring path unavailable — skipped")
                return
            _, stages, tp = ring_ctx
            if len(jax.devices()) < stages * tp:
                print(f"chaos failover: needs {stages * tp} devices — "
                      "skipped")
                return

            class Counting:
                def __init__(self, store):
                    self.store, self.reads = store, 0

                def layer(self, i):
                    self.reads += 1
                    return self.store.layer(i)

                def __getattr__(self, name):
                    return getattr(self.store, name)

            counting = Counting(ParamStore(sdir))
            srv = ElasticRingServer(cfg, counting, params, batch=B,
                                    ctx=args.ctx, n_stages=stages,
                                    tp=tp, k=args.ring_k, policy=policy)
            try:
                srv.generate(np.asarray(prompts, np.int32), 2)
            finally:
                srv.close()
                counting.close()

            inj = FaultInjector([FaultSpec(
                op="layer_read", mode="stage_failure", stage=1,
                after=counting.reads, times=1)], tracer=tracer)
            store = FaultyStore(ParamStore(sdir), inj)
            srv = ElasticRingServer(cfg, store, params, batch=B,
                                    ctx=args.ctx, n_stages=stages,
                                    tp=tp, k=args.ring_k, policy=policy,
                                    tracer=tracer)
            try:
                toks = srv.generate(np.asarray(prompts, np.int32),
                                    args.new_tokens)
            finally:
                srv.close()
                store.close()
            if not srv.events:
                raise SystemExit("chaos failover: injected stage death "
                                 "never surfaced")
            ev = srv.events[0]
            if ev.tokens_lost or toks.shape[1] != args.new_tokens:
                raise SystemExit(f"chaos failover: lost "
                                 f"{ev.tokens_lost} tokens")
            print(f"chaos failover: stage {ev.failed_stage} died at "
                  f"token {ev.token_index}; ring {ev.n_stages_before}->"
                  f"{ev.n_stages_after} stages, replayed "
                  f"{ev.replayed_tokens} tokens, recovered in "
                  f"{ev.recovery_s:.2f}s (detect {ev.detect_s * 1e3:.1f}"
                  f" ms, re-solve {ev.resolve_s * 1e3:.1f} ms, rebuild "
                  f"{ev.rebuild_s:.2f}s, replay {ev.replay_s:.2f}s), "
                  f"0 tokens lost")
    finally:
        shutil.rmtree(sdir, ignore_errors=True)


def _paged_smoke(cfg, params, args, *, tracer=None, metrics=None) -> None:
    """Paged-KV parity smoke: dense vs paged continuous batching."""
    import jax.numpy as jnp

    from ..models import init_cache
    from ..runtime.engine import make_dense_engine
    from ..runtime.kvcache import make_paged_engine

    B, ctx = args.batch, args.ctx
    gen = RequestGenerator(cfg.vocab, seed=7,
                           prompt_len=(args.prompt_len,
                                       args.prompt_len + 8),
                           max_new=args.new_tokens)
    reqs = gen.generate(2 * B)

    eng_d = make_dense_engine(params, cfg, B, ctx)
    t0 = clock()
    fin_d, _ = eng_d.run(init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    t_dense = clock() - t0

    page_tokens = 8
    n_pages = 2 + B * (-(-ctx // page_tokens))
    eng_p, kv = make_paged_engine(params, cfg, B, ctx, n_pages=n_pages,
                                  page_tokens=page_tokens, tracer=tracer,
                                  metrics=metrics,
                                  prefill_chunk=args.prefill_chunk or None)
    t0 = clock()
    fin_p, _ = eng_p.run(kv.init_cache(), reqs)
    t_paged = clock() - t0
    st = kv.stats()
    kv.close()

    dense = {f.uid: f.tokens for f in fin_d}
    paged = {f.uid: f.tokens for f in fin_p}
    if dense != paged:
        bad = [u for u in dense if dense[u] != paged.get(u)]
        raise SystemExit(f"paged-kv parity FAILED for uids {bad}")
    mode = []
    if args.prefill_chunk:
        mode.append(f"chunked prefill ({args.prefill_chunk} tokens)")
    if cfg.kv_dtype == "int8":
        mode.append("int8 KV pages")
    if mode:
        print(f"paged-kv mode: {', '.join(mode)}")
    print(f"paged decode ({len(reqs)} reqs through {B} slots, "
          f"{page_tokens}-token pages): tokens byte-identical to dense; "
          f"{t_paged:.2f}s vs dense {t_dense:.2f}s; KV high-water "
          f"{st.highwater_bytes / 1e6:.2f} MB vs dense envelope "
          f"{st.dense_bytes(B, ctx) / 1e6:.2f} MB "
          f"({st.highwater_bytes / st.dense_bytes(B, ctx):.2f}x); "
          f"prefix hits {st.prefix_hits}, CoW {st.cow_copies}, "
          f"evictions {st.evictions}")

    if args.device_budget > 0 or args.host_budget > 0 \
            or args.park_idle_s is not None:
        _tiered_smoke(cfg, params, args, dense)


def _tiered_smoke(cfg, params, args, dense) -> None:
    """Budgeted/parked paged decode: same tokens, bounded residency."""
    import shutil
    import tempfile

    import jax.numpy as jnp

    from ..runtime.kvcache import make_paged_engine
    from ..runtime.memory import MemoryBudget, TierManager

    B, ctx = args.batch, args.ctx
    budget = MemoryBudget.from_mb(
        device=args.device_budget if args.device_budget > 0 else None,
        host=args.host_budget if args.host_budget > 0 else None)
    memory = TierManager(budget)
    gen = RequestGenerator(cfg.vocab, seed=7,
                           prompt_len=(args.prompt_len,
                                       args.prompt_len + 8),
                           max_new=args.new_tokens)
    reqs = gen.generate(2 * B)
    page_tokens = 8
    n_pages = None if budget.device is not None \
        else 2 + B * (-(-ctx // page_tokens))
    ddir = tempfile.mkdtemp(prefix="kvdisk_")
    try:
        eng, kv = make_paged_engine(
            params, cfg, B, ctx, n_pages=n_pages,
            page_tokens=page_tokens, memory=memory, evict_policy="cost",
            disk_dir=ddir, park_idle_s=args.park_idle_s)
        fin, _ = eng.run(kv.init_cache(), reqs)
        tiered = {f.uid: f.tokens for f in fin}
        shed = {r.uid for r in eng.rejected}
        bad = [u for u in tiered if dense.get(u) != tiered[u]]
        if bad:
            raise SystemExit(f"tiered paged-kv parity FAILED for {bad}")
        stats = memory.stats()
        memory.audit()
        for tier in ("device", "host"):
            s = stats[tier]
            if s.capacity is not None and s.peak > s.capacity:
                raise SystemExit(f"tiered: {tier} high-water "
                                 f"{s.peak} > budget {s.capacity}")
        print(f"tiered paged decode: {len(tiered)} reqs byte-identical "
              f"({len(shed)} shed by budget); device peak "
              f"{stats['device'].peak / 1e6:.2f} MB / "
              f"{'∞' if budget.device is None else f'{budget.device / 1e6:.0f} MB'}, "
              f"host peak {stats['host'].peak / 1e6:.2f} MB, disk peak "
              f"{stats['disk'].peak / 1e6:.2f} MB; refusals "
              f"{stats['host'].refusals}")

        kv.close()

        if args.park_idle_s is not None:
            sid, half = "smoke-session", args.new_tokens
            prompt = reqs[0].prompt
            eng_f, kv_f = make_paged_engine(
                params, cfg, B, ctx,
                n_pages=2 + B * (-(-ctx // page_tokens)),
                page_tokens=page_tokens)
            full, _ = eng_f.run(kv_f.init_cache(),
                                [_SessReq(900, prompt, 2 * half)])
            kv_f.close()
            eng_s, kv_s = make_paged_engine(
                params, cfg, B, ctx,
                n_pages=2 + B * (-(-ctx // page_tokens)),
                page_tokens=page_tokens, disk_dir=ddir,
                park_idle_s=args.park_idle_s)
            cache = kv_s.init_cache()
            f1, _ = eng_s.run(cache, [_SessReq(901, prompt, half, sid)])
            if not kv_s.is_parked(sid):
                raise SystemExit("session never parked at finish")
            f2, _ = eng_s.run(cache, [_SessReq(902, prompt, half, sid)])
            got = f1[0].tokens + \
                [f for f in f2 if f.uid == 902][0].tokens
            ref = full[0].tokens
            if got != ref:
                raise SystemExit("park/restore parity FAILED: "
                                 f"{got} != {ref}")
            st = kv_s.stats()
            kv_s.close()
            print(f"session parking: split run byte-identical to one "
                  f"uninterrupted run ({len(ref)} tokens); parked "
                  f"{st.parked_sessions}, restored "
                  f"{st.restored_sessions}, disk written "
                  f"{st.disk_bytes_written / 1e6:.2f} MB")
    finally:
        shutil.rmtree(ddir, ignore_errors=True)


class _SessReq:
    def __init__(self, uid, prompt, max_new, session=None):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new
        self.session = session


def _stream_smoke(cfg, params, prompts, args, *, ring_ctx=None,
                  tracer=None) -> None:
    """Weight-streaming decode: layer store + prefetcher (+ streamed ring)."""
    import shutil
    import tempfile

    import jax as _jax

    from ..models import decode_step_layerwise
    from ..runtime.paramstore import ParamStore, save_param_store
    from ..runtime.streaming import (StreamingParamSource,
                                     StreamingRingDriver)

    B, W = prompts.shape[0], args.stream_window
    tp = ring_ctx[2] if ring_ctx is not None else args.tp
    store_params = params
    if args.store_quant == "q4":
        # TP-aware group picking so ring window banks shard cleanly; the
        # layer-wise path dequantizes at use either way
        store_params, skipped = RS.quantize_ring_params(
            dict(params), cfg, tp=tp)
        if skipped:
            print(f"store-quant q4: {len(skipped)} leaves left bf16: "
                  f"{', '.join(skipped)}")
    sdir = tempfile.mkdtemp(prefix="paramstore_")
    try:
        save_param_store(store_params, cfg, sdir)
        probe = ParamStore(sdir)
        total = probe.layer_nbytes * cfg.n_layers
        if args.store_quant != "none":
            raw = sum(a.nbytes for a in
                      _jax.tree.leaves(params["blocks"])) // cfg.n_layers
            print(f"store: {probe.quant_format} manifest v{probe.version}, "
                  f"{probe.layer_nbytes / 1e6:.2f} MB/layer packed vs "
                  f"{raw / 1e6:.2f} MB/layer unquantized "
                  f"({probe.layer_nbytes / raw:.2f}x)")
        probe.close()

        from ..runtime.telemetry import NULL_TRACER
        tracer = tracer or NULL_TRACER
        with StreamingParamSource(ParamStore(sdir), window=W,
                                  policy=_io_policy(args),
                                  tracer=tracer) as src:
            c_s = init_cache(cfg, B, args.ctx, dtype=jnp.float32)
            lg, c_s = prefill(params, cfg, prompts, c_s)
            tok = jnp.argmax(lg[:, -1], -1)[:, None]
            t0 = clock()
            for t in range(args.new_tokens):
                with tracer.token_step(t, track="decode",
                                       name=f"stream_token[{t}]"):
                    with tracer.phase("compute"):
                        lg, c_s = decode_step_layerwise(src, cfg, c_s,
                                                        tok)
                        tok = jnp.argmax(lg[:, 0], -1)[:, None]
                        tok = _jax.block_until_ready(tok)
                _metrics_tick(tracer, args, t)
            dt = clock() - t0
            st = src.stats()
        label = "" if args.store_quant == "none" \
            else f", store={args.store_quant}"
        print(f"streamed decode (window={W}/{cfg.n_layers} layers{label}): "
              f"{args.new_tokens} tokens × {B} seqs in {dt:.2f}s -> "
              f"{dt / args.new_tokens * 1e3:.1f} ms/token/batch; "
              f"peak resident {st.peak_resident_bytes / 1e6:.1f} MB of "
              f"{total / 1e6:.1f} MB weights; prefetch stall "
              f"{st.stall_s * 1e3:.0f} ms")

        if ring_ctx is not None and "pod" not in ring_ctx[0].axis_names:
            mesh, stages, tp = ring_ctx
            plan = RS.RingPlan.make(cfg, stages, k=args.ring_k)
            pr = RS.pad_vocab(dict(params), cfg, tp)
            head = {k: v for k, v in pr.items() if k != "blocks"}
            c_r = init_cache(cfg, B, args.ctx, dtype=jnp.float32)
            c_r["layers"] = RS.pad_and_permute(c_r["layers"], cfg, stages,
                                               plan.k)
            drv = StreamingRingDriver(
                cfg, mesh, plan, ParamStore(sdir), head_params=head,
                cache_like=c_r,
                prefetch_depth=max(1, W // max(plan.w, 1)),
                policy=_io_policy(args), tracer=tracer)
            ln = c_r["len"]
            tok = jnp.zeros((B, 1), jnp.int32)
            t0 = clock()
            for _ in range(args.new_tokens):
                logits, c_r = drv.step(tok, ln, c_r)
                ln = ln + 1
                tok = jnp.argmax(logits[:, 0, :cfg.vocab], -1)[:, None]
            dt = clock() - t0
            rst = drv.stats()
            drv.close()
            print(f"streamed ring decode (k={plan.k}, w={plan.w}, "
                  f"M={stages}): {args.new_tokens} tokens in {dt:.2f}s -> "
                  f"{dt / args.new_tokens * 1e3:.1f} ms/token/batch; "
                  f"peak staged {rst.peak_resident_bytes / 1e6:.1f} MB")
    finally:
        shutil.rmtree(sdir, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
