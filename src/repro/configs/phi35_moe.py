"""phi3.5-moe-42b-a6.6b — 32L d=4096 32H (GQA kv=8) d_ff=6400 vocab=32064,
MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
from .base import ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab=32064,
        n_experts=16,
        top_k=2,
        rope_theta=10_000.0,
        skip_shapes=("long_500k",),   # pure full attention (see DESIGN §5)
    )
