"""minitron-8b — 32L d=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Pruned Nemotron.  [arXiv:2407.14679]"""
from .base import ModelConfig, register


@register("minitron-8b")
def minitron() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=256000,
        rope_theta=10_000.0,
        skip_shapes=("long_500k",),   # pure full attention
    )
