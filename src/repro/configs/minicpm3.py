"""minicpm3-4b — 62L d=2560 40H d_ff=6400 vocab=73448, MLA (multi-head
latent attention).  [hf:openbmb/MiniCPM3-4B]

MLA caches a compressed latent (kv_lora_rank + rope dims per token) instead
of per-head K/V — the KV term in the Halda latency model shrinks from
2*h*e to (r + rope) accordingly (DESIGN §5).
"""
from .base import ModelConfig, register


@register("minicpm3-4b")
def minicpm3() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        kv_heads=40,                 # MLA: effective heads; cache is latent
        head_dim=64,
        d_ff=6400,
        vocab=73448,
        mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        rope_theta=10_000.0,
        skip_shapes=("long_500k",),   # full attention (latent cache, but
                                      # quadratic scores)
    )
