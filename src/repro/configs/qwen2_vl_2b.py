"""qwen2-vl-2b — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE.
[arXiv:2409.12191]

Backbone only per the assignment: the vision frontend is a STUB —
``input_specs`` provides precomputed patch embeddings (B, n_patches, d)
which the model prepends to the token embeddings; M-RoPE applies 3-D
(temporal, height, width) rotary sections to the patch positions.
"""
from .base import ModelConfig, register


@register("qwen2-vl-2b")
def qwen2_vl() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        mrope=True,
        frontend="vision",
        n_frontend_tokens=256,       # precomputed patch embeddings per image
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        skip_shapes=("long_500k",),   # pure full attention
    )
