"""mixtral-8x7b — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096).  [arXiv:2401.04088]"""
from .base import ModelConfig, register


@register("mixtral-8x7b")
def mixtral() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=32000,
        n_experts=8,
        top_k=2,
        attn_window=4096,            # SWA: rolling KV buffer
        rope_theta=1_000_000.0,
        # SWA bounds the KV cache -> long_500k runs (rolling 4096 window)
    )
