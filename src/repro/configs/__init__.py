"""Architecture registry: ``get_config("<arch-id>")`` resolves any assigned
architecture (plus the paper's own Llama family)."""
from .base import SHAPES, ModelConfig, ShapeSpec, get_config, list_archs

# importing the modules populates the registry
from . import (llama_paper, mamba2_780m, minicpm3, minitron_8b, mixtral,
               phi35_moe, qwen15_05b_draft, qwen15_32b, qwen25_14b,
               qwen2_vl_2b, recurrentgemma_9b, whisper_tiny)

#: The ten assigned architectures (dry-run / roofline cells).
ASSIGNED_ARCHS = (
    "phi3.5-moe-42b-a6.6b",
    "mixtral-8x7b",
    "qwen2.5-14b",
    "minicpm3-4b",
    "minitron-8b",
    "qwen1.5-32b",
    "recurrentgemma-9b",
    "mamba2-780m",
    "qwen2-vl-2b",
    "whisper-tiny",
)

ALL_ARCHS = True  # sentinel: registry populated

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "get_config", "list_archs",
           "ASSIGNED_ARCHS"]
