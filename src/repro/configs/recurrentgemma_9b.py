"""recurrentgemma-9b — 38L d=4096 16H (MQA kv=1) d_ff=12288 vocab=256000,
RG-LRU + local attention, 1 attention per 3 blocks.  [arXiv:2402.19427]

Hybrid: block pattern (rglru, rglru, attn) repeating; attention layers use a
bounded local window, recurrent layers carry O(1) state — so ``long_500k``
runs with a fixed-size cache.
"""
from .base import ModelConfig, register


@register("recurrentgemma-9b")
def recurrentgemma() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        attn_window=2048,
        lru_width=4096,
        block_pattern=("rglru", "rglru", "attn"),
        rope_theta=10_000.0,
    )
