"""qwen1.5-32b — 64L d=5120 40H (GQA kv=40 = MHA) d_ff=27392 vocab=152064,
QKV bias.  [hf:Qwen/Qwen1.5-* family]

MHA at 32k context is the KV-heaviest cell in the pool; the config selects
int8 KV-cache quantization so decode_32k fits the per-chip HBM budget
(see EXPERIMENTS §Dry-run).
"""
from .base import ModelConfig, register


@register("qwen1.5-32b")
def qwen15_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        kv_dtype="int8",
        skip_shapes=("long_500k",),   # pure full attention
    )
