"""qwen1.5-0.5b — 24L d=1024 16H (MHA) d_ff=2816, tied embeddings, QKV
bias.  [hf:Qwen/Qwen1.5-0.5B]

The draft model for the paper's 32B speculative-decoding scenario: same
tokenizer family as qwen1.5-32b (vocab kept identical to the target
config so draft tokens index the target's logits directly), ~60x fewer
parameters, so a draft step costs ~1-2% of a target step on the home
cluster while the target verifies the whole draft block in one
weight-streaming pass.
"""
from .base import ModelConfig, register


@register("qwen1.5-0.5b")
def qwen15_05b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab=152064,              # must match the spec-decode target
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        skip_shapes=("long_500k",),
    )
