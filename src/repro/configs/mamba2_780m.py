"""mamba2-780m — 48L d=1536, attention-free, vocab=50280, SSD state=128.
[arXiv:2405.21060]

State-space duality (SSD): per-layer state is (heads, head_dim, state) —
O(1) in sequence length, so every decode shape including ``long_500k`` runs
with constant memory.
"""
from .base import ModelConfig, register


@register("mamba2-780m")
def mamba2() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        d_inner=3072,               # expand = 2
        ssm_head_dim=64,            # -> 48 SSD heads
        conv_width=4,
        tie_embeddings=True,
    )
