"""whisper-tiny — 4L enc + 4L dec, d=384 6H (kv=6) d_ff=1536 vocab=51865,
encoder-decoder with conv frontend (stub).  [arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, 1500, d). The decoder has a
448-token context by construction; the 32k decode shapes are lowered for
shape coverage only (DESIGN §5).
"""
from .base import ModelConfig, register


@register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,                 # decoder depth
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab=51865,
        frontend="audio",
        n_frontend_tokens=1500,     # precomputed mel-frame embeddings
        max_decode_len=448,
        tie_embeddings=True,
        use_rope=False,              # absolute sinusoidal positions
        skip_shapes=("long_500k",),   # 448-token decoder context
    )
