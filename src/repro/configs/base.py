"""Architecture configuration system.

Every assigned architecture is a ``ModelConfig`` instance registered under
its public id; ``--arch <id>`` everywhere resolves through ``get_config``.
Configs carry exact published hyperparameters plus the bookkeeping the
framework needs: parameter accounting (for the profiler / roofline),
input specs per benchmark shape (for the dry-run), and a ``reduced()``
variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

GiB = float(1 << 30)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell: (seq_len, global_batch, kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


#: The assigned input-shape set (identical across LM-family archs).
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    #: expert capacity factor for train/prefill dispatch; ``None`` = lossless
    #: (capacity = T, no token ever dropped). Decode is always lossless.
    moe_capacity_factor: Optional[float] = 1.25
    # --- attention variants ----------------------------------------------
    attn_window: Optional[int] = None     # sliding-window attention
    mla: bool = False                      # multi-head latent attention
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- recurrent / SSM ---------------------------------------------------
    ssm_state: int = 0             # Mamba-2 state dimension N
    d_inner: int = 0               # Mamba-2 expanded width
    ssm_head_dim: int = 64         # Mamba-2 P (head dim)
    conv_width: int = 4
    lru_width: int = 0             # RG-LRU recurrence width
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")
    # --- modality frontend (stub per spec) --------------------------------
    frontend: Optional[str] = None        # "vision" | "audio"
    n_frontend_tokens: int = 0            # precomputed embedding count
    mrope: bool = False                   # multimodal rotary (Qwen2-VL)
    # --- encoder-decoder ---------------------------------------------------
    n_enc_layers: int = 0                 # whisper encoder depth
    max_decode_len: int = 0               # architecture-bound decoder context
    # --- numerics -----------------------------------------------------------
    kv_dtype: str = "bfloat16"            # "bfloat16" | "int8"
    use_rope: bool = True                 # whisper: absolute sinusoidal only
    # Which benchmark shapes apply to this arch. ``long_500k`` is only for
    # sub-quadratic archs (see DESIGN.md §5); others note the skip.
    skip_shapes: Tuple[str, ...] = ()

    # ------------------------------------------------------------------ #
    #  derived dimensions
    # ------------------------------------------------------------------ #

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        if self.mla:
            return self.kv_lora_rank + self.qk_rope_dim  # latent cache width
        return self.kv_heads * self.head_dim

    def layer_kind(self, layer: int) -> str:
        """Mixer kind for layer ``layer`` (hybrid archs interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        return "attn"

    def layer_kinds(self) -> List[str]:
        return [self.layer_kind(i) for i in range(self.n_layers)]

    # ------------------------------------------------------------------ #
    #  parameter accounting (used by profiler + roofline MODEL_FLOPS)
    # ------------------------------------------------------------------ #

    def attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            # q: d->q_lora->heads*(nope+rope); kv: d->kv_lora(+rope);
            # up: kv_lora->heads*(nope+v); o: heads*v->d
            p = d * self.q_lora_rank
            p += self.q_lora_rank * self.n_heads * (self.qk_nope_dim
                                                    + self.qk_rope_dim)
            p += d * (self.kv_lora_rank + self.qk_rope_dim)
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim
                                                     + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            return p
        q = d * self.n_heads * self.head_dim
        kv = 2 * d * self.kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        bias = (self.n_heads + 2 * self.kv_heads) * self.head_dim \
            if self.qkv_bias else 0
        return q + kv + o + bias

    def ffn_params_per_expert(self) -> int:
        # gated GLU: gate + up + down
        return 3 * self.d_model * self.d_ff

    def mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "attn":
            return self.attn_params()
        if kind == "rglru":
            # Griffin recurrent block: in-proj x2 (d->lru), conv(4), RG-LRU
            # gates (2 per-channel + 2 input proj), out-proj
            w = self.lru_width or d
            return 2 * d * w + 4 * w + 2 * w + 2 * w * w // max(w // d, 1) \
                if False else (2 * d * w + 4 * w + 4 * w + w * d)
        if kind == "ssm":
            # Mamba-2: in_proj d -> (2*d_inner + 2*groups*state + heads),
            # conv, dt/A/D, out_proj d_inner -> d
            di, N = self.d_inner, self.ssm_state
            nh = di // self.ssm_head_dim
            p = self.d_model * (2 * di + 2 * N + nh)
            p += self.conv_width * (di + 2 * N)
            p += 2 * nh                      # A_log, D
            p += di * self.d_model
            p += di                          # norm gate
            return p
        raise ValueError(kind)

    def params_per_layer(self) -> int:
        """Mean parameters per layer (weights only, no embeddings)."""
        total = 0
        for kind in self.layer_kinds():
            total += self.mixer_params(kind)
            if kind in ("attn", "rglru"):
                if self.n_experts:
                    total += self.n_experts * self.ffn_params_per_expert()
                    total += self.d_model * self.n_experts  # router
                else:
                    total += self.ffn_params_per_expert()
            elif kind == "ssm":
                pass  # Mamba-2 block has no separate FFN
            total += 2 * self.d_model  # 2 RMSNorm scales
        return total // self.n_layers

    def active_params_per_layer(self) -> int:
        """Per-token active parameters (MoE: only top_k experts)."""
        if not self.n_experts:
            return self.params_per_layer()
        total = 0
        for kind in self.layer_kinds():
            total += self.mixer_params(kind)
            total += self.top_k * self.ffn_params_per_expert()
            total += self.d_model * self.n_experts
            total += 2 * self.d_model
        return total // self.n_layers

    def embedding_params(self) -> int:
        p = self.vocab * self.d_model
        if not self.tie_embeddings:
            p *= 2
        return p

    def total_params(self) -> int:
        p = self.n_layers * self.params_per_layer() + self.embedding_params()
        if self.n_enc_layers:
            # encoder layers: attn + ffn (no cross-attn in encoder);
            # decoder layers counted above also carry cross-attention.
            enc = self.n_enc_layers * (self.attn_params()
                                       + self.ffn_params_per_expert()
                                       + 2 * self.d_model)
            dec_cross = self.n_layers * self.attn_params()
            p += enc + dec_cross
        return p

    def total_active_params(self) -> int:
        return (self.n_layers * self.active_params_per_layer()
                + self.embedding_params())

    # ------------------------------------------------------------------ #
    #  smoke-test reduction
    # ------------------------------------------------------------------ #

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: Dict = {}
        kw["n_layers"] = min(self.n_layers, 4 if not self.block_pattern
                             else 2 * len(self.block_pattern))
        kw["d_model"] = 64
        kw["n_heads"] = 4 if self.n_heads else 0
        kw["kv_heads"] = (min(self.kv_heads, 4) if self.kv_heads else 0)
        if self.kv_heads == self.n_heads:
            kw["kv_heads"] = 4
        elif self.kv_heads:
            kw["kv_heads"] = max(1, 4 * self.kv_heads // self.n_heads)
        kw["head_dim"] = 16
        kw["d_ff"] = 128
        kw["vocab"] = 256
        if self.n_experts:
            kw["n_experts"] = 4
            kw["top_k"] = min(self.top_k, 2)
            kw["moe_capacity_factor"] = None   # exactness for smoke tests
        if self.attn_window:
            kw["attn_window"] = 32
        if self.mla:
            kw["q_lora_rank"] = 32
            kw["kv_lora_rank"] = 16
            kw["qk_nope_dim"] = 8
            kw["qk_rope_dim"] = 8
            kw["v_head_dim"] = 8
        if self.ssm_state:
            kw["ssm_state"] = 16
            kw["d_inner"] = 128
            kw["ssm_head_dim"] = 16
        if self.lru_width:
            kw["lru_width"] = 64
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 16
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
        if self.max_decode_len:
            kw["max_decode_len"] = 64
        kw["name"] = self.name + "-smoke"
        return dataclasses.replace(self, **kw)

    def shapes(self) -> List[ShapeSpec]:
        return [s for k, s in SHAPES.items() if k not in self.skip_shapes]


# --------------------------------------------------------------------------- #
#  registry
# --------------------------------------------------------------------------- #

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    from . import ALL_ARCHS  # noqa: F401  (triggers module imports)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    from . import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)
