"""Llama-family configs used by the paper's own experiments (Table 3/4).

These drive the reproduction benchmarks; they are *additional* to the ten
assigned architectures.
"""
from .base import ModelConfig, register


def _llama(name, n_layers, d_model, n_heads, kv_heads, d_ff, vocab=128256,
           **kw) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, kv_heads=kv_heads, head_dim=d_model // n_heads,
        d_ff=d_ff, vocab=vocab, rope_theta=500_000.0,
        skip_shapes=("long_500k",), **kw)


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    return _llama("llama3-8b", 32, 4096, 32, 8, 14336)


@register("llama3-14b")
def llama3_14b() -> ModelConfig:  # paper's interpolated 14B
    return _llama("llama3-14b", 40, 5120, 40, 8, 13824)


@register("llama1-30b")
def llama1_30b() -> ModelConfig:
    return _llama("llama1-30b", 60, 6656, 52, 52, 17920, vocab=32000)


@register("llama3-45b")
def llama3_45b() -> ModelConfig:  # paper's interpolated 45B
    return _llama("llama3-45b", 60, 6656, 52, 13, 21504)


@register("llama3-60b")
def llama3_60b() -> ModelConfig:  # paper's interpolated 60B
    return _llama("llama3-60b", 70, 7168, 56, 8, 24576)


@register("llama1-65b")
def llama1_65b() -> ModelConfig:
    return _llama("llama1-65b", 80, 8192, 64, 64, 22016, vocab=32000)


@register("llama3-70b")
def llama3_70b() -> ModelConfig:
    return _llama("llama3-70b", 80, 8192, 64, 8, 28672)
