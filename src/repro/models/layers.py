"""Layer library: every mixer/FFN variant needed by the assigned archs.

Pure ``jnp`` functions over explicit parameter dicts. Distribution is
layered on top: the GSPMD path (train/prefill) relies on sharding
constraints outside these functions; the explicit shard_map ring path
passes ``tp_axis`` so projections psum over the tensor-parallel axis.

Conventions:
  x          : (B, S, d) activations
  attn cache : k/v (B, S_max, h_kv, hd)  [+ int8 scales if quantized]
  positions  : (B, S) int32 absolute positions (M-RoPE: (3, B, S))
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
#  basics
# --------------------------------------------------------------------------- #

def qmm(x: jnp.ndarray, w) -> jnp.ndarray:
    """Matmul against a weight that may still be packed.

    Plain arrays take the ordinary ``@``. A 2-D q4 ``QuantizedTensor``
    (the shape the streamed layer-wise path pulls from a v2 store)
    dispatches the fused ``kernels.ops.q4_matmul`` — dequantization
    happens tile-by-tile in VMEM instead of materializing the bf16 weight
    in HBM first. Ineligible quantized leaves (q2, 3-D expert stacks,
    tile-misaligned dims) fall back to dequantize-then-matmul, which is
    bit-identical at these sizes (both paths accumulate f32).
    """
    from ..quant.grouped import QuantizedTensor, dequantize_leaf

    if not isinstance(w, QuantizedTensor):
        return x @ w
    *lead, K = x.shape
    M = int(np.prod(lead, dtype=np.int64)) if lead else 1
    # the kernel's row tile is min(256, M): M must divide into it
    if q4_fused_eligible(w) and (M <= 256 or M % 256 == 0):
        from ..kernels import ops

        out = ops.q4_matmul(x.reshape(M, K), w.packed, w.scale,
                            group=w.group)
        return out.reshape(*lead, out.shape[-1]).astype(x.dtype)
    return x @ dequantize_leaf(w, jnp.float32).astype(x.dtype)


def q4_fused_eligible(w) -> bool:
    """Whether a QuantizedTensor fits ``kernels.q4_matmul``'s layout:
    2-D q4 packing whose dims divide the kernel's MXU-aligned blocks."""
    if w.bits != 4 or w.packed.ndim != 2:
        return False
    K, N = w.packed.shape[0] * 2, w.packed.shape[1]
    if K % w.group or 256 % w.group:
        return False
    return (K <= 256 or K % 256 == 0) and (N <= 512 or N % 512 == 0)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
             ) -> jnp.ndarray:
    """RMSNorm with f32 statistics but width-preserving dtype: the (B,S,d)
    intermediates stay in x.dtype so activation collectives (and their
    gradients) move half the bytes (see EXPERIMENTS §Perf HC1)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def swish(x):
    return x * jax.nn.sigmoid(x)


# --------------------------------------------------------------------------- #
#  rotary embeddings (standard / partial / M-RoPE)
# --------------------------------------------------------------------------- #

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, h, d); positions: (B, S). Trig in f32, rotation applied in
    x.dtype (keeps the head-wide tensors — and their gradients/collectives
    — at bf16 width)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL 3-D rotary sections (t, h, w) summing to head_dim // 2."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float
                ) -> jnp.ndarray:
    """M-RoPE: positions3 (3, B, S) — temporal/height/width streams.

    Frequency layout matches standard RoPE; each frequency index is driven
    by one of the three position streams according to its section.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                       # (half,)
    sec = mrope_sections(d)
    sec_id = jnp.concatenate([
        jnp.full((sec[0],), 0), jnp.full((sec[1],), 1),
        jnp.full((sec[2],), 2)]).astype(jnp.int32)      # (half,)
    # pos per freq index: (B, S, half)
    pos = jnp.take(positions3.astype(jnp.float32), sec_id, axis=0)  # (half,B,S)
    pos = jnp.moveaxis(pos, 0, -1)                      # (B, S, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# --------------------------------------------------------------------------- #
#  attention — chunked causal (train/prefill) and cached decode
# --------------------------------------------------------------------------- #

def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, h_kv, d) -> (B, S, h_kv*n_rep, d) (GQA broadcast)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def chunked_causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             *, window: Optional[int] = None,
                             q_offset: int = 0,
                             chunk: int = 512) -> jnp.ndarray:
    """Flash-style double-chunked causal attention (pure jnp oracle).

    q: (B, Sq, H, D); k, v: (B, Sk, h_kv, D). Scans KV chunks with an online
    softmax, so peak memory is O(chunk^2) per head instead of O(S^2). This
    is also the reference for the Pallas flash kernel.
    ``window``: sliding-window size (None = full causal).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(D)
    qc = chunk
    kc = chunk
    n_q = -(-Sq // qc)
    n_k = -(-Sk // kc)
    q_pad = n_q * qc - Sq
    k_pad = n_k * kc - Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    # (B, H, nq, qc, D) / (B, H, nk, kc, D)
    qb = q.reshape(B, n_q, qc, H, D).transpose(0, 3, 1, 2, 4) * scale
    kb = k.reshape(B, n_k, kc, H, D).transpose(0, 3, 1, 2, 4)
    vb = v.reshape(B, n_k, kc, H, D).transpose(0, 3, 1, 2, 4)

    q_pos = q_offset + jnp.arange(n_q * qc)
    k_pos = jnp.arange(n_k * kc)

    def q_chunk_body(qi, q_tile):
        # online softmax over kv chunks
        acc0 = jnp.zeros((B, H, qc, D), jnp.float32)
        m0 = jnp.full((B, H, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)

        def kv_body(carry, ki):
            acc, m, l = carry
            k_tile = kb[:, :, ki]
            v_tile = vb[:, :, ki]
            s = jnp.einsum("bhqd,bhkd->bhqk", q_tile, k_tile,
                           preferred_element_type=jnp.float32)
            qp = lax.dynamic_slice_in_dim(q_pos, qi * qc, qc)
            kp = lax.dynamic_slice_in_dim(k_pos, ki * kc, kc)
            mask = qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            mask &= kp[None, :] < Sk  # kv padding
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_tile,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        (acc, m, l), _ = lax.scan(kv_body, (acc0, m0, l0),
                                  jnp.arange(n_k))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out

    def outer(qi):
        return q_chunk_body(qi, qb[:, :, qi])

    out = lax.map(outer, jnp.arange(n_q))              # (nq, B, H, qc, D)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, n_q * qc, H, D)
    return out[:, :Sq].astype(q.dtype)


def verify_attention_stats(q: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                           *, window: Optional[int] = None,
                           pos_offset=0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-query decode attention stats (speculative draft verification).

    q: (B, T, H, D) — T draft positions scored in one pass. Query t sits at
    absolute position ``kv_len - T + t`` (``kv_len`` counts valid cache
    entries *including* the T draft tokens, so T = 1 reduces to ordinary
    decode) and attends causally: cache positions <= its own.
    k_cache/v_cache: (B, S_local, h_kv, D); ``pos_offset``: absolute
    position of this shard's slot 0 (sequence-sharded ring runtime).
    Returns acc (B, H, T, D) [unnormalized], m (B, H, T), l (B, H, T) for
    ``merge_attention_stats`` (psum/pmax over the TP axis).
    """
    B, T, H, D = q.shape
    S = k_cache.shape[1]
    n_rep = H // k_cache.shape[2]
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))               # (B, H, T, S)
    pos = jnp.arange(S) + pos_offset                    # (S,)
    qpos = kv_len[:, None] - T + jnp.arange(T)[None, :]  # (B, T)
    mask = pos[None, None, :] <= qpos[:, :, None]       # (B, T, S)
    if window is not None:
        mask &= pos[None, None, :] > (qpos[:, :, None] - window)
    s = jnp.where(mask[:, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                             # (B, H, T)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask[:, None], jnp.exp(s - m_safe[..., None]), 0.0)
    l = p.sum(-1)                                       # (B, H, T)
    acc = jnp.einsum("bhts,bshd->bhtd", p, v.astype(jnp.float32))
    return acc, m, l


def verify_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                     *, window: Optional[int] = None) -> jnp.ndarray:
    """Multi-position attention against a cache: (B, T, H, D) -> same.

    The pure-jnp oracle for the Pallas ``flash_verify`` kernel; see
    ``verify_attention_stats`` for the causal-among-drafts semantics.
    """
    acc, m, l = verify_attention_stats(q, k_cache, v_cache, kv_len,
                                       window=window)
    out = acc / jnp.maximum(l[..., None], 1e-30)        # (B, H, T, D)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention_stats(q: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                           *, window: Optional[int] = None,
                           pos_offset=0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-query stats — the T = 1 slice of ``verify_attention_stats``.

    q: (B, 1, H, D) -> acc (B, H, D) [unnormalized], m (B, H), l (B, H).
    """
    acc, m, l = verify_attention_stats(q, k_cache, v_cache, kv_len,
                                       window=window, pos_offset=pos_offset)
    return acc[:, :, 0], m[:, :, 0], l[:, :, 0]


def merge_attention_stats(acc, m, l, axis_name: str) -> jnp.ndarray:
    """Combine per-shard online-softmax stats across ``axis_name``."""
    m_g = lax.pmax(m, axis_name)
    m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l_g = lax.psum(l * corr, axis_name)
    acc_g = lax.psum(acc * corr[..., None], axis_name)
    return acc_g / jnp.maximum(l_g[..., None], 1e-30)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                     *, window: Optional[int] = None) -> jnp.ndarray:
    """Single-position attention against a cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S_max, h_kv, D);
    kv_len: (B,) number of valid cache entries (current token included).
    """
    acc, m, l = decode_attention_stats(q, k_cache, v_cache, kv_len,
                                       window=window)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out[:, None].astype(q.dtype)


# --------------------------------------------------------------------------- #
#  standard attention block (GQA / SWA / M-RoPE), with optional QKV bias
# --------------------------------------------------------------------------- #

def init_attn(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    H, hk, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, H * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, hk * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, hk * hd), dtype) * s,
        "wo": jax.random.normal(k4, (H * hd, d), dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((hk * hd,), dtype)
        p["bv"] = jnp.zeros((hk * hd,), dtype)
    return p


def set_qkv_constraint(fn) -> None:
    """Optional hook pinning (B,S,H,hd) tensors (set by the runtime)."""
    global _QKV_CONSTRAINT
    _QKV_CONSTRAINT = fn


_QKV_CONSTRAINT = None

#: hook pinning MoE (E, C, d/f) dispatch buffers — without it GSPMD can
#: replicate the capacity buffer (21 GB/chip at 32k prefill, mixtral).
_MOE_CONSTRAINT = None


def set_moe_constraint(fn) -> None:
    global _MOE_CONSTRAINT
    _MOE_CONSTRAINT = fn


def _constrain_heads(t):
    if _QKV_CONSTRAINT is not None:
        return _QKV_CONSTRAINT(t)
    return t


def attn_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray, positions,
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    H, hk, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = qmm(x, p["wq"])
    k = qmm(x, p["wk"])
    v = qmm(x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = _constrain_heads(q.reshape(B, S, H, hd))
    k = _constrain_heads(k.reshape(B, S, hk, hd))
    v = _constrain_heads(v.reshape(B, S, hk, hd))
    if not cfg.use_rope:
        return q, k, v
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def quantize_kv(t: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(token, head) symmetric int8 quantization: (B,S,h,d) -> int8+scale."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)     # (B,S,h)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def attn_block(p: Params, cfg: ModelConfig, x: jnp.ndarray, positions,
               *, cache: Optional[Dict] = None,
               decode: bool = False, tp_axis: Optional[str] = None,
               cross_kv: Optional[Tuple] = None,
               causal: bool = True) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full attention block: qkv -> attention -> o-proj.

    ``cache``: {"k": (B,Smax,hk,hd), "v": ..., "len": (B,)}. In decode mode
    the new token is written at position ``len`` (rolling for SWA) and
    attention runs against the cache; otherwise full causal attention over
    ``x`` (and the cache is filled if provided).
    If the cache carries ``k_scale``/``v_scale`` the K/V tensors are stored
    int8 (quantize-on-write, dequantize-on-read) — used by MHA archs whose
    32k bf16 cache would overflow the per-chip HBM budget.
    ``cross_kv``: (k, v) from an encoder — skips qkv for k/v (whisper).
    """
    B, S, _ = x.shape
    if cross_kv is not None:
        H, hd = cfg.n_heads, cfg.head_dim
        q = (x @ p["wq"]).reshape(B, S, H, hd)
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(H, hd)
        k, v = cross_kv
        out = chunked_causal_attention(q, k, v, chunk=256) if causal else \
            _full_attention(q, k, v)
        o = out.reshape(B, S, -1) @ p["wo"]
        if tp_axis:
            o = lax.psum(o, tp_axis)
        return o, cache

    q, k, v = attn_qkv(p, cfg, x, positions)
    window = cfg.attn_window
    quantized = cache is not None and "k_scale" in cache
    new_cache = cache
    if decode:
        assert cache is not None
        kc, vc, ln = cache["k"], cache["v"], cache["len"]
        Smax = kc.shape[1]
        rolling = window is not None and Smax == window
        # T > 1 (speculative verify) needs position-addressable slots for
        # causal masking among the draft tokens; a rolling SWA buffer
        # permutes positions, so multi-token decode is gated off there.
        assert S == 1 or not rolling, "multi-token decode needs Smax > window"
        if quantized:
            k_wr, ksc = quantize_kv(k)
            v_wr, vsc = quantize_kv(v)
        else:
            k_wr, v_wr = k.astype(kc.dtype), v.astype(vc.dtype)
        ks_c = cache.get("k_scale")
        vs_c = cache.get("v_scale")
        for t in range(S):                       # static, small (draft block)
            slot = ((ln + t) % window) if rolling \
                else jnp.minimum(ln + t, Smax - 1)
            kc = jax.vmap(lambda c, tt, i: lax.dynamic_update_slice(
                c, tt, (i, 0, 0)))(kc, k_wr[:, t:t + 1], slot)
            vc = jax.vmap(lambda c, tt, i: lax.dynamic_update_slice(
                c, tt, (i, 0, 0)))(vc, v_wr[:, t:t + 1], slot)
            if quantized:
                ks_c = jax.vmap(lambda c, tt, i: lax.dynamic_update_slice(
                    c, tt, (i, 0)))(ks_c, ksc[:, t:t + 1].astype(ks_c.dtype),
                                    slot)
                vs_c = jax.vmap(lambda c, tt, i: lax.dynamic_update_slice(
                    c, tt, (i, 0)))(vs_c, vsc[:, t:t + 1].astype(vs_c.dtype),
                                    slot)
        new_cache = {"k": kc, "v": vc, "len": ln + S}
        if quantized:
            new_cache["k_scale"] = ks_c
            new_cache["v_scale"] = vs_c
            k_at = dequantize_kv(kc, ks_c, q.dtype)
            v_at = dequantize_kv(vc, vs_c, q.dtype)
        else:
            k_at = kc.astype(q.dtype)
            v_at = vc.astype(q.dtype)
        kv_len = jnp.minimum(ln + S, Smax) if window is not None else ln + S
        out = verify_attention(q, k_at, v_at, kv_len, window=window)
    else:
        out = chunked_causal_attention(q, k, v, window=window) if causal \
            else _full_attention(q, k, v)
        if cache is not None:
            Smax = cache["k"].shape[1]
            if window is not None and Smax <= S:
                # rolling buffer: keep the trailing window; token t lives at
                # slot t % Smax so decode's rolling writes stay consistent.
                kk = jnp.roll(k[:, -Smax:], S % Smax, axis=1)
                vv = jnp.roll(v[:, -Smax:], S % Smax, axis=1)
            else:
                kk = k[:, :Smax]
                vv = v[:, :Smax]
            pad_s = Smax - kk.shape[1]
            if pad_s > 0:
                kk = jnp.pad(kk, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
                vv = jnp.pad(vv, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            new_cache = {"len": cache["len"] + S}
            if quantized:
                kq, ksc = quantize_kv(kk)
                vq, vsc = quantize_kv(vv)
                new_cache.update(
                    k=kq, v=vq,
                    k_scale=ksc.astype(cache["k_scale"].dtype),
                    v_scale=vsc.astype(cache["v_scale"].dtype))
            else:
                new_cache.update(k=kk.astype(cache["k"].dtype),
                                 v=vv.astype(cache["v"].dtype))
    o = qmm(out.reshape(B, S, -1), p["wo"])
    if tp_axis:
        o = lax.psum(o, tp_axis)
    return o, new_cache


# --------------------------------------------------------------------------- #
#  paged KV cache: block-table gather / scatter + paged attention
# --------------------------------------------------------------------------- #

def gather_pages(pages: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """(P, bs, ...) page pool + (B, nb) block table -> (B, nb*bs, ...).

    Row ``b``'s gathered axis-1 order IS its sequence order: table entry
    ``j`` covers absolute positions ``j*bs .. (j+1)*bs - 1``. Entries past
    a sequence's length may point anywhere valid (the sink page, a stale
    page) — those positions are >= ``len`` and masked by the caller.
    """
    g = jnp.take(pages, table, axis=0)               # (B, nb, bs, ...)
    B, nb, bs = g.shape[:3]
    return g.reshape(B, nb * bs, *g.shape[3:])


def write_pages(pages: jnp.ndarray, table: jnp.ndarray, ln: jnp.ndarray,
                vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter T new cache lines at positions ``ln .. ln+T-1`` through the
    block table. pages: (P, bs, ...); vals: (B, T, ...); ln: (B,).

    Distinct live slots own distinct pages, so cross-batch scatter indices
    never collide except on the sink page (freed slots), whose content is
    never read unmasked.
    """
    B, T = vals.shape[:2]
    bs, nb = pages.shape[1], table.shape[1]
    bidx = jnp.arange(B)
    for t in range(T):                       # static, small (draft block)
        pos = ln + t
        blk = jnp.minimum(pos // bs, nb - 1)
        pid = table[bidx, blk]
        pages = pages.at[pid, pos % bs].set(vals[:, t].astype(pages.dtype))
    return pages


def paged_verify_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, table: jnp.ndarray,
                           kv_len: jnp.ndarray, *,
                           window: Optional[int] = None) -> jnp.ndarray:
    """Multi-position attention against a paged cache (pure-jnp oracle for
    the Pallas ``paged_verify`` kernel).

    q: (B, T, H, D); k_pages/v_pages: (P, bs, h_kv, D); table: (B, nb);
    kv_len: (B,) valid positions *including* the T current tokens. The
    gather materializes (B, nb*bs, h_kv, D) sequences whose extra
    positions are masked exactly like unused dense-cache slots, so paged
    and dense attention agree bit-for-bit.
    """
    k = gather_pages(k_pages, table)
    v = gather_pages(v_pages, table)
    return verify_attention(q, k, v, kv_len, window=window)


def paged_prefill_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                            v_pages: jnp.ndarray, table: jnp.ndarray,
                            kv_len: jnp.ndarray, *,
                            window: Optional[int] = None) -> jnp.ndarray:
    """Chunk-vs-pages causal attention (pure-jnp oracle for the Pallas
    ``paged_prefill`` kernel).

    q: (B, S, H, D) — one prompt chunk whose KV the caller already wrote
    through the table; ``kv_len`` includes it, so chunk position t sits
    at absolute position ``kv_len - S + t``. The gathered sequence runs
    through ``chunked_causal_attention`` — the *same* function the dense
    prefill path uses — so a chunk-prefilled slot's activations (and the
    first token they produce) are byte-identical to one-shot dense
    prefill. Chunked admission runs one slot at a time, so all batch
    rows share the offset (``kv_len[0]`` is used).
    """
    S = q.shape[1]
    k = gather_pages(k_pages, table).astype(q.dtype)
    v = gather_pages(v_pages, table).astype(q.dtype)
    return chunked_causal_attention(q, k, v, window=window,
                                    q_offset=kv_len[0] - S)


def _paged_attention(q: jnp.ndarray, pages: Dict, table: jnp.ndarray,
                     kv_len: jnp.ndarray, *, window: Optional[int],
                     prefill: bool) -> jnp.ndarray:
    """Dispatch paged attention: fused Pallas kernel when compiled
    kernels are live (TPU), the pure-jnp oracle elsewhere. ``pages`` may
    carry int8 K/V plus ``k_scale``/``v_scale`` — the kernel reads the
    quantized pages directly; the jnp path dequantizes the (gathered)
    sequence first."""
    from ..kernels import ops
    if "k_scale" in pages:
        if ops.kernels_active():
            return ops.paged_verify_quant(
                q, pages["k"], pages["v"], pages["k_scale"],
                pages["v_scale"], table, kv_len, window=window)
        k = dequantize_kv(gather_pages(pages["k"], table),
                          gather_pages(pages["k_scale"], table), q.dtype)
        v = dequantize_kv(gather_pages(pages["v"], table),
                          gather_pages(pages["v_scale"], table), q.dtype)
        if prefill:
            S = q.shape[1]
            return chunked_causal_attention(q, k, v, window=window,
                                            q_offset=kv_len[0] - S)
        return verify_attention(q, k, v, kv_len, window=window)
    if prefill:
        if ops.kernels_active():
            return ops.paged_prefill(q, pages["k"], pages["v"], table,
                                     kv_len, window=window)
        return paged_prefill_attention(q, pages["k"], pages["v"], table,
                                       kv_len, window=window)
    if ops.kernels_active():
        return ops.paged_verify(q, pages["k"], pages["v"], table, kv_len,
                                window=window)
    return paged_verify_attention(q, pages["k"], pages["v"], table, kv_len,
                                  window=window)


def attn_block_paged(p: Params, cfg: ModelConfig, x: jnp.ndarray, positions,
                     pages: Dict, table: jnp.ndarray, ln: jnp.ndarray,
                     *, tp_axis: Optional[str] = None,
                     prefill: bool = False, write: bool = True
                     ) -> Tuple[jnp.ndarray, Dict]:
    """Decode-mode attention block over one layer's page pool.

    ``pages``: {"k": (P, bs, h_kv, hd), "v": ...} — plus
    ``k_scale``/``v_scale`` (P, bs, h_kv) for int8 pools, in which case
    new lines quantize on write (``quantize_kv``) and attention reads
    the quantized pages (dequant fused into the kernel on TPU).
    ``ln``: (B,) valid lengths BEFORE this step. Writes the T new lines
    through the block table, then attends over the gathered pages — the
    same per-position math as ``attn_block``'s decode path (T >= 1
    verify included), so the paged cache changes where KV lives, never
    what attention computes.

    ``prefill``: chunked-admission mode — attention mirrors the dense
    prefill math (``chunked_causal_attention``) instead of the decode
    path, keeping chunk-prefilled activations byte-identical to one-shot
    dense prefill. ``write=False`` skips the page writes (a fully
    prefix-shared prompt re-derives its last-token logits from pages it
    must not touch).
    """
    B, S, _ = x.shape
    q, k, v = attn_qkv(p, cfg, x, positions)
    quantized = "k_scale" in pages
    if not write:
        new_pages = pages
    elif quantized:
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        new_pages = {
            "k": write_pages(pages["k"], table, ln, kq),
            "v": write_pages(pages["v"], table, ln, vq),
            "k_scale": write_pages(pages["k_scale"], table, ln, ksc),
            "v_scale": write_pages(pages["v_scale"], table, ln, vsc),
        }
    else:
        new_pages = {"k": write_pages(pages["k"], table, ln, k),
                     "v": write_pages(pages["v"], table, ln, v)}
    out = _paged_attention(q, new_pages, table, ln + S,
                           window=cfg.attn_window, prefill=prefill)
    o = qmm(out.reshape(B, S, -1), p["wo"])
    if tp_axis:
        o = lax.psum(o, tp_axis)
    return o, new_pages


def mla_block_paged(p: Params, cfg: ModelConfig, x: jnp.ndarray, positions,
                    pages: Dict, table: jnp.ndarray, ln: jnp.ndarray,
                    *, tp_axis: Optional[str] = None,
                    prefill: bool = False, write: bool = True
                    ) -> Tuple[jnp.ndarray, Dict]:
    """MLA decode against paged latent storage (absorbed form).

    ``pages``: {"latent": (P, bs, r_kv + qk_rope_dim)}. Mirrors the
    absorbed decode branch of ``mla_block`` with the latent gathered
    through the block table instead of sliced from a dense cache.
    The S > 1 masking is already chunk-causal (position ``ln + t``
    attends at-or-before itself), so chunked admission reuses this path
    unchanged — ``prefill`` is accepted for signature parity and
    ``write=False`` skips the latent write (fully prefix-shared
    prompts).
    """
    del prefill
    B, S, d = x.shape
    H = cfg.n_heads
    r_kv = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_lat = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]
    latent = rms_norm(kv[..., :r_kv], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., r_kv:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    lat_cat = jnp.concatenate([latent, k_rope], -1)

    lp = write_pages(pages["latent"], table, ln, lat_cat) if write \
        else pages["latent"]
    lc = gather_pages(lp, table)                      # (B, S_eff, r + dr)
    lat_all = lc[..., :r_kv].astype(x.dtype)
    rope_all = lc[..., r_kv:].astype(x.dtype)
    S_eff = lc.shape[1]
    pos_idx = jnp.arange(S_eff)
    qpos = ln[:, None] + jnp.arange(S)[None, :]       # (B, S)
    mask = pos_idx[None, None, :] <= qpos[:, :, None]  # (B, S, S_eff)

    wk = p["wk_b"].reshape(r_kv, H, dn)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wk)
    s_nope = jnp.einsum("bqhr,bsr->bhqs", q_abs, lat_all,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, rope_all,
                        preferred_element_type=jnp.float32)
    s_all = (s_nope + s_rope) * scale
    s_all = jnp.where(mask[:, None], s_all, -jnp.inf)
    pr = jax.nn.softmax(s_all, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pr, lat_all.astype(jnp.float32))
    wv = p["wv_b"].reshape(r_kv, H, dv)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), wv)

    o = qmm(out.reshape(B, S, H * dv), p["wo"])
    if tp_axis:
        o = lax.psum(o, tp_axis)
    return o, {"latent": lp}


def _full_attention(q, k, v):
    """Bidirectional full attention (whisper encoder / cross-attn)."""
    n_rep = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
#  MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# --------------------------------------------------------------------------- #

def init_mla(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "wq_a": jax.random.normal(ks[0], (d, r_q), dtype) * s,
        "q_norm": jnp.ones((r_q,), dtype),
        "wq_b": jax.random.normal(ks[1], (r_q, H * (dn + dr)), dtype)
        / math.sqrt(r_q),
        "wkv_a": jax.random.normal(ks[2], (d, r_kv + dr), dtype) * s,
        "kv_norm": jnp.ones((r_kv,), dtype),
        "wk_b": jax.random.normal(ks[3], (r_kv, H * dn), dtype)
        / math.sqrt(r_kv),
        "wv_b": jax.random.normal(ks[4], (r_kv, H * dv), dtype)
        / math.sqrt(r_kv),
        "wo": jax.random.normal(ks[5], (H * dv, d), dtype)
        / math.sqrt(H * dv),
    }


def mla_block(p: Params, cfg: ModelConfig, x: jnp.ndarray, positions,
              *, cache: Optional[Dict] = None, decode: bool = False,
              tp_axis: Optional[str] = None,
              absorbed: bool = True) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """MLA attention. Cache holds the compressed latent (r_kv + rope dims).

    Decode uses the *absorbed* form by default (W_UK folded into the query,
    scores computed in latent space) — the serving-side optimization that
    keeps per-step FLOPs proportional to r_kv instead of H*(dn+dv).
    ``absorbed=False`` decodes via naive latent expansion (the paper-free
    baseline used in EXPERIMENTS §Perf).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    r_kv = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_lat = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]                                  # (B, S, r_kv + dr)
    latent = rms_norm(kv[..., :r_kv], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., r_kv:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]          # (B, S, dr)
    lat_cat = jnp.concatenate([latent, k_rope], -1)       # cache line

    new_cache = cache
    if decode:
        assert cache is not None
        lc, ln = cache["latent"], cache["len"]
        Smax = lc.shape[1]
        for t in range(S):                   # static, small (draft block)
            slot = jnp.minimum(ln + t, Smax - 1)
            lc = jax.vmap(lambda c, tt, i: lax.dynamic_update_slice(
                c, tt, (i, 0)))(lc, lat_cat[:, t:t + 1].astype(lc.dtype),
                                slot)
        new_cache = {"latent": lc, "len": ln + S}
        lat_all = lc[..., :r_kv].astype(x.dtype)          # (B, Smax, r)
        rope_all = lc[..., r_kv:].astype(x.dtype)         # (B, Smax, dr)
        # query t sits at absolute position ln + t; causal among drafts
        pos_idx = jnp.arange(Smax)
        qpos = ln[:, None] + jnp.arange(S)[None, :]       # (B, S)
        mask = pos_idx[None, None, :] <= qpos[:, :, None]  # (B, S, Smax)
        if absorbed:
            # fold W_UK: q_lat[h] = q_nope[h] @ wk_b[:, h]^T  -> (B,1,H,r)
            wk = p["wk_b"].reshape(r_kv, H, dn)
            q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wk)
            s_nope = jnp.einsum("bqhr,bsr->bhqs", q_abs, lat_all,
                                preferred_element_type=jnp.float32)
            s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, rope_all,
                                preferred_element_type=jnp.float32)
            s_all = (s_nope + s_rope) * scale
            s_all = jnp.where(mask[:, None], s_all, -jnp.inf)
            pr = jax.nn.softmax(s_all, axis=-1)
            # output in latent space, then expand with W_UV
            o_lat = jnp.einsum("bhqs,bsr->bqhr", pr, lat_all.astype(
                jnp.float32))
            wv = p["wv_b"].reshape(r_kv, H, dv)
            out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), wv)
        else:
            k_nope = jnp.einsum("bsr,rhd->bshd", lat_all,
                                p["wk_b"].reshape(r_kv, H, dn))
            vv = jnp.einsum("bsr,rhv->bshv", lat_all,
                            p["wv_b"].reshape(r_kv, H, dv))
            kk = jnp.concatenate(
                [k_nope, jnp.broadcast_to(rope_all[:, :, None, :],
                                          (*k_nope.shape[:3], dr))], -1)
            qq = jnp.concatenate([q_nope, q_rope], -1)
            s_all = jnp.einsum("bqhd,bshd->bhqs", qq, kk,
                               preferred_element_type=jnp.float32) * scale
            s_all = jnp.where(mask[:, None], s_all, -jnp.inf)
            pr = jax.nn.softmax(s_all, axis=-1)
            out = jnp.einsum("bhqs,bshv->bqhv", pr, vv.astype(jnp.float32)
                             ).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bsr,rhd->bshd", latent,
                            p["wk_b"].reshape(r_kv, H, dn))
        vv = jnp.einsum("bsr,rhv->bshv", latent,
                        p["wv_b"].reshape(r_kv, H, dv))
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], dr))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        # pad V up to qk head dim so the flash oracle can run, slice after
        pad = (dn + dr) - dv
        v_p = jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad else vv
        out = chunked_causal_attention(qq, kk, v_p)[..., :dv]
        if cache is not None:
            Smax = cache["latent"].shape[1]
            lc = lat_cat[:, :Smax]
            if lc.shape[1] < Smax:
                lc = jnp.pad(lc, ((0, 0), (0, Smax - lc.shape[1]), (0, 0)))
            new_cache = {"latent": lc.astype(cache["latent"].dtype),
                         "len": cache["len"] + S}
    o = qmm(out.reshape(B, S, H * dv), p["wo"])
    if tp_axis:
        o = lax.psum(o, tp_axis)
    return o, new_cache


# --------------------------------------------------------------------------- #
#  FFN: gated GLU and MoE top-k with capacity dispatch
# --------------------------------------------------------------------------- #

def init_glu(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None
             ) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, f), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(k2, (d, f), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(k3, (f, d), dtype) / math.sqrt(f),
    }


def glu_ffn(p: Params, x: jnp.ndarray, tp_axis: Optional[str] = None
            ) -> jnp.ndarray:
    h = swish(qmm(x, p["w_gate"])) * qmm(x, p["w_up"])
    out = qmm(h, p["w_down"])
    if tp_axis:
        out = lax.psum(out, tp_axis)
    return out


def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k1, (d, E), dtype) / math.sqrt(d),
        "w_gate": jax.random.normal(k2, (E, d, f), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(k3, (E, d, f), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(k4, (E, f, d), dtype) / math.sqrt(f),
    }


def moe_ffn(p: Params, cfg: ModelConfig, x: jnp.ndarray,
            *, lossless: bool = False,
            tp_axis: Optional[str] = None) -> jnp.ndarray:
    """Top-k MoE with capacity-bounded sort-free dispatch.

    Tokens are scattered into per-expert capacity buckets (overflow
    dropped, standard practice), experts run as one batched matmul over
    (E, C, d), and outputs gather back weighted by router gates. FLOPs are
    ~ top_k * T * (3 d f) * capacity_factor — proportional to *active*
    parameters, not total (no dense-dispatch waste).

    ``lossless`` (or ``cfg.moe_capacity_factor is None``) sets capacity to
    T — an exact upper bound (a token contributes each expert at most
    once), so no token is ever dropped. Decode always runs lossless: T = B
    is small, and the extra dispatch rows are negligible next to streaming
    the expert weights.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T_full = B * S
    # chunk the dispatch: the (E, C, d) capacity buffer scales with the
    # chunk, not the step — at 1M-token prefill an unchunked buffer costs
    # ~21 GiB/chip (found via dry-run memory_analysis). Per-chunk capacity
    # is standard practice and preserves losslessness when C = T_chunk.
    MAX_CHUNK = 65_536
    n_chunks = max(-(-T_full // MAX_CHUNK), 1)
    if S % n_chunks == 0 and n_chunks > 1:
        xs = x.reshape(B, n_chunks, S // n_chunks, d).transpose(1, 0, 2, 3)
        out = lax.map(
            lambda xc: moe_ffn(p, cfg, xc, lossless=lossless,
                               tp_axis=tp_axis), xs)
        return out.transpose(1, 0, 2, 3).reshape(B, S, d)
    T = T_full
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)         # (T, E)
    gates, idx = lax.top_k(jax.nn.softmax(logits, -1), K)   # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cf = cfg.moe_capacity_factor
    if lossless or cf is None:
        C = T
    else:
        C = min(max(int(K * T / E * cf), 1), T)
    constrain = _MOE_CONSTRAINT or (lambda t: t)
    flat_e = idx.reshape(-1)                                # (T*K,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (T*K, E)
    pos_in_e = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1    # (T*K,)
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)    # drop -> pad row

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    x_rep = jnp.repeat(xt, K, axis=0)                       # (T*K, d)
    buf = buf.at[slot].set(x_rep)
    xe = constrain(buf[:E * C].reshape(E, C, d))

    h = constrain(
        swish(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    ye = constrain(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))
    if tp_axis:
        ye = lax.psum(ye, tp_axis)

    ye_flat = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), ye.dtype)], 0)
    y_tok = ye_flat[slot]                                    # (T*K, d)
    y = (y_tok.reshape(T, K, d)
         * gates.astype(y_tok.dtype)[..., None]).sum(1)
    return y.reshape(B, S, d)


# --------------------------------------------------------------------------- #
#  RG-LRU recurrent block (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------- #

def init_rglru(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 4)
    # forget-rate init: a in (~0.9, ~0.999)
    lam = jnp.log(jnp.expm1(
        jnp.linspace(4.0, 9.0, w)))                     # softplus^-1 spread
    return {
        "w_x": jax.random.normal(ks[0], (d, w), dtype) / math.sqrt(d),
        "w_y": jax.random.normal(ks[1], (d, w), dtype) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, w), dtype) * 0.1,
        "gate_i": jnp.zeros((w,), dtype),
        "gate_r": jnp.zeros((w,), dtype),
        "lambda": lam.astype(dtype),
        "w_out": jax.random.normal(ks[3], (w, d), dtype) / math.sqrt(w),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B, S, C), w: (K, C).

    Returns (y, new_state) where state is the trailing K-1 inputs.
    """
    K = w.shape[0]
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_state


def rglru_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                *, cache: Optional[Dict] = None, decode: bool = False,
                tp_axis: Optional[str] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Griffin recurrent block: conv + RG-LRU gated linear recurrence.

    cache: {"h": (B, w) recurrent state, "conv": (B, K-1, w)}.
    """
    B, S, d = x.shape
    w_dim = (cfg.lru_width or d)
    branch_y = swish(x @ p["w_y"])                          # gating branch
    u = x @ p["w_x"]
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv1d(u, p["conv_w"], conv_state)

    # RG-LRU
    c = 8.0
    i_gate = jax.nn.sigmoid(u * p["gate_i"])
    r_gate = jax.nn.sigmoid(u * p["gate_r"])
    log_a = -c * r_gate * jax.nn.softplus(p["lambda"])       # (B, S, w) <= 0
    a = jnp.exp(log_a)
    gated_x = u * i_gate
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated_x

    h0 = cache["h"] if cache is not None else jnp.zeros((B, w_dim), x.dtype)
    if decode:
        assert S == 1
        h = a[:, 0] * h0.astype(a.dtype) + b[:, 0]
        y_seq = h[:, None]
    else:
        # associative scan: h_t = a_t h_{t-1} + b_t, with h_{-1} = h0
        def comb(l, r):
            al, bl = l
            ar, br = r
            return (al * ar, bl * ar + br)
        a_s, b_s = jax.lax.associative_scan(comb, (a, b), axis=1)
        y_seq = a_s * h0[:, None].astype(a.dtype) + b_s
        h = y_seq[:, -1]
    out = (y_seq.astype(x.dtype) * branch_y) @ p["w_out"]
    if tp_axis:
        out = lax.psum(out, tp_axis)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h.astype(cache["h"].dtype), "conv": new_conv}
    return out, new_cache


# --------------------------------------------------------------------------- #
#  Mamba-2 SSD block
# --------------------------------------------------------------------------- #

def init_ssd(cfg: ModelConfig, key, dtype) -> Params:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    P = cfg.ssm_head_dim
    nh = di // P
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * N + nh), dtype) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, di + 2 * N),
                                    dtype) * 0.1,
        "dt_bias": jnp.zeros((nh,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "norm": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[3], (di, d), dtype) / math.sqrt(di),
    }


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bmat: jnp.ndarray, Cmat: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None,
                chunk: int = 128
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """State-space-duality chunked scan (Mamba-2 alg. 1), pure jnp.

    x: (B, S, nh, P); dt: (B, S, nh); A: (nh,) < 0;
    Bmat/Cmat: (B, S, N); h0: (B, nh, P, N).
    Returns (y (B,S,nh,P), h_final).
    This function is also the oracle for the Pallas ``ssd_scan`` kernel.
    """
    Bsz, S, nh, P = x.shape
    N = Bmat.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = nc * chunk
    dA = dt * A[None, None, :]                                # (B, Sp, nh) <=0
    xr = x.reshape(Bsz, nc, chunk, nh, P)
    dtr = dt.reshape(Bsz, nc, chunk, nh)
    dAr = dA.reshape(Bsz, nc, chunk, nh)
    Br = Bmat.reshape(Bsz, nc, chunk, N)
    Cr = Cmat.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(dAr, axis=2)                             # within chunk
    seg_total = cum[:, :, -1]                                 # (B, nc, nh)

    # --- intra-chunk (quadratic attention-like) --------------------------
    # L[t, s] = exp(cum[t] - cum[s]) for t >= s. Clamp the masked (t < s)
    # entries BEFORE exp: exp(+big) -> inf makes the where() gradient NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,t,s,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
    Lmat = jnp.exp(diff)
    GB = jnp.einsum("bcsn,bcsh,bcshp->bcshpn", Br, dtr, xr)   # dt-weighted
    scores = jnp.einsum("bctn,bcsn->bcts", Cr, Br)            # (B,nc,t,s)
    y_intra = jnp.einsum("bcts,bctsh,bcsh,bcshp->bcthp",
                         scores, Lmat, dtr, xr)

    # --- inter-chunk state recurrence -------------------------------------
    # chunk state: sum_s exp(cum_end - cum_s) dt_s x_s B_s^T
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)    # (B,nc,s,nh)
    chunk_state = jnp.einsum("bcsh,bcsh,bcshp,bcsn->bchpn",
                             decay_to_end, dtr, xr, Br)       # (B,nc,nh,P,N)

    def scan_fn(h, inp):
        st, tot = inp                                         # (B,nh,P,N),(B,nh)
        h_new = h * jnp.exp(tot)[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, P, N), x.dtype)
    h_fin, h_prev = lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (chunk_state.swapaxes(0, 1).astype(jnp.float32),
         seg_total.swapaxes(0, 1).astype(jnp.float32)))
    h_prev = h_prev.swapaxes(0, 1)                            # (B,nc,nh,P,N)

    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp",
                         Cr, jnp.exp(cum), h_prev.astype(cum.dtype))
    y = (y_intra + y_inter).reshape(Bsz, Sp, nh, P)[:, :S]
    return y.astype(x.dtype), h_fin.astype(x.dtype)


def ssd_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              *, cache: Optional[Dict] = None, decode: bool = False,
              tp_axis: Optional[str] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba-2 block: in-proj -> conv -> SSD -> gated norm -> out-proj.

    cache: {"conv": (B, K-1, di+2N), "state": (B, nh, P, N)}.
    """
    B, S, d = x.shape
    di, N, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // P
    zxbcdt = qmm(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv1d(xbc, p["conv_w"], conv_state)
    xbc = swish(xbc)
    xs, Bmat, Cmat = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                  # (nh,)
    xh = xs.reshape(B, S, nh, P)

    h0 = cache["state"] if cache is not None else None
    if decode:
        assert S == 1 and cache is not None
        dA = jnp.exp(dt[:, 0] * A[None])                      # (B, nh)
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32),
                         Bmat[:, 0].astype(jnp.float32))
        h = h0.astype(jnp.float32) * dA[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32), h)
        y = y[:, None].reshape(B, 1, nh, P).astype(x.dtype)
        h_fin = h.astype(x.dtype)
    else:
        y, h_fin = ssd_chunked(xh, dt, A, Bmat, Cmat,
                               h0=None if h0 is None else h0)
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * swish(z), p["norm"], cfg.norm_eps)
    out = qmm(y, p["out_proj"])
    if tp_axis:
        out = lax.psum(out, tp_axis)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": h_fin.astype(cache["state"].dtype)}
    return out, new_cache
