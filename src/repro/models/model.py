"""Model assembly: init / forward / prefill / decode for every family.

All functions are pure and pjit-friendly; the explicit-collective ring
runtime passes ``tp_axis`` through to the layer library.

Parameter layout (scan-compatible — every per-layer leaf is stacked on a
leading layer axis):

  dense/moe/vlm : params["blocks"][leaf] : (L, ...)
  ssm           : params["blocks"][leaf] : (L, ...)
  hybrid        : params["groups"][bi][leaf] : (G, ...), params["tail"] : (T, ...)
  audio         : params["enc_blocks"], params["dec_blocks"] : (L, ...)

Cache layout mirrors the parameter stacking (leading layer axis), with a
single shared ``len`` (B,) counter.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as ll

Params = Dict[str, Any]

#: Optional activation-sharding hook (set by the distributed runtime at
#: trace time). GSPMD otherwise propagates the embedding table's layout
#: into the activations — batch-replicated, d-sharded — which costs
#: hundreds of GB at scale (see EXPERIMENTS §Perf iteration log).
_ACT_CONSTRAINT = None


def set_activation_constraint(fn) -> None:
    global _ACT_CONSTRAINT
    _ACT_CONSTRAINT = fn


def _constrain(x):
    if _ACT_CONSTRAINT is not None and getattr(x, "ndim", 0) == 3:
        return _ACT_CONSTRAINT(x)
    return x


# --------------------------------------------------------------------------- #
#  init
# --------------------------------------------------------------------------- #

def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _init_dense_block(cfg: ModelConfig, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        p = {"attn_norm": jnp.ones((cfg.d_model,), dtype),
             "ffn_norm": jnp.ones((cfg.d_model,), dtype)}
        if cfg.mla:
            p["attn"] = ll.init_mla(cfg, k1, dtype)
        else:
            p["attn"] = ll.init_attn(cfg, k1, dtype)
        if cfg.n_experts:
            p["moe"] = ll.init_moe(cfg, k2, dtype)
        else:
            p["ffn"] = ll.init_glu(cfg, k2, dtype)
        return p
    return init


def _init_rglru_block(cfg: ModelConfig, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"mix_norm": jnp.ones((cfg.d_model,), dtype),
                "ffn_norm": jnp.ones((cfg.d_model,), dtype),
                "rglru": ll.init_rglru(cfg, k1, dtype),
                "ffn": ll.init_glu(cfg, k2, dtype)}
    return init


def _init_ssd_block(cfg: ModelConfig, dtype):
    def init(key):
        return {"norm": jnp.ones((cfg.d_model,), dtype),
                "ssd": ll.init_ssd(cfg, key, dtype)}
    return init


def _init_enc_block(cfg: ModelConfig, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"attn_norm": jnp.ones((cfg.d_model,), dtype),
                "ffn_norm": jnp.ones((cfg.d_model,), dtype),
                "attn": ll.init_attn(cfg, k1, dtype),
                "ffn": ll.init_glu(cfg, k2, dtype)}
    return init


def _init_dec_block(cfg: ModelConfig, dtype):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"attn_norm": jnp.ones((cfg.d_model,), dtype),
                "cross_norm": jnp.ones((cfg.d_model,), dtype),
                "ffn_norm": jnp.ones((cfg.d_model,), dtype),
                "attn": ll.init_attn(cfg, k1, dtype),
                "cross": ll.init_attn(cfg, k2, dtype),
                "ffn": ll.init_glu(cfg, k3, dtype)}
    return init


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, n_tail) for hybrid block_pattern archs."""
    g = len(cfg.block_pattern)
    return cfg.n_layers // g, cfg.n_layers % g


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    params: Params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dtype)
        * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab), dtype) / math.sqrt(cfg.d_model)

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(_init_dense_block(cfg, dtype), ks[2],
                                       cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(_init_ssd_block(cfg, dtype), ks[2],
                                       cfg.n_layers)
    elif cfg.family == "hybrid":
        G, T = hybrid_layout(cfg)
        groups = {}
        for bi, kind in enumerate(cfg.block_pattern):
            init = (_init_rglru_block(cfg, dtype) if kind == "rglru"
                    else _init_dense_block(cfg, dtype))
            groups[f"b{bi}"] = _stack_init(init, ks[3 + bi], G)
        params["groups"] = groups
        if T:
            # tail layers follow the pattern prefix (rglru for r-gemma)
            tail_kind = cfg.block_pattern[0]
            init = (_init_rglru_block(cfg, dtype) if tail_kind == "rglru"
                    else _init_dense_block(cfg, dtype))
            params["tail"] = _stack_init(init, ks[6], T)
    elif cfg.family == "audio":
        params["enc_blocks"] = _stack_init(_init_enc_block(cfg, dtype),
                                           ks[2], cfg.n_enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["dec_blocks"] = _stack_init(_init_dec_block(cfg, dtype),
                                           ks[3], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


# --------------------------------------------------------------------------- #
#  caches
# --------------------------------------------------------------------------- #

def _kv_cache(cfg: ModelConfig, n: int, B: int, S: int, dtype):
    hk, hd = max(cfg.kv_heads, 1), cfg.head_dim
    if cfg.attn_window:
        S = min(S, cfg.attn_window)
    if cfg.kv_dtype == "int8":
        return {"k": jnp.zeros((n, B, S, hk, hd), jnp.int8),
                "v": jnp.zeros((n, B, S, hk, hd), jnp.int8),
                "k_scale": jnp.zeros((n, B, S, hk), jnp.bfloat16),
                "v_scale": jnp.zeros((n, B, S, hk), jnp.bfloat16)}
    return {"k": jnp.zeros((n, B, S, hk, hd), dtype),
            "v": jnp.zeros((n, B, S, hk, hd), dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    B, L = batch, cfg.n_layers
    cache: Dict[str, Any] = {"len": jnp.zeros((B,), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.mla:
            cache["layers"] = {"latent": jnp.zeros(
                (L, B, max_len, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype)}
        else:
            cache["layers"] = _kv_cache(cfg, L, B, max_len, dtype)
    elif cfg.family == "ssm":
        di, N, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
        nh = di // P
        cache["layers"] = {
            "conv": jnp.zeros((L, B, cfg.conv_width - 1, di + 2 * N), dtype),
            "state": jnp.zeros((L, B, nh, P, N), dtype)}
    elif cfg.family == "hybrid":
        G, T = hybrid_layout(cfg)
        w = cfg.lru_width or cfg.d_model
        groups = {}
        for bi, kind in enumerate(cfg.block_pattern):
            if kind == "rglru":
                groups[f"b{bi}"] = {
                    "h": jnp.zeros((G, B, w), dtype),
                    "conv": jnp.zeros((G, B, cfg.conv_width - 1, w), dtype)}
            else:
                groups[f"b{bi}"] = _kv_cache(cfg, G, B, max_len, dtype)
        cache["groups"] = groups
        if T:
            cache["tail"] = {
                "h": jnp.zeros((T, B, w), dtype),
                "conv": jnp.zeros((T, B, cfg.conv_width - 1, w), dtype)}
    elif cfg.family == "audio":
        S = min(max_len, cfg.max_decode_len or max_len)
        cache["layers"] = _kv_cache(cfg, L, B, S, dtype)
        hk, hd = cfg.kv_heads, cfg.head_dim
        F = cfg.n_frontend_tokens
        cache["cross_k"] = jnp.zeros((L, B, F, hk, hd), dtype)
        cache["cross_v"] = jnp.zeros((L, B, F, hk, hd), dtype)
    return cache


# --------------------------------------------------------------------------- #
#  block application
# --------------------------------------------------------------------------- #

def _dense_block(cfg: ModelConfig, p, x, positions, cache, ln, *,
                 decode: bool, tp_axis: Optional[str]):
    h_in = ll.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    c = None if cache is None else {**cache, "len": ln}
    if cfg.mla:
        h, nc = ll.mla_block(p["attn"], cfg, h_in, positions, cache=c,
                             decode=decode, tp_axis=tp_axis)
    else:
        h, nc = ll.attn_block(p["attn"], cfg, h_in, positions, cache=c,
                              decode=decode, tp_axis=tp_axis)
    x = x + h
    g = ll.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.n_experts:
        x = x + ll.moe_ffn(p["moe"], cfg, g, lossless=decode,
                           tp_axis=tp_axis)
    else:
        x = x + ll.glu_ffn(p["ffn"], g, tp_axis)
    if nc is not None:
        nc.pop("len", None)
    return x, nc


def _rglru_full_block(cfg: ModelConfig, p, x, cache, *, decode: bool,
                      tp_axis: Optional[str]):
    h_in = ll.rms_norm(x, p["mix_norm"], cfg.norm_eps)
    h, nc = ll.rglru_block(p["rglru"], cfg, h_in, cache=cache,
                           decode=decode, tp_axis=tp_axis)
    x = x + h
    g = ll.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    x = x + ll.glu_ffn(p["ffn"], g, tp_axis)
    return x, nc


def _ssd_full_block(cfg: ModelConfig, p, x, cache, *, decode: bool,
                    tp_axis: Optional[str]):
    h_in = ll.rms_norm(x, p["norm"], cfg.norm_eps)
    h, nc = ll.ssd_block(p["ssd"], cfg, h_in, cache=cache, decode=decode,
                         tp_axis=tp_axis)
    return x + h, nc


def _scan_stack(body, x, blocks, caches, *, remat: bool = False):
    """Scan ``body(x, p, c) -> (x, nc)`` over stacked layers."""
    def scan_body(carry, inp):
        p, c = inp
        y, nc = body(carry, p, c)
        return _constrain(y), nc

    if remat:
        scan_body = jax.checkpoint(scan_body)
    x, new_caches = lax.scan(scan_body, x, (blocks, caches))
    return x, new_caches


def _none_like(tree):
    return None


# --------------------------------------------------------------------------- #
#  embeddings / positions
# --------------------------------------------------------------------------- #

def embed_tokens(params: Params, cfg: ModelConfig, tokens: jnp.ndarray
                 ) -> jnp.ndarray:
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["embed"].T


def default_positions(cfg: ModelConfig, B: int, S: int, offset=0):
    base = jnp.arange(S, dtype=jnp.int32)[None, :]       # (1, S)
    if hasattr(offset, "shape") and getattr(offset, "ndim", 0) == 1:
        pos = offset[:, None] + base                      # (B, S)
    else:
        pos = jnp.broadcast_to(base + offset, (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def sinusoid_positions(S: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


# --------------------------------------------------------------------------- #
#  forward paths
# --------------------------------------------------------------------------- #

def _backbone(params: Params, cfg: ModelConfig, x, positions, cache, *,
              decode: bool, tp_axis: Optional[str], remat: bool):
    """Run the layer stack; returns (hidden, new_cache)."""
    ln = None if cache is None else cache["len"]
    new_cache = None if cache is None else dict(cache)

    if cfg.family in ("dense", "moe", "vlm", "ssm"):
        if cfg.family == "ssm":
            def body(h, p, c):
                return _ssd_full_block(cfg, p, h, c, decode=decode,
                                       tp_axis=tp_axis)
        else:
            def body(h, p, c):
                return _dense_block(cfg, p, h, positions, c, ln,
                                    decode=decode, tp_axis=tp_axis)
        caches = None if cache is None else cache["layers"]
        if caches is None:
            x, _ = _scan_stack(lambda h, p, c: body(h, p, None), x,
                               params["blocks"],
                               jax.tree.map(lambda a: a[:, :0],
                                            params["blocks"]),
                               remat=remat)
        else:
            x, nc = _scan_stack(body, x, params["blocks"], caches,
                                remat=remat)
            new_cache["layers"] = nc
    elif cfg.family == "hybrid":
        G, T = hybrid_layout(cfg)

        def group_body(h, p, c):
            ncs = {}
            for bi, kind in enumerate(cfg.block_pattern):
                key = f"b{bi}"
                ci = None if c is None else c[key]
                if kind == "rglru":
                    h, nci = _rglru_full_block(cfg, p[key], h, ci,
                                               decode=decode,
                                               tp_axis=tp_axis)
                else:
                    h, nci = _dense_block(cfg, p[key], h, positions, ci, ln,
                                          decode=decode, tp_axis=tp_axis)
                ncs[key] = nci
            return h, ncs

        caches = None if cache is None else cache["groups"]
        if caches is None:
            x, _ = _scan_stack(
                lambda h, p, c: (group_body(h, p, None)[0], 0.0), x,
                params["groups"],
                jax.tree.map(lambda a: a[:, :0], params["groups"]),
                remat=remat)
        else:
            x, nc = _scan_stack(group_body, x, params["groups"], caches,
                                remat=remat)
            new_cache["groups"] = nc
        if T:
            tail_kind = cfg.block_pattern[0]

            def tail_body(h, p, c):
                if tail_kind == "rglru":
                    return _rglru_full_block(cfg, p, h, c, decode=decode,
                                             tp_axis=tp_axis)
                return _dense_block(cfg, p, h, positions, c, ln,
                                    decode=decode, tp_axis=tp_axis)

            tcaches = None if cache is None else cache["tail"]
            if tcaches is None:
                x, _ = _scan_stack(
                    lambda h, p, c: (tail_body(h, p, None)[0], 0.0), x,
                    params["tail"],
                    jax.tree.map(lambda a: a[:, :0], params["tail"]),
                    remat=remat)
            else:
                x, nc = _scan_stack(tail_body, x, params["tail"], tcaches,
                                    remat=remat)
                new_cache["tail"] = nc
    else:
        raise ValueError(cfg.family)

    if new_cache is not None:
        new_cache["len"] = ln + x.shape[1]
    return x, new_cache


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            tp_axis: Optional[str] = None,
            remat: bool = False) -> jnp.ndarray:
    """Full-sequence logits (training). ``embeds``: frontend embeddings
    prepended to the token embeddings (VLM patch / audio frame stubs)."""
    if cfg.family == "audio":
        return whisper_forward(params, cfg, tokens, embeds, tp_axis=tp_axis)
    x = embed_tokens(params, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = _constrain(x)
    B, S, _ = x.shape
    if positions is None:
        positions = default_positions(cfg, B, S)
    x, _ = _backbone(params, cfg, x, positions, None, decode=False,
                     tp_axis=tp_axis, remat=remat)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x)


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Dict, *, embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            tp_axis: Optional[str] = None,
            remat: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Process the prompt, fill the cache, return last-position logits."""
    if cfg.family == "audio":
        return whisper_prefill(params, cfg, tokens, embeds, cache,
                               tp_axis=tp_axis)
    x = embed_tokens(params, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = _constrain(x)
    B, S, _ = x.shape
    if positions is None:
        positions = default_positions(cfg, B, S)
    x, new_cache = _backbone(params, cfg, x, positions, cache, decode=False,
                             tp_axis=tp_axis, remat=remat)
    x = ll.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens: jnp.ndarray, *,
                tp_axis: Optional[str] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. tokens: (B, T).

    T = 1 is ordinary autoregressive decode. T > 1 is the speculative
    *verify* path: the T tokens (last accepted token followed by T-1 draft
    tokens) are scored in one pass with causal masking among them; the
    cache advances by T and the caller rolls rejected positions back with
    ``rollback_cache``. Only KV-cache families support T > 1 — recurrent
    state (ssm / hybrid) cannot roll back.
    """
    B, T = tokens.shape
    if T > 1 and cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"multi-token decode unsupported for {cfg.family}")
    if cfg.family == "audio":
        return whisper_decode_step(params, cfg, cache, tokens,
                                   tp_axis=tp_axis)
    x = embed_tokens(params, cfg, tokens)
    pos = cache["len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    x, new_cache = _backbone(params, cfg, x, pos, cache, decode=True,
                             tp_axis=tp_axis, remat=False)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


# --------------------------------------------------------------------------- #
#  layer-wise paths (weight streaming)
# --------------------------------------------------------------------------- #
#
# The scan paths above close over the full stacked parameter pytree — all
# L layers resident. The layer-wise paths pull each layer's weights from a
# ``runtime.paramstore.ParamSource`` right before applying it, which is
# what lets the streaming runtime keep only a window of layers in memory
# (prefetch ahead of the front, release behind it). The math is the exact
# per-layer sequence the scan performs, so resident and streamed decode
# agree to numerical tolerance.
#
# Quantized stores (v2 manifests persisting packed int4/int2 +
# group-scale leaves) keep their matmul weights PACKED here: eligible 2-D
# q4 leaves flow into ``layers.qmm``, which dispatches the fused
# ``kernels.ops.q4_matmul`` (dequant-in-kernel, tile-by-tile in VMEM) —
# only the packed bytes ever cross disk -> staging -> device -> compute.
# Ineligible leaves (q2, stacked expert tensors, einsum-consumed MLA
# projections, misaligned dims) dequantize per layer at use; both paths
# accumulate f32, so streamed-quantized logits equal the
# resident-dequantized reference.

def _dequant_params(p: Params) -> Params:
    """Dequantize any QuantizedTensor leaves pulled from a ParamSource."""
    from ..quant.grouped import dequantize_tree

    return dequantize_tree(p, jnp.float32)


#: leaf names whose consumers route through ``layers.qmm`` — the only
#: sites where a packed weight may survive into the block functions.
_FUSED_Q4_KEYS = frozenset((
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "in_proj", "out_proj"))


def _prepare_layer_params(p: Params) -> Params:
    """Selective dequantization for the layer-wise (streamed) path.

    Q4 leaves that ``layers.qmm`` can feed to the fused kernel stay
    packed; everything else dequantizes as before.
    """
    from ..quant.grouped import QuantizedTensor, dequantize_leaf
    from .layers import q4_fused_eligible

    def is_qt(x):
        return isinstance(x, QuantizedTensor)

    pairs, treedef = jax.tree_util.tree_flatten_with_path(p, is_leaf=is_qt)
    out = []
    for path, leaf in pairs:
        if is_qt(leaf):
            name = getattr(path[-1], "key", None)
            if name in _FUSED_Q4_KEYS and q4_fused_eligible(leaf):
                out.append(leaf)
                continue
            leaf = dequantize_leaf(leaf, jnp.float32)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _layerwise_backbone(source, cfg: ModelConfig, x, positions, cache, *,
                        decode: bool, tp_axis: Optional[str]):
    """Run the stack one layer at a time, weights pulled from ``source``."""
    if cfg.family not in ("dense", "moe", "vlm", "ssm"):
        raise ValueError(
            f"layer-wise streaming unsupported for family {cfg.family}")
    ln = None if cache is None else cache["len"]
    layers_c = None if cache is None else cache["layers"]
    new_layers = layers_c
    for i in range(cfg.n_layers):
        p = _prepare_layer_params(source.layer(i))
        c_i = None if layers_c is None else jax.tree.map(
            lambda a: a[i], layers_c)
        if cfg.family == "ssm":
            x, nc = _ssd_full_block(cfg, p, x, c_i, decode=decode,
                                    tp_axis=tp_axis)
        else:
            x, nc = _dense_block(cfg, p, x, positions, c_i, ln,
                                 decode=decode, tp_axis=tp_axis)
        x = _constrain(x)
        if nc is not None:
            new_layers = jax.tree.map(
                lambda full, n: full.at[i].set(n), new_layers, nc)
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["len"] = ln + x.shape[1]
    return x, new_cache


def forward_layerwise(source, cfg: ModelConfig, tokens: jnp.ndarray, *,
                      embeds: Optional[jnp.ndarray] = None,
                      positions: Optional[jnp.ndarray] = None,
                      tp_axis: Optional[str] = None) -> jnp.ndarray:
    """``forward`` with weights pulled from a ParamSource."""
    head = _dequant_params(source.head())
    x = embed_tokens(head, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = _constrain(x)
    B, S, _ = x.shape
    if positions is None:
        positions = default_positions(cfg, B, S)
    x, _ = _layerwise_backbone(source, cfg, x, positions, None,
                               decode=False, tp_axis=tp_axis)
    x = ll.rms_norm(x, head["final_norm"], cfg.norm_eps)
    return unembed(head, cfg, x)


def prefill_layerwise(source, cfg: ModelConfig, tokens: jnp.ndarray,
                      cache: Dict, *,
                      embeds: Optional[jnp.ndarray] = None,
                      positions: Optional[jnp.ndarray] = None,
                      tp_axis: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, Dict]:
    """``prefill`` with weights pulled from a ParamSource."""
    head = _dequant_params(source.head())
    x = embed_tokens(head, cfg, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = _constrain(x)
    B, S, _ = x.shape
    if positions is None:
        positions = default_positions(cfg, B, S)
    x, new_cache = _layerwise_backbone(source, cfg, x, positions, cache,
                                       decode=False, tp_axis=tp_axis)
    x = ll.rms_norm(x[:, -1:], head["final_norm"], cfg.norm_eps)
    return unembed(head, cfg, x), new_cache


def decode_step_layerwise(source, cfg: ModelConfig, cache: Dict,
                          tokens: jnp.ndarray, *,
                          tp_axis: Optional[str] = None
                          ) -> Tuple[jnp.ndarray, Dict]:
    """``decode_step`` with weights pulled from a ParamSource.

    Supports the same T > 1 speculative verify semantics as
    ``decode_step`` — a streamed verify pass reads each layer from disk
    once for the whole draft block, which is the amortization the
    acceptance-aware latency model prices.
    """
    B, T = tokens.shape
    if T > 1 and cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"multi-token decode unsupported for {cfg.family}")
    head = _dequant_params(source.head())
    x = embed_tokens(head, cfg, tokens)
    pos = cache["len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    x, new_cache = _layerwise_backbone(source, cfg, x, pos, cache,
                                       decode=True, tp_axis=tp_axis)
    x = ll.rms_norm(x, head["final_norm"], cfg.norm_eps)
    return unembed(head, cfg, x), new_cache


# --------------------------------------------------------------------------- #
#  paged KV-cache paths (block-pool cache, runtime.kvcache)
# --------------------------------------------------------------------------- #
#
# The dense cache above preallocates (L, B, max_len, ...); the paged cache
# holds a global pool of fixed-size token pages plus a per-slot block
# table (see runtime/kvcache.py for allocation, prefix sharing and
# offload). These paths write new cache lines through the table and
# attend over gathered pages — the per-position math is identical to the
# dense decode path, so paged greedy decode is byte-identical to dense.

def _paged_backbone(params: Params, cfg: ModelConfig, x, positions, cache,
                    *, tp_axis: Optional[str], prefill: bool = False,
                    write: bool = True):
    ln = cache["len"]
    table = cache["block_table"]

    def body(h, p, pg):
        h_in = ll.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        if cfg.mla:
            a, npg = ll.mla_block_paged(p["attn"], cfg, h_in, positions,
                                        pg, table, ln, tp_axis=tp_axis,
                                        prefill=prefill, write=write)
        else:
            a, npg = ll.attn_block_paged(p["attn"], cfg, h_in, positions,
                                         pg, table, ln, tp_axis=tp_axis,
                                         prefill=prefill, write=write)
        h = h + a
        g = ll.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
        if cfg.n_experts:
            h = h + ll.moe_ffn(p["moe"], cfg, g, lossless=True,
                               tp_axis=tp_axis)
        else:
            h = h + ll.glu_ffn(p["ffn"], g, tp_axis)
        return h, npg

    x, new_pages = _scan_stack(body, x, params["blocks"], cache["pages"])
    new_cache = dict(cache)
    new_cache["pages"] = new_pages
    new_cache["len"] = ln + x.shape[1]
    return x, new_cache


def decode_step_paged(params: Params, cfg: ModelConfig, cache: Dict,
                      tokens: jnp.ndarray, *,
                      tp_axis: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, Dict]:
    """``decode_step`` against a paged KV cache. tokens: (B, T).

    cache: {"pages": {leaf: (L, P, bs, ...)}, "block_table": (B, nb),
    "len": (B,)} as built by ``runtime.kvcache.PagedKVCache``. T > 1 is
    the speculative verify path; rollback is ``rollback_cache`` on the
    device side plus ``PagedKVCache.trim_to`` on the allocator (pages
    past the accepted length return to the pool — the paged analogue of
    "entries past ``len`` are never attended").
    """
    B, T = tokens.shape
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"paged decode unsupported for {cfg.family}")
    x = embed_tokens(params, cfg, tokens)
    pos = cache["len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    x, new_cache = _paged_backbone(params, cfg, x, pos, cache,
                                   tp_axis=tp_axis)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


def prefill_chunk_paged(params: Params, cfg: ModelConfig, cache: Dict,
                        tokens: jnp.ndarray, *,
                        tp_axis: Optional[str] = None,
                        write: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """One chunk of a chunked (paged) prefill. tokens: (B, S).

    ``cache`` is a per-slot view ({"pages", "block_table", "len"}) whose
    ``len`` counts the prompt positions already materialized in pages
    (shared prefix + earlier chunks); the chunk's KV is written directly
    through the block table and attention runs with the dense-prefill
    math (``chunked_causal_attention``), so running a prompt chunk by
    chunk produces byte-identical activations — and first token — to
    one-shot dense prefill. Returns full (B, S, V) logits (the caller
    argmaxes the last position of the last chunk) and the updated view.

    ``write=False`` re-derives logits without touching pages — used when
    the whole prompt was a prefix-cache hit and the final positions'
    KV already exists in shared pages that must not be rewritten.
    """
    B, T = tokens.shape
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"paged prefill unsupported for {cfg.family}")
    x = embed_tokens(params, cfg, tokens)
    pos = cache["len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    x, new_cache = _paged_backbone(params, cfg, x, pos, cache,
                                   tp_axis=tp_axis, prefill=True,
                                   write=write)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


def rollback_cache(cache: Dict, new_len: jnp.ndarray) -> Dict:
    """Roll rejected speculative positions out of a KV cache.

    Entries past ``len`` are never attended (position-masked) and the next
    decode writes at slot ``len``, so discarding rejected draft tokens is
    just resetting the per-sequence counter. Not valid for recurrent-state
    families (ssm / hybrid), whose state updates are irreversible.
    """
    out = dict(cache)
    out["len"] = jnp.asarray(new_len).astype(cache["len"].dtype)
    return out


# --------------------------------------------------------------------------- #
#  whisper (encoder-decoder)
# --------------------------------------------------------------------------- #

def whisper_encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
                   *, tp_axis: Optional[str] = None) -> jnp.ndarray:
    """frames: (B, F, d) precomputed mel-frame embeddings (conv stub)."""
    B, F, d = frames.shape
    x = frames + sinusoid_positions(F, d, frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None],
                                 (B, F))

    def body(h, p, c):
        h_in = ll.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        a, _ = ll.attn_block(p["attn"], cfg, h_in, positions, causal=False,
                             tp_axis=tp_axis)
        h = h + a
        g = ll.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
        return h + ll.glu_ffn(p["ffn"], g, tp_axis), 0.0

    x, _ = _scan_stack(body, x, params["enc_blocks"],
                       jax.tree.map(lambda a: a[:, :0],
                                    params["enc_blocks"]))
    return ll.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p, cfg: ModelConfig, enc_out: jnp.ndarray):
    B, F, _ = enc_out.shape
    hk, hd = cfg.kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, F, hk, hd)
    v = (enc_out @ p["wv"]).reshape(B, F, hk, hd)
    return k, v


def _whisper_dec_block(cfg, p, x, positions, cache, ln, cross_k, cross_v,
                       *, decode, tp_axis):
    h_in = ll.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    c = None if cache is None else {**cache, "len": ln}
    a, nc = ll.attn_block(p["attn"], cfg, h_in, positions, cache=c,
                          decode=decode, tp_axis=tp_axis)
    x = x + a
    h_in = ll.rms_norm(x, p["cross_norm"], cfg.norm_eps)
    a, _ = ll.attn_block(p["cross"], cfg, h_in, positions,
                         cross_kv=(cross_k, cross_v), causal=False,
                         tp_axis=tp_axis)
    x = x + a
    g = ll.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    x = x + ll.glu_ffn(p["ffn"], g, tp_axis)
    if nc is not None:
        nc.pop("len", None)
    return x, nc


def whisper_forward(params: Params, cfg: ModelConfig, tokens, frames,
                    *, tp_axis: Optional[str] = None) -> jnp.ndarray:
    enc_out = whisper_encode(params, cfg, frames, tp_axis=tp_axis)
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    x = x + sinusoid_positions(S, cfg.d_model, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def body(h, p, c):
        ck, cv = _cross_kv(p["cross"], cfg, enc_out)
        return _whisper_dec_block(cfg, p, h, positions, None, None, ck, cv,
                                  decode=False, tp_axis=tp_axis)

    x, _ = _scan_stack(lambda h, p, c: (body(h, p, None)[0], 0.0), x,
                       params["dec_blocks"],
                       jax.tree.map(lambda a: a[:, :0],
                                    params["dec_blocks"]))
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x)


def whisper_prefill(params: Params, cfg: ModelConfig, tokens, frames, cache,
                    *, tp_axis: Optional[str] = None):
    enc_out = whisper_encode(params, cfg, frames, tp_axis=tp_axis)
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    x = x + sinusoid_positions(S, cfg.d_model, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    ln = cache["len"]

    def body(h, p, c):
        ck, cv = _cross_kv(p["cross"], cfg, enc_out)
        h, nc = _whisper_dec_block(cfg, p, h, positions, c, ln, ck, cv,
                                   decode=False, tp_axis=tp_axis)
        nc["cross_k"] = ck.astype(h.dtype)
        nc["cross_v"] = cv.astype(h.dtype)
        return h, nc

    x, nc = _scan_stack(body, x, params["dec_blocks"], cache["layers"])
    new_cache = dict(cache)
    new_cache["cross_k"] = nc.pop("cross_k")
    new_cache["cross_v"] = nc.pop("cross_v")
    new_cache["layers"] = nc
    new_cache["len"] = ln + S
    x = ll.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


def whisper_decode_step(params: Params, cfg: ModelConfig, cache, tokens,
                        *, tp_axis: Optional[str] = None):
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens)
    ln = cache["len"]
    S_tab = cfg.max_decode_len or cache["layers"]["k"].shape[2]
    pos_emb = sinusoid_positions(S_tab, cfg.d_model, x.dtype)
    x = x + jax.vmap(lambda i: pos_emb[jnp.minimum(i, S_tab - 1)])(
        ln)[:, None]
    positions = ln[:, None]

    def body(h, p, c):
        ck = c.pop("cross_k")
        cv = c.pop("cross_v")
        h, nc = _whisper_dec_block(cfg, p, h, positions, c, ln, ck, cv,
                                   decode=True, tp_axis=tp_axis)
        nc["cross_k"] = ck
        nc["cross_v"] = cv
        return h, nc

    caches = dict(cache["layers"])
    caches["cross_k"] = cache["cross_k"]
    caches["cross_v"] = cache["cross_v"]
    x, nc = _scan_stack(body, x, params["dec_blocks"], caches)
    new_cache = dict(cache)
    new_cache["cross_k"] = nc.pop("cross_k")
    new_cache["cross_v"] = nc.pop("cross_v")
    new_cache["layers"] = nc
    new_cache["len"] = ln + 1
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_cache
