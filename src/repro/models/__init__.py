from .model import (decode_step, forward, init_cache, init_params, prefill,
                    whisper_encode)

__all__ = ["decode_step", "forward", "init_cache", "init_params", "prefill",
           "whisper_encode"]
