from .model import (decode_step, forward, init_cache, init_params, prefill,
                    rollback_cache, whisper_encode)

__all__ = ["decode_step", "forward", "init_cache", "init_params", "prefill",
           "rollback_cache", "whisper_encode"]
