from .model import (decode_step, decode_step_layerwise, decode_step_paged,
                    forward, forward_layerwise, init_cache, init_params,
                    prefill, prefill_layerwise, rollback_cache,
                    whisper_encode)

__all__ = ["decode_step", "decode_step_layerwise", "decode_step_paged",
           "forward", "forward_layerwise", "init_cache", "init_params",
           "prefill", "prefill_layerwise", "rollback_cache",
           "whisper_encode"]
