"""Training substrate: FSDP(data) × TP(model) train_step with scan+remat.

The paper's contribution is inference-side; training is the standard
substrate a production framework ships with:

  * cross-entropy LM loss (z-loss optional),
  * gradient accumulation over microbatches (lax.scan) — activation
    memory scales with the microbatch, collectives amortize over the step,
  * AdamW with sharded moments,
  * optional bf16 gradient compression before the cross-pod all-reduce
    (grads are computed in param dtype, cast to bf16 at the accumulation
    boundary, accumulated in f32 — a distributed-optimization trick that
    halves gradient-synchronization bytes across the slow pod axis).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import model as M
from . import sharding as S
from .optim import AdamW, AdamState


def lm_loss(params, cfg: ModelConfig, tokens, labels, *,
            embeds=None, z_loss: float = 1e-4, remat: bool = True):
    """Mean next-token cross entropy. labels = tokens shifted outside."""
    logits = M.forward(params, cfg, tokens, embeds=embeds, remat=remat)
    if embeds is not None and cfg.family != "audio":
        logits = logits[:, embeds.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot reduction, NOT take_along_axis: a gather along the
    # vocab-sharded axis makes GSPMD replicate the full logits
    # (+400 GB/device at 152k vocab — found via dry-run memory_analysis);
    # the masked reduction keeps every op vocab-sharded.
    V = logits.shape[-1]
    onehot = (labels[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2))
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = (logz - gold).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(logz).mean()
    return loss


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *,
                    microbatch: Optional[int] = None,
                    grad_dtype: Optional[str] = "bfloat16",
                    remat: bool = True,
                    has_embeds: bool = False) -> Callable:
    """Build train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatch``: if set, the global (per-step) batch is split into
    microbatches scanned sequentially with f32 gradient accumulation.
    """

    def grads_of(params, tokens, labels, embeds):
        def loss_fn(p):
            return lm_loss(p, cfg, tokens, labels, embeds=embeds,
                           remat=remat)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        if grad_dtype is not None:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(grad_dtype)), grads)
        return loss, grads

    def train_step(params, opt_state: AdamState, batch: Dict):
        tokens = batch["tokens"]
        labels = batch["labels"]
        embeds = batch.get("embeds") if has_embeds else None
        if microbatch is None or tokens.shape[0] <= microbatch:
            loss, grads = grads_of(params, tokens, labels, embeds)
        else:
            B = tokens.shape[0]
            n_micro = B // microbatch
            tk = tokens.reshape(n_micro, microbatch, *tokens.shape[1:])
            lb = labels.reshape(n_micro, microbatch, *labels.shape[1:])
            em = (embeds.reshape(n_micro, microbatch, *embeds.shape[1:])
                  if embeds is not None else None)

            def micro(carry, inp):
                acc, loss_acc = carry
                if em is not None:
                    t, l, e = inp
                else:
                    (t, l), e = inp, None
                loss, grads = grads_of(params, t, l, e)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro,
                    acc, grads)
                return (acc, loss_acc + loss / n_micro), None

            # Seed the accumulator with the first microbatch's gradients so
            # the f32 accumulator inherits the backward pass's sharded
            # layout. (A bare jnp.zeros accumulator gets replicated by
            # GSPMD: +59 GB/device for a 14B model; a params-derived zero
            # forced per-step all-gathers — both found via the dry-run's
            # memory_analysis.)
            loss0, grads0 = grads_of(params, tk[0], lb[0],
                                     em[0] if em is not None else None)
            acc0 = jax.tree.map(
                lambda g: g.astype(jnp.float32) / n_micro, grads0)
            xs = (tk[1:], lb[1:], em[1:]) if em is not None \
                else (tk[1:], lb[1:])
            (grads, loss), _ = lax.scan(micro, (acc0, loss0 / n_micro), xs)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": _tree_norm(grads),
                   "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# --------------------------------------------------------------------------- #
#  jit wiring with explicit shardings (used by launch/train.py and dryrun)
# --------------------------------------------------------------------------- #

def jitted_train_step(cfg: ModelConfig, mesh: Mesh, params_like,
                      optimizer: Optional[AdamW] = None, *,
                      microbatch: Optional[int] = None,
                      has_embeds: bool = False,
                      remat: bool = True,
                      grad_dtype: Optional[str] = "bfloat16",
                      style: str = "fsdp",
                      donate: bool = True):
    """jit(train_step) with in/out shardings bound to ``mesh``.

    ``style``: "fsdp" (ZeRO-3-like weight sharding over data+model) or
    "zero1" (weights TP-only + data-sharded optimizer state — one grad
    reduce-scatter and one param all-gather per step instead of per-layer
    gathers; see EXPERIMENTS §Perf).
    """
    optimizer = optimizer or AdamW()
    step = make_train_step(cfg, optimizer, microbatch=microbatch,
                           grad_dtype=grad_dtype, remat=remat,
                           has_embeds=has_embeds)
    pspec = S.param_shardings(cfg, mesh, params_like, style=style)
    # eval_shape: never materialize moment buffers here (params_like may be
    # ShapeDtypeStructs for dry-run lowering — or 14B real params).
    opt_like = jax.eval_shape(optimizer.init, params_like)
    if style == "zero1":
        mspec = S.zero1_moment_shardings(cfg, mesh, opt_like.mu)
        opt_spec = AdamState(step=S.replicated(mesh), mu=mspec, nu=mspec)
    else:
        opt_spec = AdamState(
            step=S.replicated(mesh),
            mu=S.param_shardings(cfg, mesh, opt_like.mu),
            nu=S.param_shardings(cfg, mesh, opt_like.nu))
    batch_spec = {"tokens": S.data_sharding(mesh, 2),
                  "labels": S.data_sharding(mesh, 2)}
    if has_embeds:
        batch_spec["embeds"] = S.embeds_sharding(mesh)
    metric_spec = {"loss": S.replicated(mesh),
                   "grad_norm": S.replicated(mesh),
                   "step": S.replicated(mesh)}

    # NOTE (§Perf HC1, refuted hypothesis): also pinning the (B,S,H,hd)
    # attention tensors to head-sharding DOUBLES nested collective bytes
    # when H % tp != 0 (GSPMD materializes the 40->48 head padding as
    # explicit reshards every layer) — leave qkv layout to the partitioner.
    act = NamedSharding(mesh, P(S.batch_axes(mesh), None, None))

    def step_constrained(params, opt_state, batch):
        # the hook applies during tracing only (python side effect)
        from ..models import model as Mmod
        Mmod.set_activation_constraint(
            lambda x: jax.lax.with_sharding_constraint(x, act))
        try:
            return step(params, opt_state, batch)
        finally:
            Mmod.set_activation_constraint(None)

    return jax.jit(
        step_constrained,
        in_shardings=(pspec, opt_spec, batch_spec),
        out_shardings=(pspec, opt_spec, metric_spec),
        donate_argnums=(0, 1) if donate else (),
    )
