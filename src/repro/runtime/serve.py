"""Piped-ring serving runtime — the paper's technique on a TPU mesh.

Mapping (DESIGN.md §2): one *ring stage* = one coordinate of the "data"
mesh axis (M stages); inside a stage, the "model" axis is a TP group.
The model's (padded) L layers are split into k*M windows of w layers;
stage m owns windows {r*M + m : r < k} — for k > 1 this is exactly the
interleaved/looping pipeline schedule, which is what prima.cpp's
multi-round ring is on homogeneous hardware.

Decode schedule (one token for the whole batch):
  * the global batch splits into M microbatches; microbatch e enters the
    ring at stage 0 at step e;
  * at step t, stage m computes window j = t - ((t - m) mod M) for
    microbatch e = (t - m) mod M (masked out while j is out of range),
    then ppermutes its activation to stage m+1;
  * after k*M + M - 1 steps every microbatch has traversed all L layers;
    final hiddens are captured at the stage owning the last window and
    psum-broadcast for the (vocab-sharded) logits matmul.

Tensor parallelism inside a stage:
  * FFN / MoE: f (or expert) dimension sharded over "model", psum after
    the down-projection;
  * attention: weights replicated, KV cache *sequence*-sharded over
    "model"; each chip computes partial attention over its KV slice and
    shards merge with a distributed online softmax (works for any
    kv_heads, unlike head sharding);
  * SSM: O(1) state replicated inside the stage (the model is small).

Multi-pod: the "pod" axis is a pure data-parallel replica dimension —
each pod runs its own ring; no cross-pod collectives in serving.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level (with check_vma=)
    from jax import shard_map as _shard_map

    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_MAP_CHECK_KW: check_vma})

from ..configs.base import ModelConfig
from ..models import layers as ll
from ..models import model as M
from . import sharding as S

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
#  ring layout: permutation, padding, shardings
# --------------------------------------------------------------------------- #

def ring_supported(cfg: ModelConfig, batch: int, n_stages: int) -> bool:
    """Ring decode needs a uniform layer stack and >= 1 seq per stage."""
    return (cfg.family in ("dense", "moe", "vlm", "ssm")
            and batch % n_stages == 0)


def padded_layers(L: int, n_stages: int) -> int:
    return -(-L // n_stages) * n_stages


def ring_permutation(L_pad: int, n_stages: int, k: int) -> np.ndarray:
    """perm[i] = global layer index stored at ring-stacked position i.

    Position layout: stage-major, then round, then offset-in-window:
    stage m's contiguous block of k*w rows holds its k windows in order.
    """
    assert L_pad % (n_stages * k) == 0, (L_pad, n_stages, k)
    w = L_pad // (n_stages * k)
    perm = np.zeros(L_pad, dtype=np.int64)
    i = 0
    for m in range(n_stages):
        for r in range(k):
            base = (r * n_stages + m) * w
            for off in range(w):
                perm[i] = base + off
                i += 1
    return perm


def pad_and_permute(stacked: Any, cfg: ModelConfig, n_stages: int, k: int
                    ) -> Any:
    """Zero-pad the layer axis to L_pad (identity residual blocks) and apply
    the ring permutation. Works on params['blocks'] or cache['layers']."""
    L = cfg.n_layers
    L_pad = padded_layers(L, n_stages)
    perm = ring_permutation(L_pad, n_stages, k)

    def fix(a):
        if a.shape[0] != L:
            return a
        if L_pad != L:
            pad = [(0, L_pad - L)] + [(0, 0)] * (a.ndim - 1)
            a = jnp.pad(a, pad)
        return jnp.take(a, perm, axis=0)

    return jax.tree.map(fix, stacked)


#: per-layer matmul weights eligible for int4 ring storage (norms, biases,
#: convs, gates stay bf16 — they are tiny and numerically sensitive)
RING_QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "router",
    "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "in_proj", "out_proj",
    "w_x", "w_y", "w_out"})


#: leaves whose contraction dim is model-sharded in ring TP — their scale
#: rows (K/group) must stay divisible by tp
_RING_TP_CONTRACTION = frozenset({"w_down", "out_proj"})


def quantize_ring_params(params: Params, cfg: ModelConfig, *,
                         tp: int = 16) -> Tuple[Params, List[str]]:
    """Store the ring layer bank in packed int4 (+bf16 group scales).

    Returns ``(params, skipped)`` where ``skipped`` lists the eligible
    matmul leaves left in bf16 because no group size satisfied the
    sharding divisibility constraints — a silent bf16 fallback would cap
    compression without anyone noticing, so benches must report it.

    The TPU-side compute pairs this with the dequant-in-kernel
    ``kernels/q4_matmul`` (validated vs its oracle); the jnp path
    dequantizes at use. Decode is weight-bandwidth-bound, so halving →
    quartering the streamed bytes moves the dominant roofline term
    directly (EXPERIMENTS §Perf HC2).

    Group size adapts per leaf: 64 normally; smaller for leaves whose
    contraction dim is TP-sharded so packed values and scales shard
    identically (shard_map needs exact divisibility).
    """
    from ..quant.grouped import quantize_q4

    skipped: List[str] = []

    def pick_group(key: str, K: int) -> Optional[int]:
        for g in (64, 32, 16):
            if K % g:
                continue
            if key in _RING_TP_CONTRACTION and (K // g) % tp:
                continue
            if K // g < 1:
                continue
            return g
        return None

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                eligible = (k in RING_QUANT_KEYS and hasattr(v, "ndim")
                            and v.ndim >= 3)
                g = pick_group(k, v.shape[-2]) if eligible else None
                if g:
                    out[k] = quantize_q4(v, group=g)
                else:
                    if eligible:
                        skipped.append(f"{prefix}{k} (K={v.shape[-2]})")
                    out[k] = walk(v, f"{prefix}{k}/")
            return out
        return tree

    out = dict(params)
    out["blocks"] = walk(params["blocks"])
    if skipped:
        import logging

        logging.getLogger(__name__).warning(
            "quantize_ring_params: %d leaves left bf16 (no group size "
            "fits K and tp=%d): %s", len(skipped), tp, ", ".join(skipped))
    return out, skipped


def _dequant_tree(p):
    """Dequantize any QuantizedTensor leaves of a (sliced) param subtree."""
    from ..quant.grouped import dequantize_tree

    return dequantize_tree(p, jnp.bfloat16)


#: per-layer leaves the ring window body consumes through ``ll.qmm`` — a
#: 2-D q4 slice of these stays packed and dispatches the fused
#: ``kernels/q4_matmul``, so the microstep streams packed bytes instead of
#: materializing a bf16 copy in HBM first
_RING_QMM_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "wq_a", "wq_b", "wkv_a", "in_proj", "out_proj"})


def dequant_ring_reference(blocks, dtype=jnp.float32):
    """Dequantize a *stacked* ring layer bank with the same numerics the
    window body applies at use: leaves consumed through ``ll.qmm`` keep
    full precision (the fused kernel multiplies int4 by the scale in f32
    without a bf16 round-trip), everything else dequantizes through bf16
    exactly like ``_prep_ring_layer``. Reference paths (tests, oracles)
    use this so "quantized ring == dequantized reference" stays an exact
    contract.
    """
    from ..quant.grouped import QuantizedTensor, dequantize_leaf

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if isinstance(v, QuantizedTensor):
                    keep = (k in _RING_QMM_KEYS and v.bits == 4
                            and v.packed.ndim == 3)
                    dq = dequantize_leaf(
                        v, jnp.float32 if keep else jnp.bfloat16)
                    out[k] = dq.astype(dtype)
                else:
                    out[k] = walk(v)
            return out
        return tree

    return walk(blocks)


def _prep_ring_layer(p):
    """Prepare one sliced ring layer's params for the window body.

    q4 leaves consumed via ``ll.qmm`` stay packed (dequantization happens
    tile-by-tile in VMEM inside the fused matmul kernel); everything else
    — einsum-consumed ``wk_b``/``wv_b``, MoE expert banks (3-D after the
    slice), routers, the q2 demo format — dequantizes up front exactly as
    the old whole-subtree path did. Both matmul paths accumulate f32, so
    keeping a leaf packed does not change logits.
    """
    from ..quant.grouped import QuantizedTensor, dequantize_leaf

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if isinstance(v, QuantizedTensor):
                    keep = (k in _RING_QMM_KEYS and v.bits == 4
                            and v.packed.ndim == 2)
                    out[k] = v if keep else dequantize_leaf(v, jnp.bfloat16)
                else:
                    out[k] = walk(v)
            return out
        return tree

    return walk(p)


def pad_vocab(params: Params, cfg: ModelConfig, tp: int) -> Params:
    """Pad embed/unembed vocab to a multiple of tp (shard_map divisibility)."""
    V = cfg.vocab
    V_pad = -(-V // tp) * tp
    if V_pad == V:
        return params
    out = dict(params)
    out["embed"] = jnp.pad(params["embed"], ((0, V_pad - V), (0, 0)))
    if "unembed" in params:
        out["unembed"] = jnp.pad(params["unembed"], ((0, 0), (0, V_pad - V)))
    return out


def _stacked_leaf_spec(key: str, nd: int, *, ep: bool = False):
    """Spec for one stacked per-layer leaf: axis 0 = ring layer order ->
    "data"; FFN/MoE inner dims over "model"; everything else replicated."""
    if key in ("w_gate", "w_up") and nd == 4:          # MoE (L, E, d, f)
        return P("data", "model", None, None) if ep \
            else P("data", None, None, "model")
    if key == "w_down" and nd == 4:
        return P("data", "model", None, None) if ep \
            else P("data", None, "model", None)
    if key in ("w_gate", "w_up") and nd == 3:          # GLU (L, d, f)
        return P("data", None, "model")
    if key == "w_down" and nd == 3:
        return P("data", "model", None)
    return P(*(["data"] + [None] * (nd - 1)))


def ring_param_specs(cfg: ModelConfig, mesh: Mesh, params: Params):
    """PartitionSpecs for ring-mode params.

    Layer axis over "data"; FFN/MoE inner dims over "model"; attention and
    SSM weights replicated over "model"; embeddings vocab-sharded.
    """
    tp = mesh.shape["model"]
    # ring mode currently dispatches MoE with TP inside each expert; EP is
    # the §Perf hillclimb variant (build_ring_serve_step(..., moe_ep=True)).
    ep = False

    def spec(path, leaf):
        key = S._leaf_key(jax.tree_util.keystr(path))
        nd = leaf.ndim
        if key == "embed":
            return P("model", None)
        if key == "unembed":
            return P(None, "model")
        if key == "final_norm":
            return P()
        return _stacked_leaf_spec(key, nd, ep=ep)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [S.sanitize(spec(p, l), tuple(l.shape), mesh)
                  for p, l in flat])


def ring_cache_specs(cfg: ModelConfig, mesh: Mesh, cache: Dict):
    """Layer axis over "data"; KV sequence over "model"; pods shard batch."""
    pod = ("pod",) if "pod" in mesh.axis_names else ()

    def spec(path, leaf):
        key = S._leaf_key(jax.tree_util.keystr(path))
        nd = leaf.ndim
        if key == "len":
            return P(pod) if pod else P()
        if key in ("k", "v"):                 # (L, B, S, hk, hd)
            return P("data", pod, "model", None, None)
        if key in ("k_scale", "v_scale"):     # (L, B, S, hk)
            return P("data", pod, "model", None)
        if key == "latent":                   # (L, B, S, r)
            return P("data", pod, "model", None)
        if key == "state":                    # (L, B, nh, P, N)
            return P("data", pod, None, None, None)
        if key == "conv":                     # (L, B, K-1, C)
            return P("data", pod, None, None)
        return P(*(["data"] + [pod if i == 0 else None
                               for i in range(nd - 1)]))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


# --------------------------------------------------------------------------- #
#  masked sequence-sharded KV write
# --------------------------------------------------------------------------- #

def _masked_slot_update(arr: jnp.ndarray, new: jnp.ndarray,
                        slot: jnp.ndarray, s_start: int, s_len: int
                        ) -> jnp.ndarray:
    """Write new (B, 1, ...) at absolute slot into the local seq shard
    arr (B, s_len, ...) iff slot lands in [s_start, s_start + s_len)."""
    local = jnp.clip(slot - s_start, 0, s_len - 1)
    in_range = (slot >= s_start) & (slot < s_start + s_len)

    def upd(a, n, i, ok):
        cur = lax.dynamic_slice_in_dim(a, i, 1, axis=0)
        val = jnp.where(ok, n.astype(a.dtype), cur)
        return lax.dynamic_update_slice_in_dim(a, val, i, axis=0)

    return jax.vmap(upd)(arr, new, local, in_range)


# --------------------------------------------------------------------------- #
#  per-family ring window layers (decode, explicit collectives)
# --------------------------------------------------------------------------- #

def _ring_attn_layer(cfg: ModelConfig, p, x, c, ln, *, s_start, s_len):
    """One dense/moe/vlm decoder layer, ring decode mode.

    x: (mb, T, d) replicated over "model" (T = 1 ordinary decode, T > 1 the
    speculative verify block); c: local cache slice
    {k/v: (mb, s_len, hk, hd), [scales]}; ln: (mb,) tokens so far.
    """
    mb, T = x.shape[0], x.shape[1]
    pos = ln[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, mb, T))
    h = ll.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.mla:
        return _ring_mla_layer(cfg, p, x, h, c, ln, pos,
                               s_start=s_start, s_len=s_len)
    q, k, v = ll.attn_qkv(p["attn"], cfg, h, pos)
    window = cfg.attn_window
    Smax_global = s_len * lax.psum(1, "model")
    rolling = window is not None and Smax_global == window
    assert T == 1 or not rolling, "multi-token ring needs Smax > window"
    quantized = "k_scale" in c
    if quantized:
        k_wr, ksc = ll.quantize_kv(k)
        v_wr, vsc = ll.quantize_kv(v)
    else:
        k_wr, v_wr = k, v
    kc, vc = c["k"], c["v"]
    ks = c.get("k_scale")
    vs = c.get("v_scale")
    for t in range(T):                       # static, small (draft block)
        slot = ((ln + t) % window) if rolling \
            else jnp.minimum(ln + t, Smax_global - 1)
        kc = _masked_slot_update(kc, k_wr[:, t:t + 1], slot, s_start, s_len)
        vc = _masked_slot_update(vc, v_wr[:, t:t + 1], slot, s_start, s_len)
        if quantized:
            ks = _masked_slot_update(ks, ksc[:, t:t + 1], slot, s_start,
                                     s_len)
            vs = _masked_slot_update(vs, vsc[:, t:t + 1], slot, s_start,
                                     s_len)
    if quantized:
        new_c = {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}
        k_at = ll.dequantize_kv(kc, ks, q.dtype)
        v_at = ll.dequantize_kv(vc, vs, q.dtype)
    else:
        new_c = {"k": kc, "v": vc}
        k_at = kc.astype(q.dtype)
        v_at = vc.astype(q.dtype)
    kv_len = jnp.minimum(ln + T, Smax_global) if window is not None \
        else ln + T
    # rolling SWA buffer: every valid slot is in-window once full, and the
    # stats path masks by absolute position, so pass window=None when the
    # buffer size equals the window (slots are position-permuted).
    eff_window = None if rolling else window
    acc, m_, l_ = ll.verify_attention_stats(q, k_at, v_at, kv_len,
                                            window=eff_window,
                                            pos_offset=s_start)
    out = ll.merge_attention_stats(acc, m_, l_, "model")   # (mb, H, T, hd)
    o = ll.qmm(out.transpose(0, 2, 1, 3).reshape(mb, T, -1).astype(x.dtype),
               p["attn"]["wo"])
    x = x + o
    g = ll.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.n_experts:
        y = ll.moe_ffn(p["moe"], cfg, g, lossless=True, tp_axis="model")
    else:
        y = ll.glu_ffn(p["ffn"], g, tp_axis="model")
    return x + y, new_c


def _ring_mla_layer(cfg: ModelConfig, p, x, h, c, ln, pos, *, s_start,
                    s_len):
    """MLA ring decode: latent cache sequence-sharded; absorbed scores are
    computed per shard and merged with the distributed online softmax.
    x: (mb, T, d) — T > 1 scores the speculative draft block causally."""
    mb, T = x.shape[0], x.shape[1]
    pa = p["attn"]
    H = cfg.n_heads
    r_kv = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_lat = ll.rms_norm(ll.qmm(h, pa["wq_a"]), pa["q_norm"], cfg.norm_eps)
    q = ll.qmm(q_lat, pa["wq_b"]).reshape(mb, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = ll.apply_rope(q_rope, pos, cfg.rope_theta)

    kv = ll.qmm(h, pa["wkv_a"])
    latent = ll.rms_norm(kv[..., :r_kv], pa["kv_norm"], cfg.norm_eps)
    k_rope = ll.apply_rope(kv[..., r_kv:][:, :, None, :], pos,
                           cfg.rope_theta)[:, :, 0]
    lat_cat = jnp.concatenate([latent, k_rope], -1)          # (mb, T, r+dr)

    lc = c["latent"]
    for t in range(T):                       # static, small (draft block)
        lc = _masked_slot_update(lc, lat_cat[:, t:t + 1], ln + t,
                                 s_start, s_len)
    new_c = {"latent": lc}
    lat_all = lc[..., :r_kv].astype(x.dtype)                 # (mb, sl, r)
    rope_all = lc[..., r_kv:].astype(x.dtype)

    wk = pa["wk_b"].reshape(r_kv, H, dn)
    q_abs = jnp.einsum("bthd,rhd->bthr", q_nope, wk)
    s_nope = jnp.einsum("bthr,bsr->bhts", q_abs, lat_all,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bthd,bsd->bhts", q_rope, rope_all,
                        preferred_element_type=jnp.float32)
    s_all = (s_nope + s_rope) * scale                        # (mb, H, T, sl)
    spos = jnp.arange(s_len) + s_start                       # (sl,)
    qpos = ln[:, None] + jnp.arange(T)[None, :]              # (mb, T)
    mask = spos[None, None, :] <= qpos[:, :, None]           # (mb, T, sl)
    s_all = jnp.where(mask[:, None], s_all, -jnp.inf)
    m_ = jnp.max(s_all, -1)                                  # (mb, H, T)
    m_safe = jnp.where(jnp.isfinite(m_), m_, 0.0)
    pr = jnp.where(mask[:, None], jnp.exp(s_all - m_safe[..., None]), 0.0)
    l_ = pr.sum(-1)
    acc = jnp.einsum("bhts,bsr->bhtr", pr, lat_all.astype(jnp.float32))
    o_lat = ll.merge_attention_stats(acc, m_, l_, "model")   # (mb, H, T, r)
    wv = pa["wv_b"].reshape(r_kv, H, dv)
    out = jnp.einsum("bhtr,rhv->bthv", o_lat.astype(x.dtype), wv)
    o = ll.qmm(out.reshape(mb, T, H * dv), pa["wo"])
    x = x + o
    g = ll.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    y = ll.glu_ffn(p["ffn"], g, tp_axis="model")
    return x + y, new_c


def _ring_ssd_layer(cfg: ModelConfig, p, x, c, ln):
    """SSM ring decode: state update, replicated inside the stage."""
    h = ll.rms_norm(x, p["norm"], cfg.norm_eps)
    y, new_c = ll.ssd_block(p["ssd"], cfg, h, cache=c, decode=True)
    return x + y, new_c


def run_ring_window(cfg: ModelConfig, p_win, x, c_win, ln, *,
                    s_start, s_len):
    """Apply one window of w layers (leading axis of p_win/c_win)."""
    w = jax.tree.leaves(p_win)[0].shape[0]
    new_caches = []
    for i in range(w):
        p_i = _prep_ring_layer(jax.tree.map(lambda a: a[i], p_win))
        c_i = jax.tree.map(lambda a: a[i], c_win)
        if cfg.family == "ssm":
            x, nc = _ring_ssd_layer(cfg, p_i, x, c_i, ln)
        else:
            x, nc = _ring_attn_layer(cfg, p_i, x, c_i, ln,
                                     s_start=s_start, s_len=s_len)
        new_caches.append(nc)
    c_new = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_caches)
    return x, c_new


# --------------------------------------------------------------------------- #
#  vocab-sharded embed / unembed
# --------------------------------------------------------------------------- #

def _ring_embed(embed_loc: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """embed_loc: (V/tp, d) local vocab shard; tokens: (B, 1)."""
    v_loc = embed_loc.shape[0]
    off = lax.axis_index("model") * v_loc
    idx = jnp.clip(tokens - off, 0, v_loc - 1)
    emb = jnp.take(embed_loc, idx, axis=0)                   # (B, 1, d)
    ok = (tokens >= off) & (tokens < off + v_loc)
    emb = jnp.where(ok[..., None], emb, 0.0)
    return lax.psum(emb, "model")


def _ring_unembed(params_loc, cfg: ModelConfig, x: jnp.ndarray
                  ) -> jnp.ndarray:
    """x: (B, 1, d) -> local logits (B, 1, V/tp)."""
    if "unembed" in params_loc:
        return x @ params_loc["unembed"]
    return x @ params_loc["embed"].T


# --------------------------------------------------------------------------- #
#  the piped-ring serve step
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class RingPlan:
    """Static ring decode plan (the Halda decision for this mesh)."""
    n_stages: int
    k: int                      # rounds per token
    w: int                      # layers per window
    L_pad: int

    @classmethod
    def make(cls, cfg: ModelConfig, n_stages: int, k: int = 1) -> "RingPlan":
        L_pad = padded_layers(cfg.n_layers, n_stages)
        per_stage = L_pad // n_stages
        assert per_stage % k == 0, (per_stage, k)
        return cls(n_stages=n_stages, k=k, w=per_stage // k, L_pad=L_pad)


def build_ring_serve_step(cfg: ModelConfig, mesh: Mesh, plan: RingPlan,
                          *, n_tokens: int = 1) -> Callable:
    """Returns jit'd serve_step(params_ring, cache_ring, tokens, ln) ->
    (logits, new_cache).

    ``params_ring``/``cache_ring`` must already be in ring layer order
    (``pad_and_permute``) with vocab padded (``pad_vocab``).

    ``n_tokens`` (T): tokens scored per ring pass. T = 1 is the paper's
    one-token-per-ring decode; T > 1 is the speculative *verify* pass —
    tokens (B, T) are written into the cache and scored with causal
    masking among them, ``len`` advances by T, and the engine rolls back
    rejected positions by resetting per-slot ``len`` (the next pass
    overwrites the stale slots).
    """
    if n_tokens > 1 and cfg.family == "ssm":
        raise ValueError("speculative verify needs a rollbackable KV cache; "
                         "ssm state is irreversible")
    M_stages, k, w = plan.n_stages, plan.k, plan.w
    has_pod = "pod" in mesh.axis_names
    pod = ("pod",) if has_pod else ()
    n_steps = k * M_stages + M_stages - 1
    kM = k * M_stages

    def local_fn(tokens, ln, params_loc, cache_loc):
        # local shapes: tokens (B, T), ln (B,) [per-pod batch]
        # params_loc["blocks"]: (k*w, ...); cache_loc["layers"]: (k*w, B, ...)
        m = lax.axis_index("data")
        B = tokens.shape[0]
        mb = B // M_stages
        d = params_loc["embed"].shape[1]
        seq_sharded = cfg.family != "ssm"
        if seq_sharded and cfg.family in ("dense", "moe", "vlm") \
                and not cfg.mla:
            s_len = cache_loc["layers"]["k"].shape[2]
        elif cfg.mla:
            s_len = cache_loc["layers"]["latent"].shape[2]
        else:
            s_len = 0
        s_start = lax.axis_index("model") * s_len

        emb_all = _ring_embed(params_loc["embed"], tokens)    # (B, T, d)
        dtype = emb_all.dtype

        def step(t, carry):
            x, layers_c, out_buf = carry
            e = jnp.mod(t - m, M_stages)                      # microbatch id
            j = t - e                                         # window index
            valid = (j >= 0) & (j < kM)
            r = jnp.clip(j // M_stages, 0, k - 1)

            p_r = jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(a, r * w, w, axis=0),
                params_loc["blocks"])
            c_r = jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(
                    lax.dynamic_slice_in_dim(a, r * w, w, axis=0),
                    e * mb, mb, axis=1),
                layers_c)
            ln_mb = lax.dynamic_slice(ln, (e * mb,), (mb,))
            emb_mb = lax.dynamic_slice_in_dim(emb_all, e * mb, mb, axis=0)

            x_in = jnp.where(jnp.equal(j, 0), emb_mb, x)
            x_out, c_new = run_ring_window(cfg, p_r, x_in, c_r, ln_mb,
                                           s_start=s_start, s_len=s_len)

            # masked cache write-back
            def wb(full, new, old):
                sel = jnp.where(valid, new, old)
                inner = lax.dynamic_update_slice_in_dim(
                    lax.dynamic_slice_in_dim(full, r * w, w, axis=0),
                    sel, e * mb, axis=1)
                return lax.dynamic_update_slice_in_dim(full, inner, r * w,
                                                       axis=0)

            layers_c = jax.tree.map(wb, layers_c, c_new, c_r)

            # capture finished microbatch (last window)
            fin = valid & (j == kM - 1)
            hid = ll.rms_norm(x_out, params_loc["final_norm"], cfg.norm_eps)
            cur = lax.dynamic_slice_in_dim(out_buf, e * mb, mb, axis=0)
            out_buf = lax.dynamic_update_slice_in_dim(
                out_buf, jnp.where(fin, hid, cur), e * mb, axis=0)

            # ring hop
            perm = [(i, (i + 1) % M_stages) for i in range(M_stages)]
            x_next = lax.ppermute(x_out, "data", perm)
            return x_next, layers_c, out_buf

        x0 = jnp.zeros((mb, n_tokens, d), dtype)
        out0 = jnp.zeros((B, n_tokens, d), dtype)
        x_fin, layers_c, out_buf = lax.fori_loop(
            0, n_steps, step, (x0, cache_loc["layers"], out0))

        # final hiddens live on the stage that owns the last window;
        # psum over the ring replicates them for the vocab-sharded matmul.
        hidden = lax.psum(out_buf, "data")
        logits_loc = _ring_unembed(params_loc, cfg, hidden)   # (B,T,V/tp)
        new_cache = dict(cache_loc)
        new_cache["layers"] = layers_c
        new_cache["len"] = ln + n_tokens
        return logits_loc, new_cache

    # ---- shard_map wiring -------------------------------------------------
    params_like = None  # resolved at call time via eval_shape by caller

    def make(params_ring, cache_ring):
        p_specs = ring_param_specs(cfg, mesh, params_ring)
        c_specs = ring_cache_specs(cfg, mesh, cache_ring)
        tok_spec = P(pod, None) if pod else P(None, None)
        ln_spec = P(pod) if pod else P()
        out_spec = (P(pod, None, "model") if pod else P(None, None, "model"),
                    c_specs)
        fn = shard_map(local_fn, mesh=mesh,
                       in_specs=(tok_spec, ln_spec, p_specs, c_specs),
                       out_specs=out_spec, check_vma=False)
        return jax.jit(fn, donate_argnums=(3,))

    return make


# --------------------------------------------------------------------------- #
#  streamed piped ring: host-driven microsteps over disk-backed banks
# --------------------------------------------------------------------------- #
#
# ``build_ring_serve_step`` runs the whole k*M + M - 1 microstep schedule
# inside one jit over the full resident layer bank. The streamed variant
# exposes ONE microstep as the jitted unit: the host loop feeds each step
# the (w, ...) window bank it needs (assembled from the layer-sharded
# store by ``streaming.RingBankPrefetcher``), so per-device weight
# residency is bounded by the window size — the paper's pipelined layer
# streaming on the SPMD ring. The KV cache stays device-resident.

def ring_bank_rounds(plan: RingPlan, t: int) -> np.ndarray:
    """(M,) round index r_m(t) stage m computes at microstep t (clipped —
    out-of-schedule stages are masked inside the step anyway)."""
    M_stages, k = plan.n_stages, plan.k
    out = np.zeros(M_stages, dtype=np.int64)
    for m in range(M_stages):
        e = (t - m) % M_stages
        j = t - e
        out[m] = min(max(j // M_stages, 0), k - 1)
    return out


def ring_bank_layers(plan: RingPlan, t: int) -> np.ndarray:
    """(M*w,) global layer index for each row of the step-t window bank.

    Bank row m*w + off is ring-stacked position m*k*w + r_m(t)*w + off,
    i.e. global layer (r_m(t)*M + m)*w + off (rows >= L are zero padding).
    """
    M_stages, k, w = plan.n_stages, plan.k, plan.w
    rs = ring_bank_rounds(plan, t)
    rows = np.zeros(M_stages * w, dtype=np.int64)
    for m in range(M_stages):
        for off in range(w):
            rows[m * w + off] = (rs[m] * M_stages + m) * w + off
    return rows


def ring_bank_specs(cfg: ModelConfig, mesh: Mesh, bank_like):
    """PartitionSpecs for a (M*w, ...) window-bank pytree."""
    def spec(path, leaf):
        key = S._leaf_key(jax.tree_util.keystr(path))
        return S.sanitize(_stacked_leaf_spec(key, leaf.ndim),
                          tuple(leaf.shape), mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(bank_like)
    return jax.tree_util.tree_unflatten(treedef,
                                        [spec(p, l) for p, l in flat])


def build_ring_stream_step(cfg: ModelConfig, mesh: Mesh, plan: RingPlan,
                           head_params: Params, cache_like, layer_like, *,
                           n_tokens: int = 1):
    """Build the jitted pieces of the streamed ring pass.

    Returns ``((embed_fn, micro_fn, final_fn), bank_specs)``:

      embed_fn(tokens, head)                  -> emb_all (B, T, d)
      micro_fn(t, x, emb_all, ln, layers_c, out_buf, bank, final_norm)
                                              -> (x, layers_c, out_buf)
      final_fn(out_buf, head)                 -> logits (B, T, V_pad)

    ``bank`` holds each stage's current (w, ...) window
    (``ring_bank_layers`` rows, assembled host-side per microstep);
    ``head_params``/``cache_like`` must be ring-prepared (``pad_vocab``,
    cache via ``pad_and_permute``). Single-pod meshes only — the streamed
    driver is host-paced and pods would need one driver per replica.
    """
    if "pod" in mesh.axis_names:
        raise ValueError("streamed ring does not support the pod axis")
    if n_tokens > 1 and cfg.family == "ssm":
        raise ValueError("speculative verify needs a rollbackable KV cache")
    M_stages, k, w = plan.n_stages, plan.k, plan.w
    kM = k * M_stages

    def embed_local(tokens, head_loc):
        return _ring_embed(head_loc["embed"], tokens)

    def micro_local(t, x, emb_all, ln, layers_c, out_buf, bank_loc,
                    final_norm):
        m = lax.axis_index("data")
        B = emb_all.shape[0]
        mb = B // M_stages
        if cfg.family in ("dense", "moe", "vlm") and not cfg.mla:
            s_len = layers_c["k"].shape[2]
        elif cfg.mla:
            s_len = layers_c["latent"].shape[2]
        else:
            s_len = 0
        s_start = lax.axis_index("model") * s_len

        e = jnp.mod(t - m, M_stages)
        j = t - e
        valid = (j >= 0) & (j < kM)
        r = jnp.clip(j // M_stages, 0, k - 1)

        c_r = jax.tree.map(
            lambda a: lax.dynamic_slice_in_dim(
                lax.dynamic_slice_in_dim(a, r * w, w, axis=0),
                e * mb, mb, axis=1),
            layers_c)
        ln_mb = lax.dynamic_slice(ln, (e * mb,), (mb,))
        emb_mb = lax.dynamic_slice_in_dim(emb_all, e * mb, mb, axis=0)

        x_in = jnp.where(jnp.equal(j, 0), emb_mb, x)
        x_out, c_new = run_ring_window(cfg, bank_loc, x_in, c_r, ln_mb,
                                       s_start=s_start, s_len=s_len)

        def wb(full, new, old):
            sel = jnp.where(valid, new, old)
            inner = lax.dynamic_update_slice_in_dim(
                lax.dynamic_slice_in_dim(full, r * w, w, axis=0),
                sel, e * mb, axis=1)
            return lax.dynamic_update_slice_in_dim(full, inner, r * w,
                                                   axis=0)

        layers_c = jax.tree.map(wb, layers_c, c_new, c_r)

        fin = valid & (j == kM - 1)
        hid = ll.rms_norm(x_out, final_norm, cfg.norm_eps)
        cur = lax.dynamic_slice_in_dim(out_buf, e * mb, mb, axis=0)
        out_buf = lax.dynamic_update_slice_in_dim(
            out_buf, jnp.where(fin, hid, cur), e * mb, axis=0)

        perm = [(i, (i + 1) % M_stages) for i in range(M_stages)]
        x_next = lax.ppermute(x_out, "data", perm)
        return x_next, layers_c, out_buf

    def final_local(out_buf, head_loc):
        hidden = lax.psum(out_buf, "data")
        return _ring_unembed(head_loc, cfg, hidden)

    hp_specs = ring_param_specs(cfg, mesh, head_params)
    c_specs = ring_cache_specs(cfg, mesh, cache_like)["layers"]
    bank_like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((M_stages * w,) + tuple(a.shape),
                                       a.dtype), layer_like)
    bank_specs = ring_bank_specs(cfg, mesh, bank_like)
    rep = P(None, None, None)     # (B|mb, T, d) activations

    embed_fn = jax.jit(shard_map(
        embed_local, mesh=mesh, in_specs=(P(None, None), hp_specs),
        out_specs=rep, check_vma=False))
    micro_fn = jax.jit(shard_map(
        micro_local, mesh=mesh,
        in_specs=(P(), P("data", None, None), rep, P(None), c_specs,
                  P("data", None, None), bank_specs, P()),
        out_specs=(P("data", None, None), c_specs, P("data", None, None)),
        check_vma=False), donate_argnums=(1, 4, 5))
    final_fn = jax.jit(shard_map(
        final_local, mesh=mesh,
        in_specs=(P("data", None, None), hp_specs),
        out_specs=P(None, None, "model"), check_vma=False))
    return (embed_fn, micro_fn, final_fn), bank_specs


# --------------------------------------------------------------------------- #
#  GSPMD decode path (hybrid / audio / small-batch fallback) + prefill
# --------------------------------------------------------------------------- #

def gspmd_decode_step(cfg: ModelConfig, mesh: Mesh, params_like, cache_like):
    """jit(decode_step) with GSPMD shardings (non-ring baseline and the
    path for architectures whose stack the SPMD ring cannot express)."""
    pspec = S.param_shardings(cfg, mesh, params_like)
    cspec = S.cache_shardings(cfg, mesh, cache_like)
    B = cache_like["len"].shape[0]
    b_spec = S.sanitize(P(S.batch_axes(mesh)), (B, 1), mesh)
    tok = NamedSharding(mesh, b_spec)
    out = NamedSharding(mesh, P(b_spec[0], None, None))

    def fn(params, cache, tokens):
        return M.decode_step(params, cfg, cache, tokens)

    fn = _with_act_constraint(fn, mesh, B)
    return jax.jit(fn, in_shardings=(pspec, cspec, tok),
                   out_shardings=(out, cspec),
                   donate_argnums=(1,))


def _with_act_constraint(fn, mesh: Mesh, batch: int):
    """Pin (B, S, d) activations (and MoE capacity buffers) to
    batch-over-data during tracing."""
    spec = S.sanitize(P(S.batch_axes(mesh), None, None), (batch, 1, 1),
                      mesh)
    act = NamedSharding(mesh, spec)
    moe = NamedSharding(mesh, P(None, S.batch_axes(mesh), None))

    # NOTE (§Perf, refuted): also constraining the MoE (E,C,d) buffers
    # forces GSPMD to materialize both the scatter layout and the target
    # layout (37 -> 108 GiB/chip). The buffer is bounded structurally
    # instead (chunked dispatch in layers.moe_ffn).
    def wrapped(*args):
        M.set_activation_constraint(
            lambda x: lax.with_sharding_constraint(x, act))
        try:
            return fn(*args)
        finally:
            M.set_activation_constraint(None)

    return wrapped


def gspmd_prefill(cfg: ModelConfig, mesh: Mesh, params_like, cache_like, *,
                  has_embeds: bool = False):
    pspec = S.param_shardings(cfg, mesh, params_like)
    cspec = S.cache_shardings(cfg, mesh, cache_like)
    B = cache_like["len"].shape[0]
    b_spec = S.sanitize(P(S.batch_axes(mesh)), (B, 1), mesh)
    tok = NamedSharding(mesh, b_spec)
    out = NamedSharding(mesh, P(b_spec[0], None, None))

    if has_embeds:
        def fn(params, cache, tokens, embeds):
            return M.prefill(params, cfg, tokens, cache, embeds=embeds,
                             remat=True)
        in_sh = (pspec, cspec, tok, S.embeds_sharding(mesh))
    else:
        def fn(params, cache, tokens):
            return M.prefill(params, cfg, tokens, cache, remat=True)
        in_sh = (pspec, cspec, tok)

    fn = _with_act_constraint(fn, mesh, B)
    return jax.jit(fn, in_shardings=in_sh,
                   out_shardings=(out, cspec),
                   donate_argnums=(1,))
