"""Shared I/O retry/backoff policy and error taxonomy for the runtime.

The paper's operating point — weights streamed from consumer SSDs, KV
pages bounced over host links, stages living on flaky home machines —
makes I/O failure the common case, not the exception. Every worker
thread in ``runtime.streaming`` and ``runtime.kvcache`` funnels its disk
reads and host<->device transfers through one :class:`IOPolicy`, so the
whole runtime shares a single answer to the three questions that matter:

  * **is this error transient or fatal?** (``classify``): ``OSError``
    (flaky disk, short read, injected I/O fault) is transient and worth
    retrying with the mmap re-opened; shape/type/corruption errors are
    fatal — retrying a truncated manifest only burns the deadline.
  * **how long do we keep trying?** bounded retries under exponential
    backoff with deterministic jitter, all inside a per-op deadline so a
    silently hung ``read()`` becomes a detectable :class:`StallTimeout`
    instead of a forever-blocked ``get()``.
  * **what does the caller see?** one classified exception type per
    outcome — :class:`FatalIOError` (gave up), :class:`StallTimeout`
    (deadline), :class:`StageFailure` (a ring stage died; the failover
    driver keys on this) — each carrying enough context (op name,
    attempts, cause chain) to log or act on.

:class:`WorkerHealth` is the watchdog half: a tiny mutable record of
consecutive failures, retry totals, and a last-progress timestamp that
``PrefetchStats`` and stall reports surface, so degradation is visible
before it becomes an outage.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Type, TypeVar

from .telemetry import clock

T = TypeVar("T")


# --------------------------------------------------------------------------- #
#  error taxonomy
# --------------------------------------------------------------------------- #

class ShortReadError(OSError):
    """A layer file is smaller than the manifest says it should be.

    Raised by ``ParamStore.layer()`` when the mapping cannot cover
    ``layer_nbytes`` — the classified form of "the file was truncated
    after the manifest loaded". Transient by classification (a writer
    may still be flushing; a retry re-opens the mapping), but it names
    the layer and file so the fatal wrap-up after retries exhaust is
    actionable instead of a shape crash deep in jax.
    """

    def __init__(self, msg: str, *, layer: int = -1, path: str = "",
                 expected: int = 0, got: int = 0):
        super().__init__(msg)
        self.layer = layer
        self.path = path
        self.expected = expected
        self.got = got


class BudgetExceeded(OSError):
    """A tier of the shared memory budget refused an allocation.

    An ``OSError`` subclass so :class:`IOPolicy` classifies it
    *transient*: a refusal is usually a full tier whose bytes another
    slot is about to release (a finishing sequence, a layer falling
    behind the compute front), so a bounded retry under backoff is the
    right response — unbounded growth past the budget never is. Carries
    the tier and the byte arithmetic so the fatal wrap-up after retries
    exhaust names the actual pressure instead of a bare refusal.
    """

    def __init__(self, msg: str, *, tier: str = "", requested: int = 0,
                 used: int = 0, capacity: int = 0):
        super().__init__(msg)
        self.tier = tier
        self.requested = requested
        self.used = used
        self.capacity = capacity


class FatalIOError(RuntimeError):
    """An I/O op failed permanently: retries exhausted or the error was
    classified fatal. ``__cause__`` holds the last underlying error."""

    def __init__(self, msg: str, *, op: str = "", attempts: int = 0):
        super().__init__(msg)
        self.op = op
        self.attempts = attempts


class StallTimeout(FatalIOError):
    """An op (or a ``get()`` waiting on a worker) exceeded its deadline —
    the detectable form of a silent stall."""


class StageFailure(RuntimeError):
    """A pipeline stage died (injected or detected). Carries the mesh
    stage index under the *current* plan; the elastic failover driver
    walks exception cause chains looking for this type."""

    def __init__(self, msg: str, *, stage: int = -1):
        super().__init__(msg)
        self.stage = stage


def find_cause(exc: BaseException,
               cls: Type[BaseException]) -> Optional[BaseException]:
    """Walk ``__cause__``/``__context__`` looking for an instance of
    ``cls`` (e.g. dig a ``StageFailure`` out of the RuntimeError a
    prefetcher ``get()`` raised)."""
    seen = set()
    cur: Optional[BaseException] = exc
    while cur is not None and id(cur) not in seen:
        if isinstance(cur, cls):
            return cur
        seen.add(id(cur))
        cur = cur.__cause__ if cur.__cause__ is not None else cur.__context__
    return None


# --------------------------------------------------------------------------- #
#  watchdog / health
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class WorkerHealth:
    """Mutable health record for one worker thread.

    Written by the worker (under its condition lock or from the single
    worker thread), read by ``get()`` timeouts, ``stats()``, and stall
    reports. Plain attributes — torn reads of a float timestamp are
    harmless for a health display. ``last_progress_t`` is stamped on the
    shared :func:`runtime.telemetry.clock`, so health records merge onto
    the same timeline as prefetch events and fault audit trails.
    """

    name: str = ""
    consecutive_failures: int = 0
    failures: int = 0                 # every failed attempt
    retries: int = 0                  # failed attempts that were retried
    last_error: Optional[str] = None
    last_progress_t: float = dataclasses.field(default_factory=clock)
    stalled: bool = False
    closed: bool = False

    def progress(self) -> None:
        self.consecutive_failures = 0
        self.last_progress_t = clock()

    def failure(self, exc: BaseException) -> None:
        self.failures += 1
        self.consecutive_failures += 1
        self.last_error = f"{type(exc).__name__}: {exc}"

    def seconds_since_progress(self) -> float:
        return clock() - self.last_progress_t

    def report(self) -> str:
        state = "stalled" if self.stalled else (
            "closed" if self.closed else "live")
        msg = (f"{self.name or 'worker'}: {state}, "
               f"{self.consecutive_failures} consecutive failures "
               f"({self.failures} total, {self.retries} retried), "
               f"last progress {self.seconds_since_progress():.1f}s ago")
        if self.last_error:
            msg += f", last error: {self.last_error}"
        return msg


# --------------------------------------------------------------------------- #
#  the policy
# --------------------------------------------------------------------------- #

#: exception types retrying cannot fix — give up immediately.
_FATAL_TYPES = (FatalIOError, StageFailure, ValueError, TypeError,
                IndexError, KeyError, AssertionError, NotImplementedError,
                MemoryError, ArithmeticError)

#: exception types worth retrying (flaky disk / transport).
_TRANSIENT_TYPES = (OSError, TimeoutError, BufferError, ConnectionError)


@dataclasses.dataclass(frozen=True)
class IOPolicy:
    """Retry/backoff/deadline policy shared by all runtime I/O paths.

    ``run(op, fn)`` executes ``fn`` with up to ``max_retries`` retries of
    transient errors, exponential backoff with deterministic jitter, and
    a per-op wall-clock deadline. Control-flow exceptions
    (``KeyboardInterrupt``/``SystemExit``) always propagate untouched —
    they are never latched, retried, or wrapped.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.01
    backoff_max_s: float = 1.0
    jitter: float = 0.5               # +- fraction of the backoff step
    op_deadline_s: float = 30.0       # wall-clock budget per op incl. retries
    get_timeout_s: float = 60.0       # consumer-side get() default timeout
    seed: int = 0

    def classify(self, exc: BaseException) -> str:
        """"transient" (retry) or "fatal" (give up). Unknown types are
        fatal — retrying an error we cannot name hides bugs."""
        if isinstance(exc, _FATAL_TYPES):
            return "fatal"
        if isinstance(exc, _TRANSIENT_TYPES):
            return "transient"
        return "fatal"

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered."""
        base = min(self.backoff_base_s * (2.0 ** (attempt - 1)),
                   self.backoff_max_s)
        if self.jitter <= 0:
            return base
        return base * (1.0 + self.jitter * (2.0 * rng.random() - 1.0))

    def run(self, op: str, fn: Callable[[], T], *,
            reopen: Optional[Callable[[], None]] = None,
            health: Optional[WorkerHealth] = None) -> T:
        """Run ``fn`` under this policy; returns its value.

        ``reopen`` (e.g. re-mmap a layer file) runs best-effort before
        each retry. ``health`` accumulates failure/retry counts.
        Raises :class:`FatalIOError` (fatal error or retries exhausted)
        or :class:`StallTimeout` (deadline exceeded); the underlying
        error is chained as ``__cause__``.
        """
        rng = random.Random((self.seed << 20) ^ (hash(op) & 0xFFFFF))
        deadline = clock() + self.op_deadline_s
        attempt = 0
        while True:
            try:
                out = fn()
            except (KeyboardInterrupt, SystemExit):
                raise                   # control flow, never I/O policy's
            except BaseException as e:
                attempt += 1
                if health is not None:
                    health.failure(e)
                if self.classify(e) != "transient":
                    raise FatalIOError(
                        f"{op}: fatal error after {attempt} attempt(s): "
                        f"{type(e).__name__}: {e}",
                        op=op, attempts=attempt) from e
                if attempt > self.max_retries:
                    raise FatalIOError(
                        f"{op}: retries exhausted "
                        f"({self.max_retries} retries): "
                        f"{type(e).__name__}: {e}",
                        op=op, attempts=attempt) from e
                now = clock()
                if now >= deadline:
                    raise StallTimeout(
                        f"{op}: deadline {self.op_deadline_s:.1f}s exceeded "
                        f"after {attempt} attempt(s): "
                        f"{type(e).__name__}: {e}",
                        op=op, attempts=attempt) from e
                if health is not None:
                    health.retries += 1
                delay = min(self.backoff_s(attempt, rng),
                            max(deadline - now, 0.0))
                if delay > 0:
                    time.sleep(delay)
                if reopen is not None:
                    try:
                        reopen()
                    except (KeyboardInterrupt, SystemExit):
                        raise
                    except BaseException:
                        pass            # next attempt surfaces the error
                continue
            if health is not None:
                health.progress()
            return out


#: a policy tuned for tests/benchmarks: fast backoff, short deadlines.
FAST_TEST_POLICY = IOPolicy(max_retries=3, backoff_base_s=0.002,
                            backoff_max_s=0.02, op_deadline_s=5.0,
                            get_timeout_s=10.0)
