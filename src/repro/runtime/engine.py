"""Continuous-batching serving engine.

The paper serves a single stream; a production framework multiplexes many
requests into the fixed-width decode batch the ring step compiles for.
This engine implements slot-based continuous batching over any
``(prefill_fn, decode_fn)`` pair:

  * fixed B decode slots (the compiled ring batch width);
  * arriving requests are prefilled (padded batch of 1..B) and their KV
    written into free slots; finished sequences free their slot
    immediately — no head-of-line blocking on long generations;
  * per-slot position counters feed the ring's ``ln`` vector; inactive
    slots are masked out of sampling.

The engine is deliberately runtime-agnostic: tests drive it with the
pure single-device model functions; ``launch/serve.py`` can drive it
with the jitted ring step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .telemetry import NULL_TRACER, clock


@dataclasses.dataclass
class SlotState:
    uid: Optional[int] = None        # request id (None = free)
    remaining: int = 0               # tokens still to generate
    generated: Optional[List[int]] = None
    proposed: int = 0                # draft tokens proposed (speculative)
    accepted: int = 0                # draft tokens accepted (speculative)
    session: Optional[str] = None    # park the slot's KV under this key


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    tokens: List[int]
    proposed: int = 0                # speculative bookkeeping (0 = vanilla)
    accepted: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)


@dataclasses.dataclass
class RejectedRequest:
    """A request the engine shed instead of admitting (graceful
    degradation): the pool can never hold it, or its admit starved past
    the deferral TTL. ``reason`` is the operator-facing explanation;
    ``code`` is the machine-facing classification (``shed_capacity`` —
    even an empty pool could never hold it; ``deferred_ttl_expired`` —
    admission starved past the deferral TTL) so load benchmarks can gate
    "zero OOM" without conflating admission control with failures."""

    uid: int
    reason: str
    code: str = "shed_capacity"


class ContinuousBatcher:
    """Slot-multiplexed decode over a fixed-width batch.

    prefill_one(prompt (1,S) int32) -> (first_token int, slot_cache)
        runs the prompt and returns per-layer KV for ONE sequence.
    write_slot(cache, slot_cache, slot_idx, length) -> cache
        installs a prefilled sequence into batch slot ``slot_idx``.
    decode(cache, tokens (B,1)) -> (logits (B,1,V), cache)

    ``spec``: optional ``runtime.speculative.SpeculativeDecoder``. When
    set, each step runs one draft/verify cycle instead of one decode —
    every occupied slot advances by 1..gamma+1 tokens per step while the
    emitted streams stay byte-identical to vanilla greedy decode. The
    decoder owns the draft-side cache; per-slot acceptance counters land
    on ``SlotState``/``FinishedRequest``.

    ``source``: optional ``runtime.paramstore.ParamSource`` the decode
    callables pull weights from (``streaming.make_streaming_engine``
    wires this). The engine itself stays weight-agnostic; holding the
    source lets callers reach prefetch statistics
    (``engine.streaming_stats()``) and guarantees its lifetime spans the
    serving loop.

    ``ctx``: the dense cache's ``max_len``. When set, ``admit`` rejects a
    request whose ``len(prompt) + max_new`` cannot fit — the dense cache
    would otherwise silently clip into its clamped last slot. Leave it
    None only for rolling-SWA caches, whose capacity is a window, not a
    limit.

    ``kv``: optional ``runtime.kvcache.PagedKVCache``. When set, the
    threaded cache is the paged pytree and ``decode`` must be the paged
    step: ``admit`` reserves pages (prefix-sharing identical prompt
    prefixes) before the prefill and scatters the result in; every step
    grows/copy-on-writes the write range first; ``_finish`` returns the
    slot's pages to the pool (hashed prompt pages fall into the prefix
    cache). Admission is alloc-on-demand — the only rejections are a
    request larger than the slot's block table and pool exhaustion.
    """

    def __init__(self, batch: int, prefill_one: Callable,
                 write_slot: Callable, decode: Callable,
                 *, eos_id: Optional[int] = None, spec=None, source=None,
                 ctx: Optional[int] = None, kv=None, tracer=None,
                 metrics=None, prefill_chunk: Optional[int] = None,
                 chunk_step: Optional[Callable] = None):
        self.B = batch
        self.prefill_one = prefill_one
        self.write_slot = write_slot
        self.decode = decode
        self.eos_id = eos_id
        self.spec = spec
        self.source = source
        self.ctx = ctx
        self.kv = kv
        #: chunked admission (paged only): process prompts in chunks of
        #: this many tokens via ``chunk_step(view, tokens, write)`` —
        #: KV written straight into the slot's pages, one decode step
        #: for the active slots interleaved between chunks
        self.prefill_chunk = prefill_chunk
        self.chunk_step = chunk_step
        if prefill_chunk is not None and (kv is None
                                          or chunk_step is None):
            raise ValueError(
                "prefill_chunk requires a paged cache (kv) and a "
                "chunk_step callable")
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics
        self._tracker = None
        if metrics is not None:
            from .metrics import RequestTracker
            self._tracker = RequestTracker(metrics)
            metrics.add_source("engine", self.sample_gauges)
        self.slots = [SlotState() for _ in range(batch)]
        self.finished: List[FinishedRequest] = []
        self.rejected: List[RejectedRequest] = []
        self._step_idx = 0
        self._queued_n = 0               # pending requests (gauge)
        self._deferred_n = 0             # admits deferred on pool pressure
        self._spec_proposed = 0
        self._spec_accepted = 0

    def telemetry(self):
        """The attached tracer (NULL_TRACER when tracing is off)."""
        return self.tracer

    def streaming_stats(self):
        """Prefetch statistics of the attached streaming source (or None)."""
        if self.source is not None and hasattr(self.source, "stats"):
            return self.source.stats()
        return None

    def sample_gauges(self) -> Dict[str, float]:
        """Gauge sample for ``MetricsRegistry.add_source``: batcher slot
        occupancy, BlockPool pages + prefix-hit rate, TierManager
        used/peak bytes, speculative acceptance, and I/O retry counts —
        cheap field reads only (no stats() object construction)."""
        g: Dict[str, float] = {
            "slots/active": float(len(self.active())),
            "slots/free": float(len(self.free_slots())),
            "queue/pending": float(self._queued_n),
            "queue/deferred": float(self._deferred_n),
        }
        if self.spec is not None:
            g["spec/acceptance_rate"] = (
                self._spec_accepted / max(self._spec_proposed, 1))
        kv = self.kv
        if kv is not None:
            pool = kv.pool
            g["kv/pages_active"] = float(pool.n_active)
            g["kv/pages_free"] = float(pool.n_free)
            g["kv/pages_cached"] = float(pool.n_cached)
            looks = kv.prefix_hits + pool.alloc_count
            g["kv/prefix_hit_rate"] = kv.prefix_hits / max(looks, 1)
            offl = getattr(kv, "offloader", None)
            if offl is not None and hasattr(offl, "health"):
                g["io/kv_retries"] = float(offl.health.retries)
            mem = getattr(kv, "memory", None)
            if mem is not None:
                for tier, st in mem.stats().items():
                    g[f"mem/{tier}/used_bytes"] = float(st.used)
                    g[f"mem/{tier}/peak_bytes"] = float(st.peak)
        src = self.source
        if src is not None and hasattr(src, "health"):
            g["io/stream_retries"] = float(src.health.retries)
        return g

    # ------------------------------------------------------------------ #

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.uid is None]

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.uid is not None]

    def admit(self, cache, tokens: jnp.ndarray, uid: int,
              prompt: np.ndarray, max_new: int,
              session: Optional[str] = None):
        """Prefill ``prompt`` and place it in a free slot.

        Dense caches validate ``len(prompt) + max_new`` against ``ctx``
        up front (a clear error instead of a silent clip); the paged path
        allocates on demand and rejects only a request that exceeds the
        slot's block table or exhausts the pool. Speculative engines add
        ``gamma`` headroom on the paged path — a verify pass transiently
        writes up to gamma positions past the budget before rollback.

        ``session`` names a multi-turn conversation on a parking-enabled
        paged cache: at finish the slot's KV parks to host/disk under
        this key instead of being discarded, and a later admit with the
        same key restores it byte-identically and continues decoding —
        the prompt is ignored on restore (the parked state already
        contains it) and the first decode step resumes from the parked
        resume token, so the concatenated token stream is exactly what
        one uninterrupted request would have produced.
        """
        if session is not None and self.spec is not None:
            raise ValueError(
                "session parking and speculative decoding cannot be "
                "combined: the draft cache is not parked, so a restored "
                "slot would verify against a cold draft")
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        tr = self._tracker
        if tr is not None:
            tr.submit(uid, prompt_len=len(prompt))   # no-op if already seen
        t_admit = clock() if tr is not None else 0.0
        if self.kv is not None and session is not None \
                and self.kv.is_parked(session):
            cache, meta, length = self.kv.restore_session(
                cache, slot, session, max_new=max_new)
            resume = int(meta["resume_token"])
            tokens = tokens.at[slot, 0].set(resume)
            # the resume token's KV is written by the first decode step,
            # exactly as the last generated token's would have been — so
            # remaining counts the full max_new and generated starts
            # empty (the token was already emitted last turn).
            self.slots[slot] = SlotState(uid=uid, remaining=max_new,
                                         generated=[], session=session)
            if tr is not None:
                tr.admitted(uid, restored=True)
                tr.prefill_done(uid, clock() - t_admit)
            return cache, tokens
        if self.kv is not None and self.prefill_chunk is not None:
            self.kv.plan_admit(
                cache, slot, [int(t) for t in np.asarray(prompt)],
                max_new + (self.spec.gamma if self.spec else 0),
                register=False)
            try:
                cache, tokens, first_tok = self._chunked_prefill(
                    cache, tokens, slot, np.asarray(prompt), uid)
            except BaseException:
                # a failed chunk must not leak the planned pages
                self.kv.abort_admit(slot)
                raise
        elif self.kv is not None:
            margin = self.spec.gamma if self.spec is not None else 0
            self.kv.plan_admit(cache, slot,
                               [int(t) for t in np.asarray(prompt)],
                               max_new + margin)
            try:
                first_tok, slot_cache = self.prefill_one(
                    jnp.asarray(prompt)[None, :])
                cache = self.kv.install(cache, slot, slot_cache["layers"],
                                        len(prompt))
            except BaseException:
                # a failed prefill must not leak the planned pages
                self.kv.abort_admit(slot)
                raise
        else:
            if self.ctx is not None and len(prompt) + max_new > self.ctx:
                raise ValueError(
                    f"request {uid}: prompt ({len(prompt)}) + max_new "
                    f"({max_new}) exceeds the cache context ({self.ctx}); "
                    f"the preallocated cache would silently clip — raise "
                    f"ctx or trim the request")
            first_tok, slot_cache = self.prefill_one(
                jnp.asarray(prompt)[None, :])
            cache = self.write_slot(cache, slot_cache, slot, len(prompt))
        if self.spec is not None:
            self.spec.admit(jnp.asarray(prompt)[None, :], slot, len(prompt))
        tokens = tokens.at[slot, 0].set(first_tok)
        self.slots[slot] = SlotState(uid=uid, remaining=max_new - 1,
                                     generated=[int(first_tok)],
                                     session=session)
        if tr is not None:
            tr.admitted(uid)
            tr.prefill_done(uid, clock() - t_admit)
            tr.token(uid)                # prefill emits the first token
        return cache, tokens

    def _chunked_prefill(self, cache, tokens, slot: int,
                         prompt: np.ndarray, uid: int):
        """Admit one prompt in page-sized chunks computed straight into
        the slot's planned pages, interleaving one decode step for the
        active slots between chunks — the long-admit TPOT spike becomes
        a bounded per-chunk stall. The leading prefix-shared pages are
        skipped entirely (their KV is already resident); a fully shared
        prompt re-derives its last-position logits read-only. Returns
        ``(cache, tokens, first_token)``.
        """
        kv = self.kv
        S = len(prompt)
        cache, skip = kv.begin_chunked_admit(cache, slot, S)
        table1 = jnp.asarray(kv.chunk_table(slot))
        o, write = skip, True
        if skip >= S:
            # whole prompt prefix-shared: nothing to write, but the
            # first token still needs the final position's logits
            o, write = S - 1, False
        logits = None
        n_chunks = 0
        while o < S:
            c = min(self.prefill_chunk, S - o)
            view = {"pages": cache["pages"], "block_table": table1,
                    "len": jnp.full((1,), o, jnp.int32)}
            t0 = clock()
            with self.tracer.span(f"prefill-chunk[{uid}:{n_chunks}]",
                                  cat="compute", track="decode", uid=uid):
                logits, view = self.chunk_step(
                    view, jnp.asarray(prompt[o:o + c])[None, :], write)
                logits.block_until_ready()
            cache = {**cache, "pages": view["pages"]}
            n_chunks += 1
            o += c
            if o < S and self.active():
                # active decode slots stalled for exactly one chunk;
                # give them a step before the next one
                if self._tracker is not None:
                    self._tracker.interleave_stall(clock() - t0)
                cache, tokens = self.step(cache, tokens)
        first_tok = int(jnp.argmax(logits[0, -1]))
        cache = kv.finish_chunked_admit(cache, slot, S)
        if self._tracker is not None:
            self._tracker.prefill_chunks(uid, n_chunks)
        return cache, tokens, first_tok

    def _finish(self, i: int, cache):
        st = self.slots[i]
        self.finished.append(
            FinishedRequest(uid=st.uid, tokens=st.generated,
                            proposed=st.proposed, accepted=st.accepted))
        if self._tracker is not None:
            self._tracker.finished(st.uid)
        self.slots[i] = SlotState()                      # free immediately
        if self.kv is not None:
            if st.session is not None and self.kv.parking and st.generated:
                from .iopolicy import BudgetExceeded
                try:
                    self.kv.park_session(
                        cache, i, st.session,
                        meta={"resume_token": int(st.generated[-1])})
                    return cache
                except BudgetExceeded:
                    # no tier can hold the parked bytes — degrade to a
                    # normal finish; the next turn re-prefills from
                    # scratch instead of failing the current one.
                    self.tracer.instant(f"park-refused[{st.session}]",
                                        cat="sched", track="decode")
            self.kv.release_slot(i)
        return cache

    def kv_stats(self):
        """Allocator statistics of the attached paged cache (or None)."""
        return self.kv.stats() if self.kv is not None else None

    def step(self, cache, tokens: jnp.ndarray):
        """One decode step for every occupied slot.

        Each step is one token-step scope on the tracer: the decode +
        argmax (host-synced) charge to ``compute``, stalls inside the
        decode callable (prefetcher waits, KV fetches) attribute to
        their own components, and the remainder books as scheduler
        idle. Token-step records partition measured TPOT.
        """
        t0 = clock() if self._tracker is not None else 0.0
        with self.tracer.token_step(self._step_idx, track="decode"):
            self._step_idx += 1
            if self.spec is not None:
                out = self._spec_step(cache, tokens)
            else:
                out = self._vanilla_step(cache, tokens)
        if self._tracker is not None:
            self._tracker.step_done(clock() - t0)
        return out

    def _vanilla_step(self, cache, tokens: jnp.ndarray):
        if self.kv is not None:
            cache = self.kv.begin_step(cache, self.active(), 1)
        with self.tracer.phase("compute", track="decode"):
            logits, cache = self.decode(cache, tokens)
            nxt = jnp.argmax(logits[:, 0], axis=-1)      # greedy
            nxt_host = np.asarray(nxt)                   # force the sync
        tokens = nxt[:, None].astype(tokens.dtype)
        for i in self.active():
            st = self.slots[i]
            tok = int(nxt_host[i])
            if self.kv is not None:
                self.kv.advance(i)
            st.generated.append(tok)
            if self._tracker is not None:
                self._tracker.token(st.uid)
            st.remaining -= 1
            if st.remaining <= 0 or (self.eos_id is not None
                                     and tok == self.eos_id):
                cache = self._finish(i, cache)
        return cache, tokens

    def _spec_step(self, cache, tokens: jnp.ndarray):
        """One draft/verify cycle: every occupied slot advances by up to
        gamma+1 tokens. Tokens emitted past a slot's budget (or past EOS)
        are dropped — the slot frees immediately, exactly like vanilla."""
        len0 = {}
        if self.kv is not None:
            # the verify pass writes gamma+1 positions before rollback
            cache = self.kv.begin_step(cache, self.active(),
                                       self.spec.gamma + 1)
            len0 = {i: self.kv.length(i) for i in self.active()}
        with self.tracer.phase("compute", track="decode"):
            cache, res = self.spec.cycle(cache, tokens,
                                         active=self.active())
            n_emit_host = np.asarray(res.n_emit)         # force the sync
        tokens = res.next_tokens.astype(tokens.dtype)
        accepted = proposed = 0
        for i in self.active():
            st = self.slots[i]
            n = int(n_emit_host[i])
            if self.kv is not None:
                # pages past the accepted length return to the pool — the
                # allocator half of the rollback (len was already reset)
                self.kv.trim_to(i, len0[i] + n)
            # counters estimate draft/target *agreement* (the acceptance
            # probability behind E[tokens/cycle]), so verified-but-
            # truncated drafts still count — truncation doesn't bias the
            # agreement sample.
            st.proposed += self.spec.gamma
            st.accepted += n - 1
            proposed += self.spec.gamma
            accepted += n - 1
            for tok in res.emitted[i, :n]:
                tok = int(tok)
                st.generated.append(tok)
                if self._tracker is not None:
                    self._tracker.token(st.uid)
                st.remaining -= 1
                if st.remaining <= 0 or (self.eos_id is not None
                                         and tok == self.eos_id):
                    cache = self._finish(i, cache)
                    break
        if proposed:
            self._spec_proposed += proposed
            self._spec_accepted += accepted
            self.tracer.counter("spec/proposed", proposed, track="decode")
            self.tracer.counter("spec/accepted", accepted, track="decode")
        return cache, tokens

    def run(self, cache, requests, *, max_steps: int = 10_000,
            admit_patience: int = 256, respect_arrivals: bool = False):
        """Drive a request list (sorted by arrival) to completion.

        On the paged path a transiently exhausted pool (pages held by
        slots still decoding) defers the admit until finishes free pages;
        it only propagates when no active slot could ever free any.
        Deferral is bounded: a request that cannot fit an *empty* pool
        (``kv.can_ever_admit``) or whose admit has been refused for
        ``admit_patience`` consecutive steps is shed onto
        ``self.rejected`` with a clear "pool too small for request"
        error instead of starving the run.

        ``respect_arrivals=True`` replays each request's ``arrival_s``
        offset against the wall clock (load benchmarks): a request is
        invisible to admission until its arrival passes, its metrics
        ``submit`` timestamp is its arrival instant (so TTFT includes
        real queue wait), and an idle engine sleeps until the next
        arrival instead of burning decode steps.
        """
        import time as _time

        from .kvcache import PoolExhausted

        tokens = jnp.zeros((self.B, 1), jnp.int32)
        pending = list(requests)
        if respect_arrivals:
            pending.sort(key=lambda r: getattr(r, "arrival_s", 0.0))
        deferrals: Dict[int, int] = {}
        steps = 0
        t_start = clock()

        def arrived(req):
            return (not respect_arrivals
                    or getattr(req, "arrival_s", 0.0)
                    <= clock() - t_start)

        while (pending or self.active()) and steps < max_steps:
            if self._tracker is not None:
                for req in pending:
                    if not arrived(req):
                        break
                    self._tracker.submit(
                        req.uid,
                        t=t_start + getattr(req, "arrival_s", 0.0),
                        prompt_len=len(req.prompt))
            while pending and self.free_slots() and arrived(pending[0]):
                req = pending.pop(0)
                try:
                    with self.tracer.span(f"admit[{req.uid}]", cat="sched",
                                          track="decode", uid=req.uid):
                        cache, tokens = self.admit(
                            cache, tokens, req.uid, req.prompt,
                            req.max_new_tokens,
                            session=getattr(req, "session", None))
                    deferrals.pop(req.uid, None)
                except PoolExhausted as e:
                    if not self.active():
                        raise              # nothing will ever free pages
                    margin = self.spec.gamma if self.spec is not None \
                        else 0
                    if self.kv is not None and not self.kv.can_ever_admit(
                            len(req.prompt),
                            req.max_new_tokens + margin):
                        # deferring cannot help: even an empty pool is
                        # too small — shed now with the classified reason
                        self._shed(req.uid, "shed_capacity",
                                   f"pool too small for request "
                                   f"{req.uid}: {e}")
                        self.tracer.instant(f"reject[{req.uid}]",
                                            cat="sched", track="decode",
                                            uid=req.uid,
                                            reason="pool too small")
                        continue
                    n = deferrals.get(req.uid, 0) + 1
                    if n > admit_patience:
                        deferrals.pop(req.uid, None)
                        self._shed(req.uid, "deferred_ttl_expired",
                                   f"pool too small for request "
                                   f"{req.uid}: admission deferred "
                                   f"{n - 1} consecutive steps without "
                                   f"a slot freeing enough pages ({e})")
                        self.tracer.instant(f"reject[{req.uid}]",
                                            cat="sched", track="decode",
                                            uid=req.uid,
                                            reason="admit starved")
                        continue
                    deferrals[req.uid] = n
                    pending.insert(0, req)
                    break
            self._queued_n = len(pending)
            self._deferred_n = len(deferrals)
            if self.active():
                cache, tokens = self.step(cache, tokens)
            elif respect_arrivals and pending:
                # idle until the next arrival — a waiting engine burns
                # neither decode steps nor the step budget
                next_t = t_start + getattr(pending[0], "arrival_s", 0.0)
                _time.sleep(min(max(next_t - clock(), 0.0), 0.005))
                if self.kv is not None and self.kv.parking:
                    self.kv.sweep_parked()
                continue
            if self.kv is not None and self.kv.parking:
                self.kv.sweep_parked()
            if self.metrics is not None:
                self.metrics.sample()
            steps += 1
        self._queued_n = 0
        self._deferred_n = 0
        return self.finished, steps

    def _shed(self, uid: int, code: str, reason: str) -> None:
        self.rejected.append(
            RejectedRequest(uid=uid, reason=reason, code=code))
        if self._tracker is not None:
            self._tracker.rejected(uid, code, reason)


def make_dense_engine(params, cfg, batch: int, ctx: int, *,
                      eos_id: Optional[int] = None, spec=None,
                      cache_dtype=jnp.float32,
                      tracer=None, metrics=None) -> ContinuousBatcher:
    """Reference dense-cache engine wiring (prefill-one / slot-write /
    decode over ``models.decode_step``) — the single source of the
    slot-write convention, shared by the serving driver, benchmarks and
    tests. Drive it with ``eng.run(init_cache(cfg, batch, ctx), reqs)``.
    """
    from ..models import model as M

    def prefill_one(prompt):
        c1 = M.init_cache(cfg, 1, ctx, dtype=cache_dtype)
        logits, c1 = M.prefill(params, cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == batch \
                    and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new

    def decode(cache, tokens):
        return M.decode_step(params, cfg, cache, tokens)

    return ContinuousBatcher(batch, prefill_one, write_slot, decode,
                             eos_id=eos_id, spec=spec, ctx=ctx,
                             tracer=tracer, metrics=metrics)
