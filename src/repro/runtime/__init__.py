from . import (checkpoint, elastic, failover, faults, iopolicy, kvcache,
               optim, paramstore, serve, sharding, streaming, telemetry,
               train)  # noqa
