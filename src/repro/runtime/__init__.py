from . import (checkpoint, elastic, kvcache, optim, paramstore, serve,
               sharding, streaming, train)  # noqa
