from . import checkpoint, elastic, optim, serve, sharding, train  # noqa
