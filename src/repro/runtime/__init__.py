from . import (checkpoint, elastic, optim, paramstore, serve, sharding,
               streaming, train)  # noqa
