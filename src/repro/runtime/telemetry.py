"""Unified runtime telemetry: spans, counters, per-token stall
attribution, and Chrome-trace (Perfetto) export.

The paper's claims are *latency* claims — 674 ms/token with disk I/O,
compute and Wi-Fi-class comms overlapped — yet until this module every
subsystem kept private ad-hoc stats on inconsistent clocks
(``faults.FiredFault`` on ``perf_counter``, ``iopolicy.WorkerHealth`` on
``monotonic``, prefetch timelines on ``perf_counter``), so there was no
way to lay a token's milliseconds on one timeline. This module is the
shared measurement substrate:

  * **one clock** — :func:`clock` (``time.perf_counter``). Every
    timestamp in the runtime (prefetch events, fault audit trails,
    worker-health progress, failover splits) takes it, so records from
    different subsystems merge into one ordered timeline.
  * **a tracer** — :class:`Tracer`: a thread-safe *bounded ring buffer*
    of typed events (:class:`SpanEvent` / :class:`CounterEvent` /
    :class:`InstantEvent`). Near-zero overhead when disabled (one
    attribute check per call site, no allocation, no lock); optional
    deterministic 1-in-N sampling when enabled. The buffer never grows
    past ``capacity`` — a week-long serve cannot OOM on its own
    telemetry; ``evicted`` counts what wrapped away.
  * **per-token stall attribution** — :meth:`Tracer.token_step` opens a
    step scope on the calling thread; :meth:`Tracer.phase` calls inside
    it (from *any* instrumented callee — the prefetcher's blocked
    ``get()``, the engine's jitted decode call) accumulate **exclusive**
    time per component (``disk_wait``, ``staging_copy``, ``h2d``,
    ``compute``, ``comms``) with the remainder booked to ``sched_idle``,
    so the components sum to the measured step wall time *by
    construction*. The resulting :class:`StallRecord` stream is the
    per-token answer to "where did the milliseconds go".
  * **Chrome trace export** — :meth:`Tracer.chrome_trace` /
    :meth:`Tracer.export_chrome_trace` emit Chrome Trace Event Format
    JSON (one track per worker thread / ring stage) that loads directly
    in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Legacy record types (``PrefetchEvent`` timelines, ``FiredFault`` audit
trails, ``FailoverEvent`` recovery splits, ``WorkerHealth``) are
subsumed via the ``ingest_*`` adapters — they become spans/instants on
the shared timeline — while the hot paths also emit live when a tracer
is attached. ``core.latency.telemetry_crosscheck`` closes the loop by
comparing the measured per-term splits against the Halda latency
model's disk/compute/comms terms (the drift signal ROADMAP item 4's
online re-solve consumes).

Validator CLI (used by CI's trace smoke)::

    python -m repro.runtime.telemetry --validate trace.json \\
        --require prefetcher decode
"""
from __future__ import annotations

import dataclasses
import json
import logging
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

#: the one runtime clock. Monotonic, high resolution, and — crucially —
#: the SAME base every subsystem stamps against (``PrefetchEvent``
#: already used ``perf_counter``; ``faults``/``iopolicy`` now route
#: through here instead of mixing in ``time.monotonic``).
clock = time.perf_counter

#: canonical stall-attribution components. ``phase()`` names outside
#: this set accumulate into ``other``; the un-phased remainder of a step
#: is ``sched_idle``. Together they partition the step wall time.
COMPONENTS = ("disk_wait", "staging_copy", "h2d", "compute", "comms",
              "sched_idle", "other")


# --------------------------------------------------------------------------- #
#  typed event schema
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """A named interval on one track (Chrome ``ph="X"``)."""

    name: str
    cat: str
    track: str
    t_start: float
    t_end: float
    args: Tuple[Tuple[str, Any], ...] = ()

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclasses.dataclass(frozen=True)
class CounterEvent:
    """A sampled scalar (Chrome ``ph="C"`` — a value-over-time graph)."""

    name: str
    track: str
    t: float
    value: float


@dataclasses.dataclass(frozen=True)
class InstantEvent:
    """A point event (Chrome ``ph="i"`` — e.g. a fired fault)."""

    name: str
    cat: str
    track: str
    t: float
    args: Tuple[Tuple[str, Any], ...] = ()


TraceEvent = Union[SpanEvent, CounterEvent, InstantEvent]


@dataclasses.dataclass(frozen=True)
class StallRecord:
    """Per-token (per-step) stall attribution.

    Exclusive seconds per component; ``sched_idle_s`` is the measured
    wall time not inside any phase, so the components always sum to
    ``wall_s`` up to float rounding — the benchmark gate checks the sum
    against independently-measured TPOT.
    """

    index: int                    # token/step index
    t_start: float
    t_end: float
    disk_wait_s: float = 0.0      # front blocked waiting on a layer/bank
    staging_copy_s: float = 0.0   # synchronous host staging copies
    h2d_s: float = 0.0            # synchronous host->device transfers
    compute_s: float = 0.0        # jitted kernel/step calls
    comms_s: float = 0.0          # ring hops measured outside compute
    sched_idle_s: float = 0.0     # engine bookkeeping / python overhead
    other_s: float = 0.0          # non-canonical phase names

    @property
    def wall_s(self) -> float:
        return self.t_end - self.t_start

    @property
    def accounted_s(self) -> float:
        return (self.disk_wait_s + self.staging_copy_s + self.h2d_s
                + self.compute_s + self.comms_s + self.sched_idle_s
                + self.other_s)

    def component(self, name: str) -> float:
        return getattr(self, f"{name}_s")


def stall_summary(records: Sequence[StallRecord]) -> Dict[str, float]:
    """Mean seconds per component over a record stream, plus ``wall``
    (mean TPOT) and ``n`` — the shape ``telemetry_crosscheck`` and the
    ``--metrics-interval`` report consume."""
    out = {c: 0.0 for c in COMPONENTS}
    out["wall"] = 0.0
    out["n"] = float(len(records))
    if not records:
        return out
    for r in records:
        for c in COMPONENTS:
            out[c] += r.component(c)
        out["wall"] += r.wall_s
    for k in (*COMPONENTS, "wall"):
        out[k] /= len(records)
    return out


def format_summary(summary: Dict[str, float]) -> str:
    """One operator-facing line: mean TPOT and its split."""
    wall = summary.get("wall", 0.0)
    parts = ", ".join(
        f"{c} {summary.get(c, 0.0) * 1e3:.2f}" for c in COMPONENTS
        if summary.get(c, 0.0) > 0.0)
    return (f"tpot {wall * 1e3:.2f} ms over {int(summary.get('n', 0))} "
            f"steps [{parts} ms]")


# --------------------------------------------------------------------------- #
#  token-step scope (stall attribution)
# --------------------------------------------------------------------------- #

class TokenStep:
    """Open step scope: exclusive-time phase accounting on one thread.

    Entering a nested phase *pauses* the enclosing one (the prefetcher's
    ``disk_wait`` inside the engine's ``compute`` is charged to
    ``disk_wait``, not double-counted), so the recorded components
    partition the phased time exactly.
    """

    __slots__ = ("index", "track", "t_start", "components", "_stack")

    def __init__(self, index: int, track: str, t_start: float):
        self.index = index
        self.track = track
        self.t_start = t_start
        self.components: Dict[str, float] = {}
        self._stack: List[List[Any]] = []     # [name, t_resumed]

    def enter_phase(self, name: str, t: float) -> None:
        if self._stack:
            top = self._stack[-1]
            self.components[top[0]] = self.components.get(top[0], 0.0) \
                + (t - top[1])
        self._stack.append([name, t])

    def exit_phase(self, t: float) -> None:
        name, t0 = self._stack.pop()
        self.components[name] = self.components.get(name, 0.0) + (t - t0)
        if self._stack:
            self._stack[-1][1] = t

    def finish(self, t_end: float) -> StallRecord:
        while self._stack:                    # abandoned phases (errors)
            self.exit_phase(t_end)
        known = {c: 0.0 for c in COMPONENTS}
        for name, secs in self.components.items():
            known[name if name in known else "other"] += secs
        phased = sum(known.values())
        known["sched_idle"] = max((t_end - self.t_start) - phased, 0.0)
        return StallRecord(
            index=self.index, t_start=self.t_start, t_end=t_end,
            **{f"{c}_s": known[c] for c in COMPONENTS})


# --------------------------------------------------------------------------- #
#  the tracer
# --------------------------------------------------------------------------- #

class Tracer:
    """Thread-safe bounded-ring-buffer span/counter tracer.

    ``enabled=False`` (or :data:`NULL_TRACER`) is the production default:
    every emission path checks the flag first and returns without
    allocating or locking, so instrumentation can stay compiled into the
    hot paths permanently. ``sample=1/N`` keeps every N-th event
    (deterministic — no RNG), bounding trace size on long serves while
    stall attribution (which aggregates, not stores-per-event) stays
    exact.
    """

    def __init__(self, *, enabled: bool = True, capacity: int = 65536,
                 sample: float = 1.0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not (0.0 < sample <= 1.0):
            raise ValueError("sample must be in (0, 1]")
        self.enabled = enabled
        self.capacity = capacity
        self._keep_every = max(1, int(round(1.0 / sample)))
        self._buf: deque = deque(maxlen=capacity)
        self._stalls: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._seq = 0
        self.evicted = 0              # events that wrapped off the ring
        self.stalls_evicted = 0

    # -- clock ------------------------------------------------------------- #

    @staticmethod
    def now() -> float:
        return clock()

    # -- emission ---------------------------------------------------------- #

    def _append(self, ev: TraceEvent) -> None:
        with self._lock:
            self._seq += 1
            if self._keep_every > 1 and self._seq % self._keep_every:
                return
            if len(self._buf) == self.capacity:
                self.evicted += 1
            self._buf.append(ev)

    def span_event(self, name: str, t_start: float, t_end: float, *,
                   cat: str = "span", track: Optional[str] = None,
                   **args) -> None:
        if not self.enabled:
            return
        self._append(SpanEvent(
            name=name, cat=cat, track=track or _thread_track(),
            t_start=t_start, t_end=t_end,
            args=tuple(sorted(args.items()))))

    def instant(self, name: str, *, cat: str = "instant",
                track: Optional[str] = None, t: Optional[float] = None,
                **args) -> None:
        if not self.enabled:
            return
        self._append(InstantEvent(
            name=name, cat=cat, track=track or _thread_track(),
            t=t if t is not None else clock(),
            args=tuple(sorted(args.items()))))

    def counter(self, name: str, value: float, *,
                track: Optional[str] = None,
                t: Optional[float] = None) -> None:
        if not self.enabled:
            return
        self._append(CounterEvent(
            name=name, track=track or _thread_track(),
            t=t if t is not None else clock(), value=float(value)))

    @contextmanager
    def span(self, name: str, *, cat: str = "span",
             track: Optional[str] = None, **args):
        """Time a block as one span. No-op (no clock reads) when
        disabled."""
        if not self.enabled:
            yield
            return
        t0 = clock()
        try:
            yield
        finally:
            self.span_event(name, t0, clock(), cat=cat, track=track,
                            **args)

    # -- stall attribution ------------------------------------------------- #

    @contextmanager
    def token_step(self, index: int, *, track: str = "decode",
                   name: Optional[str] = None, **args):
        """Open a per-token step scope on this thread. ``phase()`` calls
        underneath (in this thread) attribute into it; on exit a
        :class:`StallRecord` is appended and the step is emitted as a
        span on the ``track`` timeline."""
        if not self.enabled:
            yield None
            return
        prev = getattr(self._local, "step", None)
        step = TokenStep(index, track, clock())
        self._local.step = step
        try:
            yield step
        finally:
            t_end = clock()
            self._local.step = prev
            rec = step.finish(t_end)
            with self._lock:
                if len(self._stalls) == self.capacity:
                    self.stalls_evicted += 1
                self._stalls.append(rec)
            self.span_event(name or f"token[{index}]", step.t_start,
                            t_end, cat="decode", track=track,
                            disk_wait_ms=round(rec.disk_wait_s * 1e3, 3),
                            compute_ms=round(rec.compute_s * 1e3, 3),
                            **args)

    def current_step(self) -> Optional[TokenStep]:
        return getattr(self._local, "step", None)

    @contextmanager
    def phase(self, name: str, *, cat: str = "phase",
              track: Optional[str] = None, min_dur: float = 0.0,
              label: Optional[str] = None, **args):
        """Attribute a block to stall component ``name``.

        Inside an open :meth:`token_step` on this thread the exclusive
        duration lands on that step's record; a span is also emitted
        (named ``label`` if given, suppressed under ``min_dur`` — e.g.
        the prefetcher's usually-instant ``disk_wait`` waits only trace
        when they actually stalled). Disabled tracer: straight
        passthrough.
        """
        if not self.enabled:
            yield
            return
        step = getattr(self._local, "step", None)
        t0 = clock()
        if step is not None:
            step.enter_phase(name, t0)
        try:
            yield
        finally:
            t1 = clock()
            if step is not None:
                step.exit_phase(t1)
            if t1 - t0 >= min_dur:
                self.span_event(label or name, t0, t1, cat=cat,
                                track=track, **args)

    # -- snapshots --------------------------------------------------------- #

    def events(self) -> List[TraceEvent]:
        with self._lock:
            return list(self._buf)

    def stalls(self) -> List[StallRecord]:
        with self._lock:
            return list(self._stalls)

    def summary(self, last_n: Optional[int] = None) -> Dict[str, float]:
        recs = self.stalls()
        if last_n is not None:
            recs = recs[-last_n:]
        return stall_summary(recs)

    def tracks(self) -> List[str]:
        seen: Dict[str, None] = {}
        for ev in self.events():
            seen.setdefault(ev.track)
        return list(seen)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._stalls.clear()
            self._seq = 0
            self.evicted = 0
            self.stalls_evicted = 0

    # -- legacy-record ingestion (schema subsumption) ----------------------- #

    def ingest_prefetch_events(self, events: Iterable, *,
                               track: str = "prefetcher",
                               cat: str = "prefetch",
                               name: str = "layer_read") -> int:
        """Merge a ``PrefetchEvent`` timeline (layer prefetcher, ring
        bank prefetcher, or KV offloader — they share the record type and
        the clock) onto the trace as spans. Returns events ingested."""
        n = 0
        for e in events:
            self.span_event(f"{name}[{e.layer}]", e.t_start, e.t_end,
                            cat=cat, track=track, nbytes=e.nbytes)
            n += 1
        return n

    def ingest_fired_faults(self, fired: Iterable, *,
                            track: str = "faults") -> int:
        """``faults.FiredFault`` audit trail -> instant events (same
        clock since the fault injector stamps with ``telemetry.clock``)."""
        n = 0
        for f in fired:
            self.instant(f"fault:{f.mode}:{f.op}", cat="fault",
                         track=track, t=f.t, key=f.key,
                         call_index=f.call_index)
            n += 1
        return n

    def ingest_failover_event(self, ev, *, t_end: Optional[float] = None,
                              track: str = "failover") -> None:
        """``failover.FailoverEvent`` -> its detect/resolve/rebuild/replay
        split as contiguous spans ending at ``t_end`` (default: now)."""
        t1 = t_end if t_end is not None else clock()
        t0 = t1 - ev.recovery_s
        edges = [t0]
        for d in (ev.detect_s, ev.resolve_s, ev.rebuild_s, ev.replay_s):
            edges.append(edges[-1] + d)
        for name, a, b in zip(("detect", "resolve", "rebuild", "replay"),
                              edges[:-1], edges[1:]):
            self.span_event(f"failover/{name}", a, b, cat="failover",
                            track=track, token_index=ev.token_index,
                            failed_stage=ev.failed_stage,
                            stages_after=ev.n_stages_after)

    def ingest_worker_health(self, health, *,
                             track: Optional[str] = None) -> None:
        """``iopolicy.WorkerHealth`` -> an instant + counters on the
        worker's own track (same clock as of this PR)."""
        tr = track or health.name or "worker"
        self.instant(f"health:{health.report()}", cat="health", track=tr,
                     t=health.last_progress_t)
        self.counter("retries", health.retries, track=tr)
        self.counter("failures", health.failures, track=tr)

    # -- Chrome trace (Perfetto) export ------------------------------------ #

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome Trace Event Format dict (``traceEvents`` +
        ``displayTimeUnit``) — loads in Perfetto / chrome://tracing.
        One pid, one tid per track, tracks named via metadata events."""
        events = self.events()
        t0 = min((ev.t_start if isinstance(ev, SpanEvent) else ev.t
                  for ev in events), default=0.0)
        tids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": "repro-runtime"}}]

        def tid(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids) + 1
                out.append({"name": "thread_name", "ph": "M", "pid": 1,
                            "tid": tids[track], "args": {"name": track}})
            return tids[track]

        for ev in events:
            if isinstance(ev, SpanEvent):
                out.append({
                    "name": ev.name, "cat": ev.cat or "span", "ph": "X",
                    "ts": (ev.t_start - t0) * 1e6,
                    "dur": max(ev.duration, 0.0) * 1e6,
                    "pid": 1, "tid": tid(ev.track),
                    "args": dict(ev.args)})
            elif isinstance(ev, CounterEvent):
                out.append({
                    "name": ev.name, "ph": "C",
                    "ts": (ev.t - t0) * 1e6, "pid": 1,
                    "tid": tid(ev.track),
                    "args": {"value": ev.value}})
            else:
                out.append({
                    "name": ev.name, "cat": ev.cat or "instant",
                    "ph": "i", "s": "t", "ts": (ev.t - t0) * 1e6,
                    "pid": 1, "tid": tid(ev.track),
                    "args": dict(ev.args)})
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "metadata": {"evicted": self.evicted,
                             "stalls_evicted": self.stalls_evicted,
                             "complete": self.evicted == 0}}

    def export_chrome_trace(self, path: str) -> str:
        doc = self.chrome_trace()
        if self.evicted:
            # a truncated trace must never pass for a complete one
            logging.getLogger(__name__).warning(
                "trace %s is truncated: ring evicted %d events "
                "(%d token-step stall records) — raise Tracer(capacity=)",
                path, self.evicted, self.stalls_evicted)
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return path


def _thread_track() -> str:
    return threading.current_thread().name


#: the shared disabled tracer: instrumented code defaults to it so the
#: hot paths never branch on ``None``.
NULL_TRACER = Tracer(enabled=False, capacity=1)


# --------------------------------------------------------------------------- #
#  trace validation (CI's trace smoke + the observability benchmark)
# --------------------------------------------------------------------------- #

def validate_chrome_trace(path: str,
                          require_tracks: Sequence[str] = ()
                          ) -> Dict[str, Any]:
    """Parse a Chrome-trace JSON and check schema invariants.

    Raises ``ValueError`` on a malformed trace or a missing required
    track (substring match against thread names, so ``prefetcher``
    matches both the layer and ring-bank prefetchers). Returns a summary
    dict (tracks, event/phase counts) for reporting.
    """
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace "
                         "(missing traceEvents)")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError(f"{path}: empty traceEvents")
    tracks: List[str] = []
    phases: Dict[str, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"{path}: event {i} is not an object")
        ph = ev.get("ph")
        if ph not in ("X", "C", "i", "M", "B", "E"):
            raise ValueError(f"{path}: event {i} has unknown ph {ph!r}")
        phases[ph] = phases.get(ph, 0) + 1
        if ph == "M":
            if ev.get("name") == "thread_name":
                tracks.append(str(ev["args"]["name"]))
            continue
        for key in ("name", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"{path}: event {i} missing {key!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"{path}: event {i} bad ts {ev['ts']!r}")
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            raise ValueError(f"{path}: event {i} bad dur "
                             f"{ev.get('dur')!r}")
    missing = [want for want in require_tracks
               if not any(want in t for t in tracks)]
    if missing:
        raise ValueError(
            f"{path}: required tracks missing: {missing} "
            f"(present: {tracks})")
    evicted = int(doc.get("metadata", {}).get("evicted", 0))
    return {"tracks": tracks, "n_events": len(events), "phases": phases,
            "evicted": evicted}


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate a Chrome-trace JSON exported by "
                    "repro.runtime.telemetry")
    ap.add_argument("--validate", required=True, metavar="TRACE_JSON")
    ap.add_argument("--require", nargs="*", default=(),
                    help="track-name substrings that must be present")
    args = ap.parse_args(argv)
    info = validate_chrome_trace(args.validate, args.require)
    print(f"{args.validate}: valid Chrome trace — "
          f"{info['n_events']} events, tracks {info['tracks']}, "
          f"phases {info['phases']}")
    if info["evicted"]:
        print(f"WARNING: trace is truncated — ring evicted "
              f"{info['evicted']} events")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
