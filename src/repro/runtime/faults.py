"""Deterministic, schedule-driven fault injection for the runtime.

Chaos testing the streaming runtime needs failures that are (a) the
*right* failures — transient disk errors, short reads, stalls, dead
stages — and (b) exactly reproducible, so a chaos test that passes
today fails tomorrow only if the code regressed, never because the dice
rolled differently. The injector here is therefore schedule-driven and
seeded: each :class:`FaultSpec` names an op kind (``layer_read``,
``kv_h2d``, ``kv_d2h``), an activation window (fire after the N-th call,
up to ``times`` firings, ``times=-1`` for a permanent fault), and a
mode:

  * ``error``       — raise ``error_type`` (default :class:`InjectedFault`,
                      an ``OSError`` → transient under ``IOPolicy``);
  * ``short_read``  — raise a :class:`iopolicy.ShortReadError`;
  * ``delay``       — sleep ``delay_s`` then succeed (slow disk);
  * ``stall``       — sleep ``delay_s`` *then raise* (hung read that the
                      deadline must catch);
  * ``stage_failure`` — raise :class:`iopolicy.StageFailure` for
                      ``stage`` (ring failover trigger).

``prob`` (with the injector's seed) thins a schedule
deterministically — two injectors built with the same schedule and seed
fire on exactly the same calls.

:class:`FaultyStore` wraps a ``ParamStore``-like source and routes
``layer()``/``willneed()`` through ``check("layer_read", key=i)``;
``BlockOffloader`` takes the injector directly and checks ``kv_h2d`` /
``kv_d2h`` around its transfers. Everything the chaos suite and
``benchmarks/fault_recovery.py`` exercise goes through this one chokepoint.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple, Type

from .iopolicy import ShortReadError, StageFailure
from .telemetry import NULL_TRACER, clock

OP_KINDS = ("layer_read", "kv_h2d", "kv_d2h", "kv_d2disk", "kv_disk2h")
MODES = ("error", "short_read", "delay", "stall", "stage_failure")


class InjectedFault(OSError):
    """The default injected error: an ``OSError`` subclass so ``IOPolicy``
    classifies it transient (retryable), like a real flaky-disk EIO."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    Matches calls to ``check(op, key)`` where ``op == self.op`` and
    (``self.key is None`` or ``key == self.key``). Among matching calls,
    skips the first ``after``, then fires on up to ``times`` calls
    (``times=-1``: every one — a permanent fault). ``prob < 1`` thins
    the firing set with the injector's seeded RNG.
    """

    op: str                                   # one of OP_KINDS
    mode: str = "error"                       # one of MODES
    key: Optional[Any] = None                 # e.g. layer index; None = any
    after: int = 0                            # matching calls to skip first
    times: int = 1                            # firings budget; -1 = forever
    delay_s: float = 0.05                     # delay/stall duration
    stage: int = 0                            # stage_failure target
    prob: float = 1.0                         # seeded thinning
    message: str = ""
    error_type: Type[BaseException] = InjectedFault

    def __post_init__(self):
        if self.op not in OP_KINDS:
            raise ValueError(f"unknown fault op {self.op!r} "
                             f"(expected one of {OP_KINDS})")
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r} "
                             f"(expected one of {MODES})")


@dataclasses.dataclass(frozen=True)
class FiredFault:
    """Record of one firing, for assertions and bench reports."""

    op: str
    key: Any
    mode: str
    call_index: int          # per-(spec) matching-call counter at firing
    t: float                 # shared telemetry-clock timestamp


class FaultInjector:
    """Thread-safe deterministic injector over a list of FaultSpecs.

    ``check(op, key)`` is called by instrumented I/O paths; it consults
    every spec (so overlapping schedules compose) and fires the first
    one whose window and seeded coin match. ``fired`` records firings on
    the shared telemetry clock (so the audit trail lands on the same
    timeline as prefetch spans and health records); an attached
    ``tracer`` additionally gets a live instant event per firing.
    """

    def __init__(self, schedule: Sequence[FaultSpec], *, seed: int = 0,
                 tracer=None):
        self.schedule = list(schedule)
        self.seed = seed
        self.tracer = tracer or NULL_TRACER
        self.fired: List[FiredFault] = []
        self._lock = threading.Lock()
        self._seen: List[int] = [0] * len(self.schedule)   # matching calls
        self._shot: List[int] = [0] * len(self.schedule)   # firings
        self._rngs = [random.Random((seed << 8) ^ idx)
                      for idx in range(len(self.schedule))]

    # -- bookkeeping ------------------------------------------------------ #

    def counts(self) -> List[Tuple[int, int]]:
        """(matching_calls, firings) per spec — test observability."""
        with self._lock:
            return list(zip(self._seen, self._shot))

    def exhausted(self) -> bool:
        """True when every finite spec has used its firing budget."""
        with self._lock:
            return all(s.times >= 0 and shot >= s.times
                       for s, shot in zip(self.schedule, self._shot))

    # -- the chokepoint --------------------------------------------------- #

    def check(self, op: str, key: Any = None) -> None:
        """Maybe inject a fault for this call; no-op when nothing fires."""
        to_fire: Optional[Tuple[FaultSpec, int]] = None
        with self._lock:
            for idx, spec in enumerate(self.schedule):
                if spec.op != op:
                    continue
                if spec.key is not None and key != spec.key:
                    continue
                seen = self._seen[idx]
                self._seen[idx] = seen + 1
                if seen < spec.after:
                    continue
                if spec.times >= 0 and self._shot[idx] >= spec.times:
                    continue
                if spec.prob < 1.0 and \
                        self._rngs[idx].random() >= spec.prob:
                    continue
                if to_fire is None:      # first matching spec wins
                    self._shot[idx] += 1
                    self.fired.append(FiredFault(
                        op=op, key=key, mode=spec.mode, call_index=seen,
                        t=clock()))
                    to_fire = (spec, seen)
        if to_fire is None:
            return
        spec, seen = to_fire
        self.tracer.instant(f"fault:{spec.mode}:{op}", cat="fault",
                            track="faults", key=key, call_index=seen)
        self._raise(spec, op, key, seen)

    def _raise(self, spec: FaultSpec, op: str, key: Any, seen: int) -> None:
        msg = spec.message or (
            f"injected {spec.mode} fault on {op}"
            f"{f'[{key}]' if key is not None else ''} (call {seen})")
        if spec.mode == "delay":
            time.sleep(spec.delay_s)
            return
        if spec.mode == "stall":
            time.sleep(spec.delay_s)
            raise spec.error_type(msg)
        if spec.mode == "short_read":
            raise ShortReadError(
                msg, layer=key if isinstance(key, int) else -1,
                path=f"<injected:{op}>", expected=1, got=0)
        if spec.mode == "stage_failure":
            raise StageFailure(f"{msg}: stage {spec.stage} unreachable",
                               stage=spec.stage)
        raise spec.error_type(msg)       # mode == "error"


class FaultyStore:
    """ParamStore proxy that routes layer reads through a FaultInjector.

    Wrap the store *before* handing it to a prefetcher / driver:
    ``store = FaultyStore(ParamStore(d), injector)``. Only the read
    chokepoints are instrumented; everything else (``head``,
    ``release``, ``reopen``, attributes like ``n_layers``) delegates.
    """

    def __init__(self, store, injector: FaultInjector):
        self._store = store
        self.injector = injector

    def layer(self, i: int):
        self.injector.check("layer_read", key=i)
        return self._store.layer(i)

    def willneed(self, i: int) -> None:
        # prefetch hints share the disk path but are advisory; only
        # hard faults on the actual read matter, so hints stay clean.
        self._store.willneed(i)

    def reopen(self, i: int) -> None:
        reopen = getattr(self._store, "reopen", None)
        if reopen is not None:
            reopen(i)

    def __getattr__(self, name):
        return getattr(self._store, name)

    def __enter__(self) -> "FaultyStore":
        return self

    def __exit__(self, *exc) -> None:
        self._store.close()
