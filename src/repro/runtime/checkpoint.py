"""Checkpoint/restore for fault tolerance.

Atomic (write-to-temp + rename) npz checkpoints of arbitrary pytrees
(params, optimizer state, KV caches, RNG, step counters). On a real
multi-host deployment each host writes its process-local shards; here the
layout is identical but single-process. Restore is shape/dtype-checked.

``CheckpointManager`` keeps the newest ``keep`` checkpoints and can resume
from the latest complete one (partial writes are never visible thanks to
the rename barrier) — the restart half of checkpoint/restart fault
tolerance. ``launch/train.py`` wires it to a periodic cadence and to a
SIGTERM-style preemption hook.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: store bits
            out[f"leaf_{i:05d}__bf16"] = arr.view(np.uint16)
        else:
            out[f"leaf_{i:05d}"] = arr
    return out, treedef


def save(path: str, tree: Any, *, step: Optional[int] = None) -> str:
    """Atomically write ``tree`` to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten(tree)
    meta = {"n_leaves": len(arrays), "step": step}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **arrays)
        os.replace(tmp, path)        # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    flat, treedef = jax.tree_util.tree_flatten(like)
    with np.load(path, allow_pickle=False) as data:
        n = len({k.split("__")[0] for k in data.files
                 if k.startswith("leaf_")})
        if n != len(flat):
            raise ValueError(f"checkpoint has {n} leaves, expected "
                             f"{len(flat)}")
        leaves = []
        for i, ref in enumerate(flat):
            key = f"leaf_{i:05d}"
            if key in data.files:
                arr = data[key]
            else:
                import ml_dtypes
                arr = data[key + "__bf16"].view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(f"leaf {i}: shape {arr.shape} != "
                                 f"{np.shape(ref)}")
            leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_step(path: str) -> Optional[int]:
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
    return meta.get("step")


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    prefix: str = "ckpt"

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.prefix}_{step:08d}.npz")

    def all_steps(self) -> List[int]:
        if not os.path.isdir(self.directory):
            return []
        pat = re.compile(rf"{self.prefix}_(\d+)\.npz$")
        out = []
        for f in os.listdir(self.directory):
            m = pat.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> str:
        p = save(self._path(step), tree, step=step)
        for s in self.all_steps()[:-self.keep]:
            os.unlink(self._path(s))
        return p

    def restore_latest(self, like: Any) -> Tuple[Optional[int], Any]:
        step = self.latest()
        if step is None:
            return None, like
        return step, restore(self._path(step), like)
