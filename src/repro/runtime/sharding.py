"""Partition specs for the GSPMD (pjit) paths.

Mesh axes:
  "pod"   : data-parallel replica dimension across pods (multi-pod only)
  "data"  : FSDP / batch axis within a pod (16 on the production mesh)
  "model" : tensor-parallel axis (16)

Rules (applied by leaf name; the stacked layer axis is never sharded):
  * column-parallel weights (d -> heads*hd / d_ff):  (L, d, out) ->
    P(None, "data", "model")   — FSDP on the contraction dim, TP on out.
  * row-parallel weights (heads*hd / d_ff -> d):     (L, in, d) ->
    P(None, "model", "data").
  * MoE experts: expert-parallel over "model" when E % tp == 0, else
    TP inside each expert on the f dim.
  * embeddings: vocab over "model", d over "data" (both large).
  * norms / small vectors: replicated.

GSPMD tolerates non-divisible shardings by padding (e.g. 40 heads over 16
chips); that waste shows up honestly in the roofline FLOPs.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

_COL = {"wq", "wk", "wv", "w_gate", "w_up", "wq_b", "wk_b", "wv_b",
        "w_x", "w_y", "w_z", "w_b", "w_c", "w_dt", "in_proj"}
_ROW = {"wo", "w_down", "w_out", "out_proj"}
_LATENT = {"wq_a", "wkv_a"}


def _leaf_key(path_str: str) -> str:
    keys = re.findall(r"\['([^']+)'\]", path_str)
    return keys[-1] if keys else path_str


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the array dim —
    explicit jit argument shardings require exact divisibility. The result
    always has exactly ``len(shape)`` entries."""
    padded = (tuple(spec) + (None,) * len(shape))[:len(shape)]
    out = []
    for i, axis in enumerate(padded):
        if axis is None:
            out.append(None)
            continue
        if shape[i] % _axis_size(mesh, axis) == 0:
            out.append(axis)
        elif isinstance(axis, (tuple, list)):
            # try a prefix of the axis tuple (e.g. drop "data", keep "pod")
            kept = None
            for j in range(len(axis) - 1, 0, -1):
                if shape[i] % _axis_size(mesh, axis[:j]) == 0:
                    kept = tuple(axis[:j])
                    break
            out.append(kept)
        else:
            out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh):
    """Axes used for the batch dimension (pods fold into data-parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


#: experiment override for MoE expert-parallelism (None = auto by
#: divisibility). Set via ``set_moe_ep`` (dry-run --moe-ep flag).
_MOE_EP_OVERRIDE: Optional[bool] = None


def set_moe_ep(value: Optional[bool]) -> None:
    global _MOE_EP_OVERRIDE
    _MOE_EP_OVERRIDE = value


def moe_ep(cfg: ModelConfig, mesh: Mesh) -> bool:
    if _MOE_EP_OVERRIDE is not None:
        return _MOE_EP_OVERRIDE and cfg.n_experts > 0 \
            and cfg.n_experts % mesh.shape["model"] == 0
    tp = mesh.shape["model"]
    return cfg.n_experts > 0 and cfg.n_experts % tp == 0


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str,
               leaf_ndim: int, style: str = "fsdp") -> P:
    """PartitionSpec for one parameter leaf.

    style="fsdp": weights sharded over data AND model (ZeRO-3-like; XLA
    all-gathers per layer per use — collective-heavy, memory-light).
    style="zero1": weights TP-sharded only (replicated over data);
    optimizer moments are data-sharded (``zero1_moment_shardings``), so the
    per-step collective cost is one grad reduce-scatter + one param
    all-gather instead of per-layer-per-microbatch gathers.
    """
    key = _leaf_key(path)
    ep = moe_ep(cfg, mesh)
    if style == "zero1":
        spec = param_spec(cfg, mesh, path, leaf_ndim, style="fsdp")
        return P(*[None if ax == "data" else ax for ax in spec])

    if key == "embed":
        return P("model", "data")
    if key == "unembed":
        return P("data", "model")

    # MoE expert banks (L, E, d, f) / (L, E, f, d)
    if leaf_ndim == 4 and key in ("w_gate", "w_up"):
        return P(None, "model", "data", None) if ep \
            else P(None, None, "data", "model")
    if leaf_ndim == 4 and key == "w_down":
        return P(None, "model", None, "data") if ep \
            else P(None, None, "model", "data")
    if key == "router":
        return P(None, "data", None)

    if key in _ROW:
        return P(None, "model", "data") if leaf_ndim == 3 \
            else P("model", "data")
    if key in _COL:
        return P(None, "data", "model") if leaf_ndim == 3 \
            else P("data", "model")
    if key in _LATENT:
        return P(None, "data", None)
    # conv weights, gates, norms, biases, scalars: replicated
    return P()


def param_shardings(cfg: ModelConfig, mesh: Mesh, params: Any,
                    style: str = "fsdp"):
    """Pytree of NamedShardings matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = param_spec(cfg, mesh, name, getattr(leaf, "ndim", 0),
                          style=style)
        spec = sanitize(spec, tuple(leaf.shape), mesh)
        specs.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_moment_shardings(cfg: ModelConfig, mesh: Mesh, params: Any):
    """ZeRO-1 optimizer-state shardings: the param's TP spec plus "data"
    on the first still-unsharded divisible axis (usually the stacked layer
    axis)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    d = mesh.shape["data"]
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = param_spec(cfg, mesh, name, getattr(leaf, "ndim", 0),
                          style="zero1")
        spec = list(sanitize(spec, tuple(leaf.shape), mesh))
        for i in range(leaf.ndim):
            if spec[i] is None and leaf.shape[i] % d == 0:
                spec[i] = "data"
                break
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """KV/state cache specs for the GSPMD decode path.

    Batch over the data axes. The "model" axis goes to the kv-head dim when
    divisible, else to the sequence dim (sequence-parallel KV), else the
    leaf stays replicated over "model" — explicit jit argument shardings
    require exact divisibility.
    """
    b = batch_axes(mesh)
    tp = mesh.shape["model"]
    key = _leaf_key(path)
    nd = len(shape)
    if key == "len":
        return P()
    if key == "latent":                      # (L, B, S, r) — MLA
        s_ok = shape[2] % tp == 0
        return P(None, b, "model" if s_ok else None, None)
    if key == "state":                       # (L, B, nh, P, N)
        h_ok = shape[2] % tp == 0
        return P(None, b, "model" if h_ok else None, None, None)
    if key == "conv":                        # (L, B, K-1, C)
        c_ok = shape[3] % tp == 0
        return P(None, b, None, "model" if c_ok else None)
    if key == "h":                           # (G, B, w)
        return P(None, b, "model" if shape[2] % tp == 0 else None)
    if key in ("cross_k", "cross_v"):        # (L, B, F, hk, hd)
        return P(None, b, None, None, None)
    if nd == 5:                              # k/v (L, B, S, hk, hd)
        if shape[3] % tp == 0:
            return P(None, b, None, "model", None)
        if shape[2] % tp == 0:
            return P(None, b, "model", None, None)
        return P(None, b, None, None, None)
    if nd == 4:                              # int8 scales (L, B, S, hk)
        if shape[3] % tp == 0:
            return P(None, b, None, "model")
        if shape[2] % tp == 0:
            return P(None, b, "model", None)
        return P(None, b, None, None)
    return P()


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = cache_spec(cfg, mesh, name, tuple(leaf.shape))
        out.append(NamedSharding(mesh, sanitize(spec, tuple(leaf.shape),
                                                mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def data_sharding(mesh: Mesh, ndim: int, *, mrope: bool = False):
    """Tokens/labels (B, S) — batch over pod+data. M-RoPE positions are
    (3, B, S) with the batch on axis 1."""
    b = batch_axes(mesh)
    if mrope and ndim == 3:
        return NamedSharding(mesh, P(None, b, None))
    spec = [b] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def embeds_sharding(mesh: Mesh):
    """Frontend embeddings (B, F, d)."""
    return NamedSharding(mesh, P(batch_axes(mesh), None, None))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
