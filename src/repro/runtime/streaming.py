"""Async layer prefetcher + streamed decode drivers (paper §3.1).

The paper's pipelined-ring insight is that disk I/O for the *next* layer
window can hide behind compute for the current one — but only if
prefetch and release are disentangled: naive ``mmap`` offloading lets the
OS reclaim the pages being prefetched to satisfy the prefetch itself
("prefetch-release conflict"). This module implements the fix
explicitly:

  * a background thread reads layer ``k + w`` from the layer-sharded
    store (``runtime.paramstore``) into private host staging buffers
    while layer ``k`` computes — staging copies cannot be reclaimed by
    the kernel, so prefetch never self-evicts;
  * staged buffers are (optionally) ``jax.device_put`` ahead of use, so
    the host→device copy of window ``w+1`` overlaps compute on window
    ``w`` (double buffering);
  * release is explicit and strictly *behind* the compute front: once
    the front passes layer ``k``, its staging buffer is freed and the
    store drops the mmap pages (``MADV_DONTNEED``) — the resident set is
    bounded by the window size, never the model size.

Three consumers:

  * ``StreamingParamSource`` — plugs into the layer-wise model forward
    (``models.model.decode_step_layerwise`` etc.) and the
    ``ContinuousBatcher`` via ``make_streaming_engine``;
  * ``RingBankPrefetcher`` / ``StreamingRingDriver`` — drive the SPMD
    piped ring (``runtime.serve.build_ring_stream_step``) with per-step
    window banks, the multi-device version of the same pipeline;
  * the prefetch timeline (``PrefetchEvent``) feeds
    ``core.latency.streaming_crosscheck`` so the analytic disk terms are
    validated against measured reads.

Quantized (v2) stores flow through unchanged: ``store.layer(i)`` hands
back ``QuantizedTensor`` leaves whose packed/scale children are what the
staging copies, byte accounting and ``device_put`` traverse — so the
prefetch window, the resident-bytes bound and ``PrefetchStats`` all see
the ~4x-smaller packed footprint, and dequantization happens at use
(layer-wise model paths / ``serve.run_ring_window``), never in staging.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .iopolicy import IOPolicy, StallTimeout, WorkerHealth
from .memory import TierManager
from .paramstore import ParamSource, ParamStore
from .telemetry import NULL_TRACER, clock

Params = Dict[str, Any]

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class PrefetchEvent:
    """One background layer read (staging copy from the mmap store)."""

    layer: int
    t_start: float
    t_end: float
    nbytes: int

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def bps(self) -> float:
        return self.nbytes / max(self.duration, 1e-12)


@dataclasses.dataclass
class PrefetchStats:
    """Aggregate view of a prefetcher run (benchmarks + cross-checks)."""

    events: List[PrefetchEvent]
    peak_resident_bytes: int          # max staged parameter bytes
    total_bytes_read: int
    stall_s: float                    # compute blocked waiting on a layer
    layers_served: int
    releases: int
    retries: int = 0                  # transient I/O retries (IOPolicy)
    released_bytes: int = 0           # bytes the store returned to the OS
    budget_refusals: int = 0          # staging leases the budget refused

    @property
    def bytes_per_layer(self) -> float:
        """Measured streamed bytes per staged layer. For a quantized (v2)
        store this is the *packed* footprint — staging copies exactly the
        packed int4/int2 + scale sub-leaves, so it lands ~4x under the
        bf16 store's ``layer_nbytes`` (the benchmark's acceptance gate
        reads this, not manifest math)."""
        reads = [e for e in self.events if e.nbytes > 0]
        return (sum(e.nbytes for e in reads) / len(reads)) if reads else 0.0

    @property
    def median_layer_read_s(self) -> float:
        from ..core.latency import median_event_duration

        return median_event_duration(self.events)

    @property
    def measured_disk_bps(self) -> float:
        from ..core.latency import aggregate_bps

        return aggregate_bps(self.events)


class LayerPrefetcher:
    """Keep a cyclic window of ``window`` layers staged ahead of the front.

    ``get(i)`` blocks until layer ``i`` is staged, schedules reads through
    ``i + window - 1`` (mod L), and releases every staged layer behind the
    front (cyclic distance >= window). Access is expected to be the decode
    pattern — layers 0..L-1 in order, repeated per token — but any order
    is correct (out-of-window requests are staged on demand).

    ``window`` is a *scheduling lookahead*, not a capacity cap: every
    staged byte is leased from ``memory`` (a shared
    :class:`~runtime.memory.TierManager`, or a private unbounded one when
    omitted) — host bytes while staging, moved to the device tier after
    ``device_put`` — so one ``MemoryBudget`` bounds weights and KV
    together and a full tier throttles the worker (it blocks for a
    release) instead of overshooting.
    """

    def __init__(self, store: ParamStore, *, window: int = 4,
                 device_put: bool = True,
                 policy: Optional[IOPolicy] = None, tracer=None,
                 memory: Optional[TierManager] = None,
                 owner: str = "weights"):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.store = store
        self.window = min(window, store.n_layers)
        self.device_put = device_put
        self.policy = policy or IOPolicy()
        self.tracer = tracer or NULL_TRACER
        self.memory = memory if memory is not None \
            else TierManager(tracer=tracer, name="prefetch-memory")
        self.owner = owner
        self.health = WorkerHealth(name="LayerPrefetcher")
        # layer -> (tree, nbytes, tier at rest)
        self._buf: Dict[int, Tuple[Params, int, str]] = {}
        self._queue: deque = deque()
        self._inflight: set = set()
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._interrupted = False
        self._error: Optional[BaseException] = None
        self._events: List[PrefetchEvent] = []
        self._resident = 0
        self._peak = 0
        self._read = 0
        self._stall = 0.0
        self._served = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- worker ------------------------------------------------------------ #

    def _reopen(self, i: int) -> None:
        reopen = getattr(self.store, "reopen", None)
        if reopen is not None:
            reopen(i)

    def _stage(self, i: int) -> Tuple[Params, int, float, float]:
        """Copy layer i out of the mmap into private host buffers."""
        self.store.willneed(i)
        t0 = clock()
        views = self.store.layer(i)
        # a real copy, not ascontiguousarray (which aliases contiguous mmap
        # views): staging must be private so the kernel reclaiming mmap
        # pages can never touch data the compute front is about to use
        staged = jax.tree.map(lambda a: np.array(a, copy=True), views)
        t1 = clock()                 # event = disk->staging only (the term
        nbytes = sum(a.nbytes for a in jax.tree.leaves(staged))
        return staged, nbytes, t0, t1     # latency model prices b/s_disk

    def _fail(self, i: int, e: BaseException) -> None:
        with self._cv:
            self._error = e
            self._inflight.discard(i)
            self._cv.notify_all()

    def _worker(self) -> None:
        est = self.store.layer_nbytes     # upper bound on a staged layer
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                i = self._queue.popleft()
                self._inflight.add(i)
            # lease *before* materializing: the sum of live leases is an
            # upper bound on true residency, so the budget's high-water
            # holds by construction. A full tier blocks here (throttling
            # prefetch) until the front releases a layer behind it.
            try:
                self.memory.lease("host", est, self.owner, wait=True,
                                  timeout=self.policy.op_deadline_s,
                                  cancelled=lambda: self._stop)
            except BaseException as e:
                self._fail(i, e)
                return
            try:
                staged, nbytes, t0, t1 = self.policy.run(
                    f"layer_read[{i}]", lambda: self._stage(i),
                    reopen=lambda: self._reopen(i), health=self.health)
            except (KeyboardInterrupt, SystemExit):
                # control flow, never a latched I/O error: unblock any
                # waiting get() (it raises "prefetcher stopped") and let
                # the exception terminate the worker thread
                self.memory.release("host", est, self.owner)
                with self._cv:
                    self._stop = True
                    self._interrupted = True
                    self._inflight.discard(i)
                    self._cv.notify_all()
                raise
            except BaseException as e:   # surface in get(), don't deadlock
                self.memory.release("host", est, self.owner)
                self._fail(i, e)
                return
            # shrink the upper-bound lease to the bytes actually staged
            # (a v2 store reads the ~4x-smaller packed footprint)
            self.memory.resize("host", self.owner, est, nbytes)
            tier = "host"
            if self.device_put:
                # async H2D: the transfer of layer k+w overlaps compute on
                # k. Lease device bytes first, copy, then drop the host
                # staging lease (the np buffers die with the rebind).
                try:
                    self.memory.lease("device", nbytes, self.owner,
                                      wait=True,
                                      timeout=self.policy.op_deadline_s,
                                      cancelled=lambda: self._stop)
                except BaseException as e:
                    self.memory.release("host", nbytes, self.owner)
                    self._fail(i, e)
                    return
                with self.tracer.span("h2d", cat="prefetch",
                                      track="prefetcher", layer=i):
                    staged = jax.tree.map(jnp.asarray, staged)
                self.memory.release("host", nbytes, self.owner)
                tier = "device"
            self.tracer.span_event(f"layer_read[{i}]", t0, t1,
                                   cat="prefetch", track="prefetcher",
                                   nbytes=nbytes)
            with self._cv:
                self._inflight.discard(i)
                if i not in self._buf and not self._stop:
                    self._buf[i] = (staged, nbytes, tier)
                    self._resident += nbytes
                    self._peak = max(self._peak, self._resident)
                else:   # duplicate stage / raced close: hand bytes back
                    self.memory.release(tier, nbytes, self.owner)
                self._read += nbytes
                self._events.append(PrefetchEvent(i, t0, t1, nbytes))
                self._cv.notify_all()

    # -- front side -------------------------------------------------------- #

    def _schedule_locked(self, i: int) -> None:
        L = self.store.n_layers
        for d in range(self.window):
            j = (i + d) % L
            if j not in self._buf and j not in self._inflight \
                    and j not in self._queue:
                self._queue.append(j)
        self._cv.notify_all()

    def _release_locked(self, front: int) -> None:
        L = self.store.n_layers
        dropped = False
        for j in list(self._buf):
            if (j - front) % L >= self.window:
                _, nbytes, tier = self._buf.pop(j)
                self._resident -= nbytes
                self.memory.release(tier, nbytes, self.owner)
                self.store.release(j)
                dropped = True
        if dropped:
            self.tracer.counter(
                "store/released_bytes",
                getattr(self.store, "released_bytes", 0),
                track="prefetcher")

    def get(self, i: int, *, timeout: Optional[float] = None) -> Params:
        """Block until layer ``i`` is staged, at most ``timeout`` seconds
        (default: the policy's ``get_timeout_s``) — a wedged worker
        becomes a :class:`StallTimeout` with a health report, never an
        unbounded block."""
        if timeout is None:
            timeout = self.policy.get_timeout_s
        deadline = clock() + timeout
        with self._cv:
            self._schedule_locked(i)
            self._release_locked(i)
            t0 = clock()
            # blocked time here is the un-hidden disk term — attribute
            # it to the caller's open token step as ``disk_wait`` (the
            # span itself only traces when the wait actually stalled)
            with self.tracer.phase("disk_wait", cat="prefetch",
                                   track="decode", min_dur=2e-4,
                                   label=f"disk_wait[{i}]"):
                while i not in self._buf:
                    if self._error is not None:
                        raise RuntimeError(
                            f"prefetch of layer {i} failed "
                            f"({self.health.report()})") from self._error
                    if self._stop:
                        raise RuntimeError(
                            "prefetcher stopped" + (
                                " (worker interrupted)"
                                if self._interrupted else ""))
                    remaining = deadline - clock()
                    if remaining <= 0:
                        self.health.stalled = True
                        raise StallTimeout(
                            f"layer {i} not staged within {timeout:.1f}s "
                            f"({self.health.report()})",
                            op=f"layer_read[{i}]")
                    self._cv.wait(min(remaining, 0.25))
            self._stall += clock() - t0
            self._served += 1
            return self._buf[i][0]

    def stats(self) -> PrefetchStats:
        with self._cv:
            refusals = sum(s.refusals
                           for s in self.memory.stats().values())
            return PrefetchStats(
                events=list(self._events), peak_resident_bytes=self._peak,
                total_bytes_read=self._read, stall_s=self._stall,
                layers_served=self._served, releases=self.store.released,
                retries=self.health.retries,
                released_bytes=getattr(self.store, "released_bytes", 0),
                budget_refusals=refusals)

    def close(self, timeout: float = 5.0) -> bool:
        """Stop the worker; returns True once it has actually joined.

        Idempotent: a second call re-checks the join without re-stopping.
        A thread that fails to join within ``timeout`` is reported as a
        stall (logged with the health record) and left daemonized; the
        object is unusable either way. Staged buffers hand their leases
        back so a shared budget balances after shutdown.
        """
        with self._cv:
            self._closed = True
            self._stop = True
            for j in list(self._buf):
                _, nbytes, tier = self._buf.pop(j)
                self._resident -= nbytes
                self.memory.release(tier, nbytes, self.owner)
            self._cv.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            self.health.stalled = True
            log.error("LayerPrefetcher.close: worker failed to join "
                      "within %.1fs — %s", timeout, self.health.report())
            return False
        self.health.closed = True
        return True


class StreamingParamSource(ParamSource):
    """ParamSource over a store + async prefetcher (the streamed path).

    The head (embedding / final norm / lm head) is loaded once and stays
    resident, exactly as the paper pins the head on device 1; block layers
    stream through the ``window``-sized prefetch buffer.
    """

    def __init__(self, store: ParamStore, *, window: int = 4,
                 device_put: bool = True,
                 policy: Optional[IOPolicy] = None, tracer=None,
                 memory: Optional[TierManager] = None):
        self.store = store
        self.n_layers = store.n_layers
        self.prefetcher = LayerPrefetcher(store, window=window,
                                          device_put=device_put,
                                          policy=policy, tracer=tracer,
                                          memory=memory)
        head = store.head()
        if device_put:
            head = jax.tree.map(jnp.asarray, head)
        self._head = head

    def layer(self, i: int) -> Params:
        return self.prefetcher.get(i)

    def head(self) -> Params:
        return self._head

    def stats(self) -> PrefetchStats:
        return self.prefetcher.stats()

    def health(self) -> WorkerHealth:
        return self.prefetcher.health

    def close(self) -> None:
        self.prefetcher.close()
        self.store.close()

    def __enter__(self) -> "StreamingParamSource":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------- #
#  continuous-batching integration
# --------------------------------------------------------------------------- #

def make_streaming_engine(source: ParamSource, cfg, batch: int, ctx: int,
                          *, eos_id: Optional[int] = None, spec=None,
                          cache_dtype=jnp.float32, tracer=None,
                          metrics=None):
    """Build a ``ContinuousBatcher`` whose prefill/decode pull weights from
    ``source`` layer by layer (resident or streamed — same engine).
    """
    from ..models import model as M
    from .engine import ContinuousBatcher

    def prefill_one(prompt):
        c1 = M.init_cache(cfg, 1, ctx, dtype=cache_dtype)
        logits, c1 = M.prefill_layerwise(source, cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == batch and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst

        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new

    def decode(cache, tokens):
        return M.decode_step_layerwise(source, cfg, cache, tokens)

    return ContinuousBatcher(batch, prefill_one, write_slot, decode,
                             eos_id=eos_id, spec=spec, source=source,
                             ctx=ctx, tracer=tracer, metrics=metrics)


# --------------------------------------------------------------------------- #
#  piped-ring streaming (multi-device)
# --------------------------------------------------------------------------- #

class RingBankPrefetcher:
    """Stage per-microstep window banks for the streamed SPMD ring.

    The ring schedule needs, at microstep ``t``, a bank whose stage-``m``
    rows hold that stage's round-``r_m(t)`` window
    (``serve.ring_bank_layers``). A background thread assembles each
    step's bank from the layer store (staging copies + sharded
    ``device_put``) one step ahead of the compute front; per-layer staging
    buffers are reused across the steps that need them and dropped after
    their last use in the pass — release strictly behind the front.
    """

    def __init__(self, store: ParamStore, cfg, mesh, plan, *,
                 bank_specs, depth: int = 2,
                 policy: Optional[IOPolicy] = None, tracer=None,
                 memory: Optional[TierManager] = None,
                 owner: str = "weights"):
        from . import serve as RS

        self.store = store
        self.plan = plan
        self.depth = max(depth, 1)
        self.policy = policy or IOPolicy()
        self.tracer = tracer or NULL_TRACER
        self.memory = memory if memory is not None \
            else TierManager(tracer=tracer, name="ring-prefetch-memory")
        self.owner = owner
        self.health = WorkerHealth(name="RingBankPrefetcher")
        self._sharding = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), bank_specs)
        n_steps = plan.k * plan.n_stages + plan.n_stages - 1
        self._rows = [RS.ring_bank_layers(plan, t) for t in range(n_steps)]
        self.n_steps = n_steps
        L = cfg.n_layers
        last: Dict[int, int] = {}
        for t, rows in enumerate(self._rows):
            for layer in rows:
                if 0 <= layer < L:
                    last[int(layer)] = t
        self._last_use = last
        self.n_layers = L
        self._zero = None                 # cached zero layer (padding rows)
        self._staged: Dict[int, Params] = {}
        self._banks: Dict[int, Any] = {}
        self._bank_nbytes: Dict[int, int] = {}
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._interrupted = False
        self._error: Optional[BaseException] = None
        self._want: deque = deque()
        self._front = -1                  # last consumed step
        self._resident = 0
        self._peak = 0
        self._read = 0
        self._stall = 0.0                 # compute front blocked in get()
        self._served = 0
        self._events: List[PrefetchEvent] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- staging ----------------------------------------------------------- #

    def _reopen(self, layer: int) -> None:
        reopen = getattr(self.store, "reopen", None)
        if reopen is not None:
            reopen(layer)

    def _read_np(self, layer: int) -> Params:
        views = self.store.layer(layer)
        return jax.tree.map(lambda a: np.array(a, copy=True), views)

    def _layer_np(self, layer: int) -> Params:
        if layer >= self.n_layers:              # ring padding rows
            if self._zero is None:
                proto = self.policy.run(
                    "layer_read[0]", lambda: self._read_np(0),
                    reopen=lambda: self._reopen(0), health=self.health)
                self._zero = jax.tree.map(
                    lambda a: np.zeros(a.shape, a.dtype), proto)
            return self._zero
        staged = self._staged.get(layer)
        if staged is None:
            # lease the manifest upper bound before reading, shrink to
            # the packed bytes actually staged (v2 stores)
            est = self.store.layer_nbytes
            self.memory.lease("host", est, self.owner, wait=True,
                              timeout=self.policy.op_deadline_s,
                              cancelled=lambda: self._stop)
            t0 = clock()
            try:
                staged = self.policy.run(
                    f"layer_read[{layer}]", lambda: self._read_np(layer),
                    reopen=lambda: self._reopen(layer), health=self.health)
            except BaseException:
                self.memory.release("host", est, self.owner)
                raise
            t1 = clock()
            nbytes = sum(a.nbytes for a in jax.tree.leaves(staged))
            self.memory.resize("host", self.owner, est, nbytes)
            self.tracer.span_event(f"layer_read[{layer}]", t0, t1,
                                   cat="prefetch",
                                   track="ring-prefetcher",
                                   nbytes=nbytes)
            with self._cv:    # bookkeeping races with done()'s releases
                self._staged[layer] = staged
                self._resident += nbytes
                self._peak = max(self._peak, self._resident)
                self._read += nbytes
                self._events.append(PrefetchEvent(layer, t0, t1, nbytes))
        return staged

    def _build_bank(self, t: int):
        rows = self._rows[t]
        layers = [self._layer_np(int(i)) for i in rows]
        with self.tracer.span(f"bank_h2d[{t}]", cat="prefetch",
                              track="ring-prefetcher"):
            bank_np = jax.tree.map(lambda *xs: np.stack(xs, 0), *layers)
            nbytes = sum(a.nbytes for a in jax.tree.leaves(bank_np))
            # device bytes for the stacked bank: leased before the put,
            # released when done(t) drops the bank behind the front
            self.memory.lease("device", nbytes, self.owner, wait=True,
                              timeout=self.policy.op_deadline_s,
                              cancelled=lambda: self._stop)
            try:
                bank = jax.device_put(bank_np, self._sharding)
            except BaseException:
                self.memory.release("device", nbytes, self.owner)
                raise
            with self._cv:
                self._bank_nbytes[t] = nbytes
            return bank

    def _worker(self) -> None:
        while True:
            with self._cv:
                # throttle: never build more than ``depth`` banks past the
                # front — this is what bounds staged bytes by the window,
                # not the model (prefetch cannot run away from release)
                while not self._stop and (
                        not self._want
                        or self._want[0] > self._front + self.depth):
                    self._cv.wait()
                if self._stop:
                    return
                t = self._want.popleft()
            try:
                bank = self._build_bank(t)
            except (KeyboardInterrupt, SystemExit):
                # control flow: unblock waiters, then die loudly
                with self._cv:
                    self._stop = True
                    self._interrupted = True
                    self._cv.notify_all()
                raise
            except BaseException as e:   # surface in get(), don't deadlock
                with self._cv:
                    self._error = e
                    self._cv.notify_all()
                return
            with self._cv:
                self._banks[t] = bank
                self._cv.notify_all()

    # -- front side -------------------------------------------------------- #

    def begin_pass(self) -> None:
        """Enqueue the whole step schedule (banks build ``depth`` ahead)."""
        with self._cv:
            self._drain_locked(banks_only=True)
            self._front = -1
            self._want.extend(range(self.n_steps))
            self._cv.notify_all()

    def _drain_locked(self, *, banks_only: bool = False) -> None:
        """Hand every live lease back (abandoned pass / shutdown)."""
        for t in list(self._banks):
            self._banks.pop(t)
            self.memory.release("device", self._bank_nbytes.pop(t, 0),
                                self.owner)
        if banks_only:
            return
        for layer in list(self._staged):
            staged = self._staged.pop(layer)
            nbytes = sum(a.nbytes for a in jax.tree.leaves(staged))
            self._resident -= nbytes
            self.memory.release("host", nbytes, self.owner)
            self.store.release(layer)

    def get(self, t: int, *, timeout: Optional[float] = None):
        if timeout is None:
            timeout = self.policy.get_timeout_s
        deadline = clock() + timeout
        with self._cv:
            t0 = clock()
            with self.tracer.phase("disk_wait", cat="prefetch",
                                   track="decode", min_dur=2e-4,
                                   label=f"bank_wait[{t}]"):
                while t not in self._banks:
                    if self._error is not None:
                        raise RuntimeError(
                            f"bank staging for step {t} failed "
                            f"({self.health.report()})") from self._error
                    if self._stop:
                        raise RuntimeError(
                            "bank prefetcher stopped" + (
                                " (worker interrupted)"
                                if self._interrupted else ""))
                    remaining = deadline - clock()
                    if remaining <= 0:
                        self.health.stalled = True
                        raise StallTimeout(
                            f"bank for step {t} not staged within "
                            f"{timeout:.1f}s ({self.health.report()})",
                            op=f"bank_build[{t}]")
                    self._cv.wait(min(remaining, 0.25))
            self._stall += clock() - t0
            self._served += 1
            return self._banks[t]

    def done(self, t: int) -> None:
        """Step ``t`` consumed: drop its bank and release layers whose last
        use in this pass was step ``t`` (behind the compute front)."""
        with self._cv:
            if self._banks.pop(t, None) is not None:
                self.memory.release("device",
                                    self._bank_nbytes.pop(t, 0),
                                    self.owner)
            self._front = max(self._front, t)
            for layer, last in self._last_use.items():
                if last == t and layer in self._staged:
                    staged = self._staged.pop(layer)
                    nbytes = sum(
                        a.nbytes for a in jax.tree.leaves(staged))
                    self._resident -= nbytes
                    self.memory.release("host", nbytes, self.owner)
                    self.store.release(layer)
            self._cv.notify_all()

    def stats(self) -> PrefetchStats:
        with self._cv:
            return PrefetchStats(
                events=list(self._events), peak_resident_bytes=self._peak,
                total_bytes_read=self._read, stall_s=self._stall,
                layers_served=len(self._events),
                releases=self.store.released,
                retries=self.health.retries,
                released_bytes=getattr(self.store, "released_bytes", 0),
                budget_refusals=sum(
                    s.refusals for s in self.memory.stats().values()))

    def close(self, timeout: float = 5.0) -> bool:
        """Stop the worker (idempotent); True once it has joined, False
        with a logged stall report if it is stuck."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=timeout)
        if not self._thread.is_alive():
            with self._cv:
                self._drain_locked()
        if self._thread.is_alive():
            self.health.stalled = True
            log.error("RingBankPrefetcher.close: worker failed to join "
                      "within %.1fs — %s", timeout, self.health.report())
            return False
        self.health.closed = True
        return True


class StreamingRingDriver:
    """Host-driven piped-ring decode whose window banks stream from disk.

    Where ``build_ring_serve_step`` closes over the full ring-ordered
    layer bank ((k*w, ...) per stage, all resident), this driver holds
    only each microstep's (w, ...) window on device: the host loop runs
    the ``k*M + M - 1`` ring microsteps itself, feeding banks staged by
    ``RingBankPrefetcher`` — disk reads and H2D copies for step ``t+1``
    overlap the device compute of step ``t``, and layers behind the
    front are released. The KV cache stays device-resident (it is state,
    not streamable weights).
    """

    def __init__(self, cfg, mesh, plan, store: ParamStore, *,
                 head_params: Params, cache_like, n_tokens: int = 1,
                 prefetch_depth: int = 2,
                 policy: Optional[IOPolicy] = None, tracer=None,
                 memory: Optional[TierManager] = None):
        from . import serve as RS

        self.cfg = cfg
        self.plan = plan
        policy = policy or IOPolicy()
        self.tracer = tracer or NULL_TRACER
        layer_like = policy.run("layer_read[0]", lambda: store.layer(0))
        fns, bank_specs = RS.build_ring_stream_step(
            cfg, mesh, plan, head_params, cache_like, layer_like,
            n_tokens=n_tokens)
        self._embed, self._micro, self._final = fns
        self.head_params = head_params
        self.n_tokens = n_tokens
        self.prefetch = RingBankPrefetcher(store, cfg, mesh, plan,
                                           bank_specs=bank_specs,
                                           depth=prefetch_depth,
                                           policy=policy, tracer=tracer,
                                           memory=memory)
        self.n_steps = self.prefetch.n_steps
        self._token_idx = 0

    def step(self, tokens, ln, cache):
        """One decode pass (all L layers streamed once): (logits, cache).

        With a tracer attached each pass is one token-step scope: bank
        waits attribute to ``disk_wait`` (inside the prefetcher's
        ``get``), the ring microsteps to ``compute``, and the microstep
        spans land on the ``ring`` track of the exported trace.
        """
        with self.tracer.token_step(self._token_idx, track="decode",
                                    name=f"ring_token"
                                         f"[{self._token_idx}]"):
            self._token_idx += 1
            return self._step_inner(tokens, ln, cache)

    def _step_inner(self, tokens, ln, cache):
        cfg, plan = self.cfg, self.plan
        B = tokens.shape[0]
        mb = B // plan.n_stages
        d = self.head_params["embed"].shape[1]
        self.prefetch.begin_pass()
        with self.tracer.phase("compute", cat="ring", track="ring",
                               label="embed"):
            emb_all = self._embed(tokens, self.head_params)
        dtype = emb_all.dtype
        x = jnp.zeros((plan.n_stages * mb, self.n_tokens, d), dtype)
        out_buf = jnp.zeros((plan.n_stages * B, self.n_tokens, d), dtype)
        layers_c = cache["layers"]
        for t in range(self.n_steps):
            bank = self.prefetch.get(t)
            with self.tracer.phase("compute", cat="ring", track="ring",
                                   label=f"microstep[{t}]"):
                x, layers_c, out_buf = self._micro(
                    jnp.int32(t), x, emb_all, ln, layers_c, out_buf,
                    bank, self.head_params["final_norm"])
            self.prefetch.done(t)
        with self.tracer.phase("compute", cat="ring", track="ring",
                               label="head"):
            logits = self._final(out_buf, self.head_params)
            logits = jax.block_until_ready(logits)
        new_cache = dict(cache)
        new_cache["layers"] = layers_c
        new_cache["len"] = ln + self.n_tokens
        return logits, new_cache

    def stats(self) -> PrefetchStats:
        return self.prefetch.stats()

    def health(self) -> WorkerHealth:
        return self.prefetch.health

    def close(self, timeout: float = 5.0) -> bool:
        return self.prefetch.close(timeout=timeout)
