"""Unified tiered memory manager: one budget for weights *and* KV.

The paper's "OOM-free with <6% memory pressure" claim rests on treating
disk, RAM and VRAM as a single coordinated hierarchy. The repo grew
that hierarchy piecewise — weights stream disk→host→device through
``ParamStore``/``LayerPrefetcher`` with a per-subsystem ``window`` cap,
KV pages live in a device ``BlockPool`` with host-only offload — so
nothing enforced a whole-system budget and an idle user's KV could
never leave RAM. This module is the unification (ROADMAP item 3; PIPO's
pipelined host↔device offload timeline and TPI-LLM's sliding-window
memory scheduler in PAPERS.md are the two designs it subsumes):

  * :class:`MemoryBudget` — byte caps for the ``device`` / ``host`` /
    ``disk`` tiers (``None`` = unbounded). One budget object describes
    the whole machine.
  * :class:`TierManager` — the single accountant for every resident
    byte. Subsystems *lease* bytes from a tier before materializing
    them and release (or :meth:`~TierManager.move` across tiers) when
    the bytes move on: the layer prefetchers lease staging/device bytes
    per staged layer, the KV block pool leases its device pool, the
    offloader leases host copies and disk page files. Capacity caps
    stop living inside each subsystem — ``LayerPrefetcher``'s window
    and ``BlockPool``'s page count become *scheduling* parameters while
    the byte ceiling is enforced here, so the whole-system high-water
    can never exceed the configured budget by construction.
  * per-tier, per-owner telemetry: every mutation updates
    :class:`TierStats` (used / peak / lease / release / refusal
    counters) and, with a tracer attached, emits ``mem/<tier>/used``
    counters onto the shared telemetry timeline.

A refused lease raises :class:`~runtime.iopolicy.BudgetExceeded` — an
``OSError`` the shared :class:`~runtime.iopolicy.IOPolicy` classifies
*transient*, because a full tier is usually a tier another slot is
about to make room in; ``wait=True`` leases block (bounded) for that
room instead of failing immediately.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional

from .iopolicy import BudgetExceeded
from .telemetry import NULL_TRACER, clock

TIERS = ("device", "host", "disk")


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Byte caps per tier; ``None`` leaves a tier unbounded.

    One instance describes the whole machine the runtime may use:
    ``device`` is the accelerator pool (KV pages + staged device
    layers), ``host`` is pinned RAM (staging buffers + offloaded KV
    copies), ``disk`` bounds page files (parked sessions + spilled
    pages). ``from_mb`` is the CLI-friendly constructor behind
    ``serve --device-budget/--host-budget``.
    """

    device: Optional[int] = None
    host: Optional[int] = None
    disk: Optional[int] = None

    def __post_init__(self):
        for tier in TIERS:
            cap = getattr(self, tier)
            if cap is not None and cap < 0:
                raise ValueError(f"{tier} budget must be >= 0, got {cap}")

    def cap(self, tier: str) -> Optional[int]:
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected {TIERS})")
        return getattr(self, tier)

    @classmethod
    def from_mb(cls, *, device: Optional[float] = None,
                host: Optional[float] = None,
                disk: Optional[float] = None) -> "MemoryBudget":
        conv = lambda x: None if x is None else int(x * 1e6)
        return cls(device=conv(device), host=conv(host), disk=conv(disk))


@dataclasses.dataclass
class TierStats:
    """Accounting view of one tier (budget audits + benchmarks)."""

    capacity: Optional[int]          # None = unbounded
    used: int = 0
    peak: int = 0                    # high-water of ``used``
    leases: int = 0                  # successful lease calls
    releases: int = 0
    refusals: int = 0                # leases denied (BudgetExceeded)
    leased_bytes: int = 0            # lifetime bytes leased
    released_bytes: int = 0          # lifetime bytes released

    @property
    def available(self) -> Optional[int]:
        return None if self.capacity is None else self.capacity - self.used


class TierManager:
    """Thread-safe accountant of every resident byte across the tiers.

    ``lease(tier, nbytes, owner)`` reserves bytes against the tier's
    cap (raising :class:`BudgetExceeded` on refusal, or blocking up to
    ``timeout`` when ``wait=True``); ``release`` returns them; ``move``
    atomically re-homes bytes (host→device after an H2D copy,
    host→disk after a spill). ``owner`` tags the accounting — "weights"
    vs "kv" — so the unified budget still reports who holds what.

    The manager never touches the bytes themselves: subsystems
    materialize buffers only after their lease succeeds, so the sum of
    live leases is an upper bound on true residency and the per-tier
    high-water (``stats()[tier].peak``) can never exceed the budget.
    """

    def __init__(self, budget: Optional[MemoryBudget] = None, *,
                 tracer=None, name: str = "memory"):
        self.budget = budget or MemoryBudget()
        self.tracer = tracer or NULL_TRACER
        self.name = name
        self._cv = threading.Condition()
        self._stats: Dict[str, TierStats] = {
            t: TierStats(capacity=self.budget.cap(t)) for t in TIERS}
        self._owners: Dict[str, Dict[str, int]] = {t: {} for t in TIERS}

    # -- queries ----------------------------------------------------------- #

    def used(self, tier: str) -> int:
        with self._cv:
            return self._tier(tier).used

    def peak(self, tier: str) -> int:
        with self._cv:
            return self._tier(tier).peak

    def capacity(self, tier: str) -> Optional[int]:
        return self.budget.cap(tier)

    def available(self, tier: str) -> Optional[int]:
        """Free bytes in ``tier`` (None = unbounded)."""
        with self._cv:
            return self._tier(tier).available

    def owner_bytes(self, owner: str, tier: Optional[str] = None) -> int:
        """Bytes ``owner`` currently holds (in one tier or across all)."""
        with self._cv:
            tiers = [tier] if tier is not None else list(TIERS)
            return sum(self._owners[t].get(owner, 0) for t in tiers)

    def stats(self) -> Dict[str, TierStats]:
        with self._cv:
            return {t: dataclasses.replace(s)
                    for t, s in self._stats.items()}

    def _tier(self, tier: str) -> TierStats:
        st = self._stats.get(tier)
        if st is None:
            raise ValueError(f"unknown tier {tier!r} (expected {TIERS})")
        return st

    # -- mutation ---------------------------------------------------------- #

    def _fits_locked(self, tier: str, nbytes: int) -> bool:
        st = self._tier(tier)
        return st.capacity is None or st.used + nbytes <= st.capacity

    def _lease_locked(self, tier: str, nbytes: int, owner: str) -> None:
        st = self._tier(tier)
        st.used += nbytes
        st.peak = max(st.peak, st.used)
        st.leases += 1
        st.leased_bytes += nbytes
        self._owners[tier][owner] = \
            self._owners[tier].get(owner, 0) + nbytes
        self.tracer.counter(f"mem/{tier}/used", st.used, track=self.name)

    def _release_locked(self, tier: str, nbytes: int, owner: str) -> None:
        st = self._tier(tier)
        held = self._owners[tier].get(owner, 0)
        if nbytes > held:
            raise ValueError(
                f"release of {nbytes} B from {tier} by {owner!r}, who "
                f"holds only {held} B — the tier-budget audit would go "
                f"negative (double release?)")
        st.used -= nbytes
        st.releases += 1
        st.released_bytes += nbytes
        left = held - nbytes
        if left:
            self._owners[tier][owner] = left
        else:
            del self._owners[tier][owner]
        self.tracer.counter(f"mem/{tier}/used", st.used, track=self.name)

    def try_lease(self, tier: str, nbytes: int,
                  owner: str = "anon") -> bool:
        """Non-blocking lease; False (and a counted refusal) on a full
        tier instead of an exception."""
        if nbytes < 0:
            raise ValueError(f"lease of negative bytes: {nbytes}")
        with self._cv:
            if not self._fits_locked(tier, nbytes):
                self._tier(tier).refusals += 1
                return False
            self._lease_locked(tier, nbytes, owner)
            return True

    def lease(self, tier: str, nbytes: int, owner: str = "anon", *,
              wait: bool = False, timeout: float = 30.0,
              cancelled: Optional[Callable[[], bool]] = None) -> None:
        """Reserve ``nbytes`` in ``tier`` or raise :class:`BudgetExceeded`.

        ``wait=True`` blocks (up to ``timeout`` seconds, waking on every
        release) for another holder to make room — the backpressure mode
        worker threads use so a full tier throttles staging instead of
        failing it. ``cancelled`` lets a waiting worker abandon the
        lease when its owner is shutting down.
        """
        if nbytes < 0:
            raise ValueError(f"lease of negative bytes: {nbytes}")
        deadline = clock() + timeout
        with self._cv:
            while not self._fits_locked(tier, nbytes):
                st = self._tier(tier)
                if not wait or (cancelled is not None and cancelled()):
                    st.refusals += 1
                    raise BudgetExceeded(
                        f"{self.name}: {tier} tier refuses {nbytes} B "
                        f"({st.used}/{st.capacity} B used)",
                        tier=tier, requested=nbytes, used=st.used,
                        capacity=st.capacity or 0)
                remaining = deadline - clock()
                if remaining <= 0:
                    st.refusals += 1
                    raise BudgetExceeded(
                        f"{self.name}: {tier} tier still refuses "
                        f"{nbytes} B after {timeout:.1f}s "
                        f"({st.used}/{st.capacity} B used)",
                        tier=tier, requested=nbytes, used=st.used,
                        capacity=st.capacity or 0)
                self._cv.wait(min(remaining, 0.25))
            self._lease_locked(tier, nbytes, owner)

    def release(self, tier: str, nbytes: int, owner: str = "anon") -> None:
        """Return ``nbytes`` to ``tier`` and wake blocked leases."""
        if nbytes < 0:
            raise ValueError(f"release of negative bytes: {nbytes}")
        with self._cv:
            self._release_locked(tier, nbytes, owner)
            self._cv.notify_all()

    def resize(self, tier: str, owner: str, old: int, new: int) -> None:
        """Adjust a live lease to its true size (an upper-bound lease —
        e.g. ``layer_nbytes`` before a quantized store read — shrinks to
        the packed bytes actually staged)."""
        if new > old:
            self.lease(tier, new - old, owner)
        elif new < old:
            self.release(tier, old - new, owner)

    def move(self, src: str, dst: str, nbytes: int,
             owner: str = "anon", *, wait: bool = False,
             timeout: float = 30.0,
             cancelled: Optional[Callable[[], bool]] = None) -> None:
        """Atomically re-home ``nbytes`` from ``src`` to ``dst`` (the
        copy already happened — host→device after an H2D ``device_put``,
        host→disk after a page spill). The destination must fit (same
        wait/refusal semantics as :meth:`lease`); the source release
        only lands once it does, so an audit never sees the bytes in
        zero or two tiers."""
        if src == dst:
            return
        deadline = clock() + timeout
        with self._cv:
            while not self._fits_locked(dst, nbytes):
                st = self._tier(dst)
                if not wait or (cancelled is not None and cancelled()):
                    st.refusals += 1
                    raise BudgetExceeded(
                        f"{self.name}: cannot move {nbytes} B "
                        f"{src}->{dst}: {dst} tier full "
                        f"({st.used}/{st.capacity} B used)",
                        tier=dst, requested=nbytes, used=st.used,
                        capacity=st.capacity or 0)
                remaining = deadline - clock()
                if remaining <= 0:
                    st.refusals += 1
                    raise BudgetExceeded(
                        f"{self.name}: move {src}->{dst} of {nbytes} B "
                        f"still refused after {timeout:.1f}s "
                        f"({st.used}/{st.capacity} B used)",
                        tier=dst, requested=nbytes, used=st.used,
                        capacity=st.capacity or 0)
                self._cv.wait(min(remaining, 0.25))
            self._release_locked(src, nbytes, owner)
            self._lease_locked(dst, nbytes, owner)
            self._cv.notify_all()

    # -- invariants (tests / benchmarks) ----------------------------------- #

    def audit(self) -> None:
        """Assert the books balance: per-owner bytes sum to each tier's
        ``used``, nothing is negative, and no tier exceeds its cap."""
        with self._cv:
            for tier, st in self._stats.items():
                owned = sum(self._owners[tier].values())
                assert st.used == owned, \
                    f"{tier}: used {st.used} != sum(owners) {owned}"
                assert st.used >= 0, f"{tier}: negative used {st.used}"
                assert st.leased_bytes - st.released_bytes == st.used, \
                    (f"{tier}: lifetime leases {st.leased_bytes} - "
                     f"releases {st.released_bytes} != used {st.used}")
                if st.capacity is not None:
                    assert st.peak <= st.capacity, \
                        f"{tier}: peak {st.peak} > cap {st.capacity}"

    def report(self) -> str:
        with self._cv:
            parts = []
            for tier, st in self._stats.items():
                cap = "inf" if st.capacity is None \
                    else f"{st.capacity / 1e6:.1f}"
                parts.append(
                    f"{tier} {st.used / 1e6:.1f}/{cap} MB "
                    f"(peak {st.peak / 1e6:.1f}, "
                    f"{st.refusals} refusals)")
            return f"{self.name}: " + ", ".join(parts)
