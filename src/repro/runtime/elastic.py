"""Elastic ring: stage failure -> Halda re-solve -> window remap -> resume.

The paper's A.5 shows the scheduler choosing device subsets; the same
machinery gives fault tolerance on a pod: when a stage (or host) dies, the
survivors re-run Halda over the reduced stage list (possibly with reduced
HBM budgets for stages co-located with recovery work), re-permute the layer
stack for the new (M', k', w') plan, and continue from the last token — KV
state for the lost stage's layers is rebuilt by a re-prefill of the
conversation so far (decode state is the only non-checkpointed state).

Straggler mitigation is the same mechanism with a soft signal: the device
profiler feeds per-stage throughput into Halda, which shrinks the slow
stage's windows instead of dropping it (heterogeneous w_m) — exercised in
the simulator-backed tests; the SPMD ring uses the uniform-window plan the
solver returns for healthy homogeneous pods.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import halda
from ..core.profiles import DeviceProfile, ModelProfile
from ..core.ring import build_schedule, RingSchedule
from ..configs.base import ModelConfig
from .serve import RingPlan, padded_layers


@dataclasses.dataclass
class ElasticState:
    stages: List[int]                  # surviving stage ids (mesh coords)
    plan: RingPlan
    generation: int = 0


def initial_state(cfg: ModelConfig, n_stages: int, k: int = 1
                  ) -> ElasticState:
    return ElasticState(stages=list(range(n_stages)),
                        plan=RingPlan.make(cfg, n_stages, k=k))


def fail_stages(state: ElasticState, cfg: ModelConfig,
                failed: Sequence[int], *, k: Optional[int] = None
                ) -> ElasticState:
    """Drop failed stages and recompute the ring plan for the survivors."""
    survivors = [s for s in state.stages if s not in set(failed)]
    if not survivors:
        raise RuntimeError("all stages failed")
    M = len(survivors)
    if k is None:
        # keep per-stage layer count near the old plan: more rounds on a
        # smaller ring (the piped-ring knob the paper turns)
        per_stage = padded_layers(cfg.n_layers, M) // M
        k = max(1, min(state.plan.k * state.plan.w, per_stage))
        while per_stage % k:
            k -= 1
    plan = RingPlan.make(cfg, M, k=k)
    return ElasticState(stages=survivors, plan=plan,
                        generation=state.generation + 1)


def resolve_heterogeneous(devices: Sequence[DeviceProfile],
                          model: ModelProfile) -> halda.HaldaSolution:
    """Full Halda re-solve for heterogeneous survivors (reduced HBM budgets,
    stragglers with degraded throughput, mixed stage sizes)."""
    return halda.solve(devices, model)


def remap_schedule(sol: halda.HaldaSolution, L: int) -> RingSchedule:
    """Concrete layer->window schedule for a Halda solution."""
    return build_schedule(sol.w, sol.n, L)
