"""Elastic failover for the streamed SPMD ring (wires ``runtime.elastic``
into the serve path).

The paper's A.5 machinery — drop dead devices, re-run Halda over the
survivors, re-permute the layer stack, continue from the last token —
lived in ``runtime/elastic.py`` but nothing drove it.
:class:`ElasticRingServer` closes the loop for the streamed ring:

  * **detect** — any exception out of a ring pass is walked for a
    :class:`iopolicy.StageFailure` (the classified form of "stage m is
    unreachable", injected by the chaos suite, raised by health
    monitoring in production). Unattributed fatal errors rebuild the
    driver on the same stages (a wedged worker thread, not a dead host).
  * **re-solve** — ``elastic.fail_stages`` drops the dead stage and
    recomputes the ring plan; the survivor set shrinks further until the
    SPMD constraints hold again (``batch % M == 0``, ``M * tp`` devices).
    With device/model profiles attached, ``elastic.resolve_heterogeneous``
    re-runs the full Halda solve over the survivors and its ``k`` is
    adopted when the uniform ring supports it.
  * **resume** — a fresh mesh/driver/cache is built for the new plan and
    the *entire* token history (prompt + every emitted token) is replayed
    through the ring ("re-prefill": decode KV is the only
    non-checkpointed state, so it is rebuilt by re-running the
    conversation). Emitted tokens are never discarded — generation
    resumes exactly at the next token, and because the replay is the
    same deterministic computation a clean run on the survivor mesh
    performs, post-recovery tokens match that reference bit-for-bit.

Every recovery emits a :class:`FailoverEvent` with the detect/re-solve/
replay timing split and tokens-lost accounting that
``benchmarks/fault_recovery.py`` reports.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import elastic
from . import serve as RS
from .iopolicy import IOPolicy, StageFailure, find_cause
from .streaming import StreamingRingDriver
from .telemetry import NULL_TRACER, clock

Params = Dict[str, Any]

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class FailoverEvent:
    """One recovery: what died, what the new plan is, what it cost."""

    token_index: int              # emitted tokens when the failure struck
    failed_stage: Optional[int]   # original stage id (None = unattributed)
    generation: int               # elastic generation after recovery
    n_stages_before: int
    n_stages_after: int
    plan: Dict[str, int]          # new RingPlan as a dict
    halda: Optional[Dict[str, Any]]   # re-solve summary (profiles given)
    detect_s: float               # failure raised -> cause classified
    resolve_s: float              # elastic/Halda re-plan
    rebuild_s: float              # mesh + driver + jit rebuild
    replay_s: float               # re-prefill of the token history
    tokens_lost: int              # emitted tokens discarded (always 0)
    replayed_tokens: int

    @property
    def recovery_s(self) -> float:
        return self.detect_s + self.resolve_s + self.rebuild_s \
            + self.replay_s


class ElasticRingServer:
    """Streamed-ring generation loop with stage-failure recovery.

    ``store`` is any ``ParamStore``-like source (a ``faults.FaultyStore``
    in the chaos suite); ``params`` the full unpadded parameter dict
    (head leaves are used; blocks stream from the store). The server
    owns mesh/driver/cache construction so it can rebuild them when the
    stage set changes.

    ``device_profiles``/``model_profile`` (``core.profiles``) are
    optional: when both are given, each failover re-runs the Halda
    solver over the surviving stages' profiles and adopts its ``k`` if
    the uniform-window ring supports it.
    """

    def __init__(self, cfg, store, params: Params, *, batch: int,
                 ctx: int, n_stages: int, tp: int, k: int = 1,
                 prefetch_depth: int = 2, max_failovers: int = 2,
                 policy: Optional[IOPolicy] = None,
                 device_profiles: Optional[Sequence] = None,
                 model_profile=None, tracer=None):
        if not RS.ring_supported(cfg, batch, n_stages):
            raise ValueError(
                f"ring unsupported: family {cfg.family}, "
                f"batch {batch} % stages {n_stages} != 0")
        self.cfg = cfg
        self.store = store
        self.batch = batch
        self.ctx = ctx
        self.tp = tp
        self.prefetch_depth = prefetch_depth
        self.max_failovers = max_failovers
        self.policy = policy or IOPolicy()
        self.tracer = tracer or NULL_TRACER
        self.device_profiles = list(device_profiles) \
            if device_profiles is not None else None
        self.model_profile = model_profile
        self.state = elastic.initial_state(cfg, n_stages, k=k)
        # head stays resident and tp never changes, so pad once
        self._head = {key: v for key, v in
                      RS.pad_vocab(dict(params), cfg, tp).items()
                      if key != "blocks"}
        self.events: List[FailoverEvent] = []
        self.driver: Optional[StreamingRingDriver] = None
        self.mesh = None
        self._pending_event: Optional[Dict[str, Any]] = None

    # -- (re)construction -------------------------------------------------- #

    def _feasible(self, state: elastic.ElasticState
                  ) -> elastic.ElasticState:
        """Shrink the survivor set until the SPMD ring constraints hold:
        ``batch % M == 0`` and ``M * tp`` devices exist. Dropping a
        healthy stage is graceful degradation, not data loss — its
        layers re-distribute like a failed stage's."""
        n_dev = len(jax.devices())
        while True:
            M = len(state.stages)
            if M >= 1 and self.batch % M == 0 and M * self.tp <= n_dev:
                return state
            if M <= 1:
                raise RuntimeError(
                    f"no feasible ring: batch {self.batch}, tp {self.tp},"
                    f" {n_dev} devices, {M} surviving stages")
            state = elastic.fail_stages(state, self.cfg,
                                        [state.stages[-1]])

    def _build(self):
        """Mesh + fresh ring-permuted cache + streaming driver for the
        current elastic state."""
        M = self.state.plan.n_stages
        need = M * self.tp
        devs = jax.devices()
        if len(devs) < need:
            raise RuntimeError(f"need {need} devices for M={M} x "
                               f"tp={self.tp}, have {len(devs)}")
        from ..models import init_cache
        mesh = jax.sharding.Mesh(
            np.array(devs[:need]).reshape(M, self.tp), ("data", "model"))
        cache = init_cache(self.cfg, self.batch, self.ctx,
                           dtype=jnp.float32)
        cache["layers"] = RS.pad_and_permute(cache["layers"], self.cfg,
                                             M, self.state.plan.k)
        driver = StreamingRingDriver(
            self.cfg, mesh, self.state.plan, self.store,
            head_params=self._head, cache_like=cache,
            prefetch_depth=self.prefetch_depth, policy=self.policy,
            tracer=self.tracer)
        self.mesh, self.driver = mesh, driver
        return driver, cache

    # -- recovery ---------------------------------------------------------- #

    def _resolve(self, exc: BaseException, n_emitted: int,
                 t_detect0: float) -> None:
        """Classify ``exc``, update the elastic state, record the event
        timing skeleton (completed by the caller after rebuild+replay)."""
        cause = find_cause(exc, StageFailure)
        detect_s = clock() - t_detect0
        before = len(self.state.stages)
        t0 = clock()
        failed_id: Optional[int] = None
        halda_info: Optional[Dict[str, Any]] = None
        if cause is not None and 0 <= cause.stage < before:
            failed_id = self.state.stages[cause.stage]
            self.state = elastic.fail_stages(self.state, self.cfg,
                                             [failed_id])
            self.state = self._feasible(self.state)
            if self.device_profiles is not None \
                    and self.model_profile is not None:
                profs = [self.device_profiles[s] for s in
                         self.state.stages
                         if s < len(self.device_profiles)]
                try:
                    sol = elastic.resolve_heterogeneous(
                        profs, self.model_profile)
                    halda_info = {"k": int(sol.k),
                                  "w": [int(x) for x in sol.w],
                                  "latency_s": float(sol.latency)}
                    per = self.state.plan.L_pad \
                        // self.state.plan.n_stages
                    if sol.k >= 1 and per % sol.k == 0 \
                            and sol.k != self.state.plan.k:
                        self.state = elastic.fail_stages(
                            self.state, self.cfg, [], k=int(sol.k))
                except Exception as e:      # re-solve is best-effort
                    log.warning("halda re-solve failed: %s", e)
        else:
            # unattributed: rebuild on the same stages (wedged worker,
            # poisoned jit buffer — not a dead host)
            log.warning("unattributed ring failure at token %d: %s",
                        n_emitted, exc)
        resolve_s = clock() - t0
        self._pending_event = dict(
            token_index=n_emitted, failed_stage=failed_id,
            generation=self.state.generation,
            n_stages_before=before,
            n_stages_after=len(self.state.stages),
            plan=dataclasses.asdict(self.state.plan),
            halda=halda_info, detect_s=detect_s, resolve_s=resolve_s)

    def _replay(self, driver, cache, history: List[np.ndarray]):
        """Feed every history column through the ring (re-prefill);
        returns (cache, ln, next_token_column)."""
        ln = cache["len"]
        logits = None
        for col in history:
            tok = jnp.asarray(col, jnp.int32).reshape(self.batch, 1)
            logits, cache = driver.step(tok, ln, cache)
            ln = ln + 1
        nxt = np.asarray(
            jnp.argmax(logits[:, 0, :self.cfg.vocab], -1), np.int32)
        return cache, ln, nxt

    # -- generation -------------------------------------------------------- #

    def generate(self, prompts, max_new: int) -> np.ndarray:
        """Greedy-decode ``max_new`` tokens per sequence; returns
        ``(batch, max_new)`` int32. Failures mid-stream recover per the
        module docstring; ``self.events`` records each one."""
        prompts = np.asarray(prompts, np.int32)
        if prompts.shape[0] != self.batch:
            raise ValueError(f"prompts batch {prompts.shape[0]} != "
                             f"engine batch {self.batch}")
        history: List[np.ndarray] = [prompts[:, t]
                                     for t in range(prompts.shape[1])]
        emitted: List[np.ndarray] = []
        driver = None
        failovers = 0
        while len(emitted) < max_new:
            try:
                if driver is None:
                    t_b0 = clock()
                    driver, cache = self._build()
                    rebuild_s = clock() - t_b0
                    t_r0 = clock()
                    cache, ln, nxt = self._replay(driver, cache, history)
                    replay_s = clock() - t_r0
                    ev = getattr(self, "_pending_event", None)
                    if ev is not None:
                        fe = FailoverEvent(
                            **ev, rebuild_s=rebuild_s, replay_s=replay_s,
                            tokens_lost=0,
                            replayed_tokens=len(history))
                        self.events.append(fe)
                        # recovery splits land on the shared timeline as
                        # back-to-back spans ending now
                        self.tracer.ingest_failover_event(fe,
                                                          t_end=clock())
                        self._pending_event = None
                while len(emitted) < max_new:
                    emitted.append(nxt)
                    history.append(nxt)
                    if len(emitted) >= max_new:
                        break
                    tok = jnp.asarray(nxt, jnp.int32).reshape(
                        self.batch, 1)
                    logits, cache = driver.step(tok, ln, cache)
                    ln = ln + 1
                    nxt = np.asarray(
                        jnp.argmax(logits[:, 0, :self.cfg.vocab], -1),
                        np.int32)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as exc:
                t_caught = clock()
                self.tracer.instant("stage_failure", cat="failover",
                                    track="failover",
                                    token_index=len(emitted),
                                    error=type(exc).__name__)
                failovers += 1
                if failovers > self.max_failovers:
                    raise
                log.warning("ring failure at token %d (failover %d/%d): "
                            "%s", len(emitted), failovers,
                            self.max_failovers, exc)
                if driver is not None:
                    driver.close()
                    driver = None
                self._resolve(exc, len(emitted), t_caught)
        return np.stack(emitted, axis=1) if emitted \
            else np.zeros((self.batch, 0), np.int32)

    def stats(self):
        return self.driver.stats() if self.driver is not None else None

    def close(self) -> None:
        if self.driver is not None:
            self.driver.close()
            self.driver = None
