"""Speculative decoding: greedy draft/verify loop over two model stacks.

A small *draft* model proposes ``gamma`` tokens autoregressively; the
target model then scores all ``gamma + 1`` positions (the pending token
followed by the drafts) in ONE multi-token verify pass — either
``models.decode_step`` with T > 1 on a single host or the piped-ring
verify step (``runtime.serve.build_ring_serve_step(n_tokens=gamma+1)``).
The verify pass streams each layer's weights once for the whole block,
which is why it wins on the paper's weight-bandwidth-bound home clusters
(Ghidorah, arXiv 2505.23219; PIPO, arXiv 2504.03664).

Greedy acceptance keeps the emitted stream *byte-identical* to plain
greedy decode of the target: drafts are accepted while they match the
target argmax, and the first mismatch is replaced by the target's own
token, so every cycle emits between 1 and gamma + 1 tokens. Rejected
cache positions roll back by resetting the per-slot ``len`` counter —
entries past ``len`` are position-masked and the next write lands at
``len``, so no data movement is needed (see ``models.rollback_cache``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.latency import expected_tokens_per_cycle  # noqa: F401  (re-export)
from ..models.model import rollback_cache


@dataclasses.dataclass
class SpecCycleResult:
    """Host-side view of one draft/verify cycle."""

    next_tokens: jnp.ndarray     # (B, 1) new pending token per slot
    emitted: np.ndarray          # (B, gamma+1) emitted tokens (row-padded)
    n_emit: np.ndarray           # (B,) valid prefix of ``emitted`` (>= 1)

    @property
    def n_accepted(self) -> np.ndarray:
        return self.n_emit - 1


class SpeculativeDecoder:
    """Drives a draft model against a target verify function.

    draft_decode(d_cache, tokens (B, 1)) -> (logits (B, 1, V), d_cache)
    verify(t_cache, tokens (B, T))       -> (logits (B, T, V), t_cache)

    Both caches carry a per-sequence ``len`` counter (the only thing the
    rollback touches). The decoder owns the draft-side cache and its
    prefill/slot plumbing so the serving engine only threads the target
    cache through, exactly as in vanilla decode.
    """

    def __init__(self, draft_decode: Callable, verify: Callable, *,
                 gamma: int = 4,
                 draft_cache: Optional[Dict] = None,
                 draft_prefill_one: Optional[Callable] = None,
                 draft_write_slot: Optional[Callable] = None,
                 vocab: Optional[int] = None):
        assert gamma >= 1
        self.draft_decode = draft_decode
        self.verify = verify
        self.gamma = gamma
        self.draft_cache = draft_cache
        self.draft_prefill_one = draft_prefill_one
        self.draft_write_slot = draft_write_slot
        #: true vocab size — REQUIRED when either model fn returns padded
        #: logits (the ring step pads vocab to a multiple of tp; a zero
        #: pad column would otherwise win the argmax whenever every real
        #: logit is negative). None = logits are already unpadded.
        self.vocab = vocab
        # aggregate bookkeeping (per-slot counters live in the engine)
        self.cycles = 0
        self.proposed = 0
        self.accepted = 0

    # ------------------------------------------------------------------ #

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    def admit(self, prompt: jnp.ndarray, slot: int, length: int) -> None:
        """Prefill the draft cache for a newly admitted request."""
        if self.draft_prefill_one is None:
            return
        _, slot_cache = self.draft_prefill_one(prompt)
        self.draft_cache = self.draft_write_slot(self.draft_cache,
                                                 slot_cache, slot, length)

    def cycle(self, t_cache: Dict, tokens: jnp.ndarray,
              active=None) -> Tuple[Dict, SpecCycleResult]:
        """One draft/verify cycle for the whole batch.

        ``tokens``: (B, 1) pending token per slot — emitted already but in
        neither cache. ``active``: optional iterable of occupied slot
        indices; only those rows feed the aggregate acceptance counters
        (free slots decode junk). Returns the rolled-back target cache and
        the emitted block; the draft cache is updated in place.
        """
        B = tokens.shape[0]
        g = self.gamma
        d_cache = self.draft_cache
        t_len0 = t_cache["len"]
        d_len0 = d_cache["len"]

        # -- draft gamma tokens; one extra step banks the last draft's KV
        #    so a fully-accepted cycle leaves the draft cache complete.
        drafts = []
        cur = tokens
        for _ in range(g):
            lg, d_cache = self.draft_decode(d_cache, cur)
            lg = lg if self.vocab is None else lg[..., :self.vocab]
            cur = jnp.argmax(lg[:, -1], -1)[:, None].astype(tokens.dtype)
            drafts.append(cur)
        _, d_cache = self.draft_decode(d_cache, cur)
        draft_blk = jnp.concatenate(drafts, axis=1)          # (B, g)

        # -- one multi-token verify pass on the target --------------------
        ver_in = jnp.concatenate([tokens, draft_blk], axis=1)  # (B, g+1)
        logits, t_cache = self.verify(t_cache, ver_in)
        logits = logits if self.vocab is None else logits[..., :self.vocab]
        tgt = jnp.argmax(logits, -1).astype(tokens.dtype)      # (B, g+1)

        # -- greedy acceptance: longest prefix where draft == target ------
        ok = draft_blk == tgt[:, :-1]                          # (B, g)
        ok_pad = jnp.pad(ok, ((0, 0), (0, 1)), constant_values=False)
        n_acc = jnp.argmin(ok_pad, axis=1)                     # (B,)
        corr = jnp.take_along_axis(tgt, n_acc[:, None], axis=1)  # (B, 1)
        idx = jnp.arange(g + 1, dtype=n_acc.dtype)[None, :]
        emitted = jnp.pad(draft_blk, ((0, 0), (0, 1)))
        emitted = jnp.where(idx == n_acc[:, None], corr, emitted)

        # -- rollback: keep pending + accepted drafts, drop the rest ------
        t_cache = rollback_cache(t_cache, t_len0 + n_acc + 1)
        self.draft_cache = rollback_cache(d_cache, d_len0 + n_acc + 1)

        n_emit = np.asarray(n_acc) + 1
        rows = list(active) if active is not None else range(int(B))
        self.cycles += 1
        self.proposed += len(rows) * g
        self.accepted += int(sum(n_emit[i] for i in rows)) - len(rows)
        return t_cache, SpecCycleResult(next_tokens=corr,
                                        emitted=np.asarray(emitted),
                                        n_emit=n_emit)
