"""Optimizer substrate (no external deps): AdamW with global-norm clipping.

Optimizer state is sharded like the parameters (FSDP), so per-chip memory
is (params + 2 moments) / |data axis|.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100

    def init(self, params: Any) -> AdamState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                             params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                         nu=jax.tree.map(jnp.copy, zeros))

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads: Any, state: AdamState, params: Any
               ) -> Tuple[Any, AdamState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** t)
        nu_hat_scale = 1.0 / (1 - b2 ** t)
        lr = self.schedule(step)

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
