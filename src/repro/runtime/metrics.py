"""Serving-scale request metrics: counters, gauges, streaming histograms.

``runtime/telemetry.py`` sees individual token steps and worker spans;
this layer sees *requests*. It provides the measurement substrate the
serving benchmarks gate on (p50/p99 TTFT and TPOT, queue wait, shed
classification) without retaining per-sample data:

  * :class:`Counter` — monotonic, labeled (``requests/rejected{reason=…}``).
  * :class:`Gauge` — last-value, fed by registered sample sources
    (BlockPool occupancy, TierManager bytes, batcher slots, …).
  * :class:`LogHistogram` — streaming log-bucketed histogram: geometric
    buckets (growth ``1.1`` ≈ 4.8% worst-case quantile error), a sparse
    ``bucket→count`` dict, exact ``count/sum/min/max``, mergeable across
    registries, p50/p90/p99 in O(buckets) — no samples retained.
  * :class:`MetricsRegistry` — thread-safe home for all of the above,
    with three exposure paths: :meth:`MetricsRegistry.prometheus_text`,
    a JSON :meth:`MetricsRegistry.snapshot` checked by
    :func:`validate_metrics_snapshot` (mirroring
    ``telemetry.validate_chrome_trace``), and the rolling
    ``serve --metrics-interval`` line.
  * :class:`RequestTrace` / :class:`RequestTracker` — per-request
    lifecycle (submit → queue_wait → admit → prefill/restore →
    per-token decode → finish/reject) recorded by ``ContinuousBatcher``;
    finished traces land in a bounded log that doubles as the
    exact-sample reference the histogram gates compare against.

Everything is stdlib + numpy-free on the hot path; recording is a dict
increment under a lock, and an engine built with ``metrics=None`` pays
nothing (every call site is guarded).
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .telemetry import clock

SCHEMA = "repro-metrics-v1"
DEFAULT_GROWTH = 1.1


def _label_key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter. ``inc`` with a negative amount raises."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self.value += n


class Gauge:
    """Last-value gauge (free to move both ways)."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class LogHistogram:
    """Streaming log-bucketed histogram.

    Positive observations land in geometric buckets
    ``[growth**i, growth**(i+1))``; zero/negative observations share a
    dedicated zero bucket (durations can legitimately round to 0).
    Quantiles walk the cumulative counts and return the geometric bucket
    midpoint clamped to the exact ``[min, max]`` — so any quantile is
    within one bucket of relative error (a factor of ``growth``) of the
    same-rank exact sample, and p0/p100 are exact. Merging sums sparse
    bucket dicts, which is associative and lossless (registries shard
    across workers and merge at export).
    """

    __slots__ = ("growth", "_lg", "count", "total", "min", "max",
                 "zero_count", "buckets", "_lock")

    def __init__(self, growth: float = DEFAULT_GROWTH):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self._lg = math.log(self.growth)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.zero_count = 0
        self.buckets: Dict[int, int] = {}
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if v <= 0.0:
                self.zero_count += 1
            else:
                idx = math.floor(math.log(v) / self._lg)
                self.buckets[idx] = self.buckets.get(idx, 0) + 1

    def merge(self, other: "LogHistogram") -> None:
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError("cannot merge histograms with different growth")
        with self._lock, other._lock:
            self.count += other.count
            self.total += other.total
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
            self.zero_count += other.zero_count
            for idx, c in other.buckets.items():
                self.buckets[idx] = self.buckets.get(idx, 0) + c

    def quantile(self, q: float) -> float:
        """Inverted-CDF quantile: the bucket of the smallest sample whose
        cumulative count reaches ``ceil(q * count)`` (matches
        ``numpy.quantile(..., method="inverted_cdf")`` up to bucket
        rounding)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            if self.count == 0:
                return math.nan
            if q == 0.0:
                return self.min            # extremes are tracked exactly
            if q == 1.0:
                return self.max
            target = max(1, math.ceil(q * self.count))
            cum = self.zero_count
            if cum >= target:
                # zero-bucket sample: its exact value is <= 0, clamp into
                # the observed range
                return min(max(0.0, self.min), self.max)
            for idx in sorted(self.buckets):
                cum += self.buckets[idx]
                if cum >= target:
                    mid = self.growth ** (idx + 0.5)
                    return min(max(mid, self.min), self.max)
            return self.max          # unreachable unless counts drifted

    def quantiles(self, qs: Sequence[float]) -> List[float]:
        return [self.quantile(q) for q in qs]

    def state(self) -> dict:
        with self._lock:
            return {
                "growth": self.growth,
                "count": self.count,
                "sum": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "zero_count": self.zero_count,
                "buckets": {str(i): c for i, c in sorted(self.buckets.items())},
            }


@dataclasses.dataclass
class RequestTrace:
    """One request's lifecycle timestamps (shared ``telemetry.clock``)."""

    uid: int
    submit_t: float
    prompt_len: int = 0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    n_tokens: int = 0
    max_gap_s: float = 0.0            # worst inter-token gap (stall peak)
    restored: bool = False            # parked-session restore admit
    outcome: str = "pending"          # pending | finished | shed | rejected
    reason: str = ""                  # reject/shed classification code

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admit_t is None:
            return None
        return max(self.admit_t - self.submit_t, 0.0)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return max(self.first_token_t - self.submit_t, 0.0)

    @property
    def tpot_s(self) -> Optional[float]:
        if (self.first_token_t is None or self.finish_t is None
                or self.n_tokens < 2):
            return None
        return max(self.finish_t - self.first_token_t, 0.0) \
            / (self.n_tokens - 1)

    @property
    def e2e_s(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return max(self.finish_t - self.submit_t, 0.0)


class MetricsRegistry:
    """Thread-safe registry of counters, gauges and histograms, plus a
    bounded log of completed :class:`RequestTrace` records (the
    exact-sample reference for histogram-agreement gates; evictions are
    counted, never silent)."""

    def __init__(self, *, growth: float = DEFAULT_GROWTH,
                 request_log_size: int = 4096):
        self.growth = growth
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, LogHistogram] = {}
        self._sources: Dict[str, Callable[[], Dict[str, float]]] = {}
        self.request_log: deque = deque(maxlen=request_log_size)
        self.request_log_evicted = 0

    # -- get-or-create accessors --------------------------------------- #

    def counter(self, name: str, **labels) -> Counter:
        key = _label_key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(name, labels)
            return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = _label_key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge(name, labels)
            return g

    def histogram(self, name: str, **labels) -> LogHistogram:
        key = _label_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = LogHistogram(self.growth)
            return h

    # -- recording shorthands ------------------------------------------ #

    def inc(self, name: str, n: int = 1, **labels) -> None:
        self.counter(name, **labels).inc(n)

    def set_gauge(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.histogram(name, **labels).observe(v)

    def record_request(self, trace: RequestTrace) -> None:
        with self._lock:
            if len(self.request_log) == self.request_log.maxlen:
                self.request_log_evicted += 1
            self.request_log.append(trace)

    # -- gauge sampling ------------------------------------------------- #

    def add_source(self, name: str,
                   fn: Callable[[], Dict[str, float]]) -> None:
        """Register a callable returning ``{gauge_name: value}``; polled
        by :meth:`sample` (subsystems expose state without the registry
        reaching into them)."""
        with self._lock:
            self._sources[name] = fn

    def sample(self) -> None:
        with self._lock:
            sources = list(self._sources.values())
        for fn in sources:
            for name, v in fn().items():
                self.set_gauge(name, v)

    # -- exposure ------------------------------------------------------- #

    def snapshot(self) -> dict:
        """JSON-serializable snapshot (validated by
        :func:`validate_metrics_snapshot`)."""
        self.sample()
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = dict(self._hists)
            log_n = len(self.request_log)
            evicted = self.request_log_evicted
        return {
            "schema": SCHEMA,
            "t": clock(),
            "counters": counters,
            "gauges": gauges,
            "histograms": {k: h.state() for k, h in hists.items()},
            "request_log": {"logged": log_n, "evicted": evicted},
        }

    def percentile_summary(self) -> Dict[str, float]:
        """Flat ``{hist/pXX: value}`` dict for rolling console output."""
        out: Dict[str, float] = {}
        with self._lock:
            hists = dict(self._hists)
        for key, h in hists.items():
            if h.count == 0:
                continue
            p50, p90, p99 = h.quantiles((0.5, 0.9, 0.99))
            out[f"{key}/p50"] = p50
            out[f"{key}/p90"] = p90
            out[f"{key}/p99"] = p99
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition: counters as ``_total``, histograms
        as summaries (quantile labels + ``_sum``/``_count``)."""
        lines: List[str] = []
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = [(k, h) for k, h in self._hists.items()]
        typed = set()

        def emit_type(name: str, kind: str):
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for c in counters:
            name = _prom_name(c.name) + "_total"
            emit_type(name, "counter")
            lines.append(f"{name}{_prom_labels(c.labels)} {c.value}")
        for g in gauges:
            name = _prom_name(g.name)
            emit_type(name, "gauge")
            lines.append(f"{name}{_prom_labels(g.labels)} {_fmt(g.value)}")
        for key, h in hists:
            labels = _parse_key_labels(key)
            name = _prom_name(_parse_key_name(key))
            emit_type(name, "summary")
            st = h.state()
            for q in (0.5, 0.9, 0.99):
                lab = dict(labels)
                lab["quantile"] = f"{q}"
                v = h.quantile(q)
                lines.append(f"{name}{_prom_labels(lab)} {_fmt(v)}")
            lines.append(f"{name}_sum{_prom_labels(labels)} "
                         f"{_fmt(st['sum'])}")
            lines.append(f"{name}_count{_prom_labels(labels)} "
                         f"{st['count']}")
            if st["count"]:
                lines.append(f"{name}_min{_prom_labels(labels)} "
                             f"{_fmt(st['min'])}")
                lines.append(f"{name}_max{_prom_labels(labels)} "
                             f"{_fmt(st['max'])}")
        return "\n".join(lines) + "\n"

    def export_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path


def _prom_name(name: str) -> str:
    safe = "".join(ch if (ch.isalnum() or ch == "_") else "_"
                   for ch in name)
    if not safe or not (safe[0].isalpha() or safe[0] == "_"):
        safe = "_" + safe
    return "repro_" + safe


def _prom_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    def esc(v):  # noqa: E306 — tiny local helper
        return str(v).replace("\\", "\\\\").replace('"', '\\"')
    inner = ",".join(f'{k}="{esc(labels[k])}"' for k in sorted(labels))
    return "{" + inner + "}"


def _parse_key_name(key: str) -> str:
    return key.split("{", 1)[0]


def _parse_key_labels(key: str) -> Dict[str, str]:
    if "{" not in key:
        return {}
    inner = key.split("{", 1)[1].rstrip("}")
    out = {}
    for part in inner.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _fmt(v: float) -> str:
    if v != v:                       # NaN
        return "NaN"
    return repr(float(v))


# ---------------------------------------------------------------------- #
# Request lifecycle recorder (ContinuousBatcher-facing)
# ---------------------------------------------------------------------- #

SHED_CODES = ("shed_capacity", "deferred_ttl_expired")


class RequestTracker:
    """Per-request lifecycle recorder bound to a registry.

    The engine calls ``submit`` when a request becomes visible (its
    arrival time passes, or it enters the admit loop), ``admitted`` when
    a slot is claimed (queue wait observed; ``restored=True`` marks a
    parked-session restore), ``token`` per emitted token (the first one
    stamps TTFT), ``finished``/``rejected`` to close the trace. All
    methods are idempotent-friendly and no-ops for unknown uids, so the
    engine never has to special-case restore/defer orderings.
    """

    def __init__(self, registry: MetricsRegistry):
        self.reg = registry
        self._live: Dict[int, RequestTrace] = {}

    def submit(self, uid: int, *, t: Optional[float] = None,
               prompt_len: int = 0) -> None:
        if uid in self._live:
            return
        self._live[uid] = RequestTrace(
            uid=uid, submit_t=clock() if t is None else t,
            prompt_len=prompt_len)
        self.reg.inc("requests/submitted")

    def admitted(self, uid: int, *, restored: bool = False) -> None:
        tr = self._live.get(uid)
        if tr is None:
            return
        tr.admit_t = clock()
        tr.restored = restored
        self.reg.inc("requests/admitted")
        if restored:
            self.reg.inc("requests/restored")
        self.reg.observe("request/queue_wait_s", tr.queue_wait_s)

    def prefill_done(self, uid: int, seconds: float) -> None:
        self.reg.observe("request/prefill_s", seconds)

    def prefill_chunks(self, uid: int, n: int) -> None:
        """Chunked admission: how many paged-prefill chunks this request
        took (1 for an unchunked or fully prefix-shared admit)."""
        self.reg.observe("request/prefill_chunks", float(n))

    def interleave_stall(self, seconds: float) -> None:
        """Time active decode slots spent waiting on one prefill chunk
        before their interleaved step ran — the per-chunk TPOT tax of
        chunked admission (the whole-prefill stall it replaces books
        nothing here; compare ``decode/step_s`` spikes instead)."""
        self.reg.counter("decode/interleave_stall_s").inc(seconds)

    def token(self, uid: int, n: int = 1) -> None:
        tr = self._live.get(uid)
        if tr is None:
            return
        now = clock()
        if tr.first_token_t is None:
            tr.first_token_t = now
            self.reg.observe("request/ttft_s", tr.ttft_s)
        else:
            # worst single stall between emissions — the TPOT *spike* an
            # unchunked long admit causes (averages hide it)
            tr.max_gap_s = max(tr.max_gap_s, now - tr.last_token_t)
        tr.last_token_t = now
        tr.n_tokens += n
        self.reg.inc("tokens/generated", n)

    def finished(self, uid: int) -> None:
        tr = self._live.pop(uid, None)
        if tr is None:
            return
        tr.finish_t = clock()
        tr.outcome = "finished"
        self.reg.inc("requests/finished")
        self.reg.observe("request/e2e_s", tr.e2e_s)
        self.reg.observe("request/tokens", tr.n_tokens)
        if tr.tpot_s is not None:
            self.reg.observe("request/tpot_s", tr.tpot_s)
        if tr.n_tokens >= 2:
            self.reg.observe("request/max_gap_s", tr.max_gap_s)
        self.reg.record_request(tr)

    def rejected(self, uid: int, code: str, reason: str = "") -> None:
        tr = self._live.pop(uid, None)
        if tr is None:
            tr = RequestTrace(uid=uid, submit_t=clock())
        tr.finish_t = clock()
        tr.outcome = "shed" if code in SHED_CODES else "rejected"
        tr.reason = code
        self.reg.inc("requests/rejected", reason=code)
        self.reg.record_request(tr)

    def step_done(self, seconds: float) -> None:
        self.reg.observe("decode/step_s", seconds)


# ---------------------------------------------------------------------- #
# Snapshot validation (mirrors telemetry.validate_chrome_trace)
# ---------------------------------------------------------------------- #

def validate_metrics_snapshot(doc, require: Sequence[str] = ()) -> dict:
    """Validate a metrics snapshot (dict or JSON path): schema marker,
    counter monotonicity (>= 0), histogram internal consistency
    (``count == zero_count + Σ buckets``, ordered quantiles inside
    ``[min, max]``), and that every name in ``require`` matches at least
    one metric key (substring). Raises ``ValueError`` on any violation;
    returns a summary dict."""
    if isinstance(doc, str):
        with open(doc) as f:
            doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        raise ValueError(f"not a metrics snapshot (schema != {SCHEMA!r})")
    counters = doc.get("counters", {})
    gauges = doc.get("gauges", {})
    hists = doc.get("histograms", {})
    for key, v in counters.items():
        # seconds-valued counters (e.g. decode/interleave_stall_s) are
        # floats; monotonicity means non-negative and finite either way
        if (not isinstance(v, (int, float)) or isinstance(v, bool)
                or v != v or v < 0):
            raise ValueError(f"counter {key}: non-monotonic value {v!r}")
    for key, v in gauges.items():
        if not isinstance(v, (int, float)) or v != v:
            raise ValueError(f"gauge {key}: non-numeric value {v!r}")
    quantile_summary = {}
    for key, st in hists.items():
        n = st.get("count", 0)
        bsum = st.get("zero_count", 0) + sum(st.get("buckets", {}).values())
        if n != bsum:
            raise ValueError(
                f"histogram {key}: count {n} != bucket sum {bsum}")
        if any(c <= 0 for c in st.get("buckets", {}).values()):
            raise ValueError(f"histogram {key}: non-positive bucket count")
        if n > 0:
            h = LogHistogram(st.get("growth", DEFAULT_GROWTH))
            h.count = n
            h.zero_count = st["zero_count"]
            h.min = st["min"]
            h.max = st["max"]
            h.total = st["sum"]
            h.buckets = {int(i): c for i, c in st["buckets"].items()}
            p50, p90, p99 = h.quantiles((0.5, 0.9, 0.99))
            eps = 1e-9 + 1e-9 * abs(st["max"])
            ordered = (st["min"] - eps <= p50 <= p90 + eps
                       and p90 <= p99 + eps <= st["max"] + 2 * eps)
            if not ordered:
                raise ValueError(
                    f"histogram {key}: quantiles not ordered within "
                    f"[min, max]: min={st['min']} p50={p50} p90={p90} "
                    f"p99={p99} max={st['max']}")
            if not math.isfinite(st["sum"]):
                raise ValueError(f"histogram {key}: non-finite sum")
            quantile_summary[key] = {"p50": p50, "p90": p90, "p99": p99}
    all_keys = list(counters) + list(gauges) + list(hists)
    for name in require:
        if not any(name in k for k in all_keys):
            raise ValueError(
                f"required metric {name!r} not found among "
                f"{len(all_keys)} keys")
    return {
        "counters": len(counters),
        "gauges": len(gauges),
        "histograms": len(hists),
        "quantiles": quantile_summary,
        "request_log": doc.get("request_log", {}),
    }


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        description="validate a repro metrics snapshot")
    p.add_argument("--validate", required=True, metavar="SNAPSHOT.json")
    p.add_argument("--require", nargs="*", default=[],
                   help="metric names that must be present (substring)")
    args = p.parse_args(argv)
    try:
        info = validate_metrics_snapshot(args.validate,
                                         require=args.require)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"INVALID: {e}")
        return 1
    print(f"OK: {info['counters']} counters, {info['gauges']} gauges, "
          f"{info['histograms']} histograms, "
          f"request_log={info['request_log']}")
    for key, qs in sorted(info["quantiles"].items()):
        print(f"  {key}: p50={qs['p50']:.6g} p90={qs['p90']:.6g} "
              f"p99={qs['p99']:.6g}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
