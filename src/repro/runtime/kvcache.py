"""Paged KV cache: block-pool allocator, prefix reuse, host offload.

The dense cache (``models.model.init_cache``) preallocates
``(L, B, max_len, ...)`` — memory scales with ``batch * max_len`` no
matter how many tokens are actually live, which is what OOMs first on
low-RAM devices and caps ``ContinuousBatcher`` concurrency. This module
applies the paper's working-window recipe to KV state the way PR 2/3
applied it to weights:

  * **BlockPool** — fixed-size token pages with refcounts. Sequences own
    pages only for tokens they actually hold; HBM high-water tracks
    *active* tokens, not the batch envelope.
  * **Prefix reuse** — every full prompt page (and the final partial
    page) is content-addressed by its exact chained token key (compared
    by value — a collision can never silently share the wrong bytes);
    identical prompt prefixes retain the same refcounted pages instead
    of recomputing and re-storing them. Writes into a shared page copy-on-write at the
    divergence page; writes into a privately-held but still-addressable
    page unregister its hash first, so the content a hash names is
    immutable by construction.
  * **Host offload** — pages whose refcount drops to zero stay resident
    as an LRU prefix cache; when the pool needs room they are evicted to
    pinned host copies instead of being discarded. A prefix hit on an
    offloaded page allocates a fresh device page and fetches the bytes
    back on a background staging thread (the double-buffer pattern of
    ``runtime.streaming``), so the H2D copy overlaps the admit's prefill
    compute exactly like layer prefetch overlaps decode. The fetch
    timeline reuses ``PrefetchEvent`` so ``core.latency`` can cross-check
    the offload-traffic term against measurement.

Device state lives in the engine-threaded cache pytree
(``{"pages", "block_table", "len"}``); this module's classes hold only
host bookkeeping plus the staging thread, and every device mutation
takes and returns the cache functionally.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .iopolicy import IOPolicy, StallTimeout, WorkerHealth
from .streaming import PrefetchEvent, PrefetchStats
from .telemetry import NULL_TRACER, clock

log = logging.getLogger(__name__)

Params = Dict[str, Any]

#: page id 0 is a write sink: freed slots keep decoding junk into it (the
#: batch is fixed-width, inactive rows still run), so it is never handed
#: out by the allocator and its content is never read unmasked.
SINK_PAGE = 0


class PoolExhausted(RuntimeError):
    """The block pool cannot satisfy an allocation (clear admit error)."""


def chain_key(prev: tuple, tokens: Sequence[int], count: int) -> tuple:
    """Content key of a prompt page given its predecessor's key.

    The key IS the (nested) token chain, not a digest — lookups compare
    the actual tokens, so a collision can never silently share another
    prompt's KV pages. ``count`` participates so a partial page
    (count < page_tokens) only matches a page with the identical token
    count — partial pages are shared only between byte-identical
    prompts. Start the chain with ``()``.
    """
    return (prev, count, tuple(int(t) for t in tokens))


# --------------------------------------------------------------------------- #
#  block pool (host-side allocator)
# --------------------------------------------------------------------------- #

class BlockPool:
    """Refcounted fixed-size page allocator with an LRU prefix cache.

    Page states:
      free     — on the free list, content meaningless;
      active   — refcount >= 1 (held by one or more slots);
      cached   — refcount 0 but still hash-addressable (prefix cache),
                 evicted LRU-first when the free list runs dry.

    ``release`` on a page that is not active raises — the double-free is
    a bug in the caller, not a condition to paper over.
    """

    def __init__(self, n_pages: int, page_tokens: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the write sink)")
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self._free: List[int] = list(range(n_pages - 1, SINK_PAGE, -1))
        self._ref: Dict[int, int] = {}
        self._hash_of: Dict[int, Any] = {}       # pid -> registered key
        self._pid_of: Dict[Any, int] = {}        # content key -> pid
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref 0
        self.alloc_count = 0
        self.evictions = 0

    # -- queries ----------------------------------------------------------- #

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def lookup(self, h) -> Optional[int]:
        """Device-resident page registered under content key ``h`` (or
        None). Keys are compared by value (the exact token chain), so a
        hit is always the right bytes."""
        return self._pid_of.get(h)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return len(self._ref)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    def available(self) -> int:
        """Pages an alloc burst could obtain (free + evictable cached)."""
        return len(self._free) + len(self._cached)

    # -- lifecycle --------------------------------------------------------- #

    def alloc(self, *, evict_cb=None) -> int:
        """Take a page (refcount 1). Falls back to evicting the LRU cached
        page; ``evict_cb(pid, h)`` runs first so the owner can offload the
        content. Raises ``PoolExhausted`` when neither source has a page.
        """
        if self._free:
            pid = self._free.pop()
        elif self._cached:
            pid, _ = self._cached.popitem(last=False)      # LRU
            h = self._hash_of.pop(pid)
            del self._pid_of[h]
            self.evictions += 1
            if evict_cb is not None:
                evict_cb(pid, h)
        else:
            raise PoolExhausted(
                f"KV block pool exhausted: {self.n_pages - 1} pages, "
                f"{self.n_active} active, none cached/free")
        self._ref[pid] = 1
        self.alloc_count += 1
        return pid

    def retain(self, pid: int) -> None:
        """Add a reference (prefix share / cached-page revival)."""
        if pid == SINK_PAGE:
            raise ValueError("cannot retain the sink page")
        if pid in self._cached:
            del self._cached[pid]
            self._ref[pid] = 1
        else:
            if pid not in self._ref:
                raise ValueError(f"retain of non-active page {pid}")
            self._ref[pid] += 1

    def release(self, pid: int) -> None:
        """Drop a reference; at zero the page goes to the prefix cache if
        hash-addressable, otherwise back to the free list."""
        n = self._ref.get(pid)
        if n is None:
            raise ValueError(f"double free of page {pid}")
        if n > 1:
            self._ref[pid] = n - 1
            return
        del self._ref[pid]
        if pid in self._hash_of:
            self._cached[pid] = None                       # MRU end
            self._cached.move_to_end(pid)
        else:
            self._free.append(pid)

    # -- hash addressing --------------------------------------------------- #

    def register(self, h, pid: int) -> None:
        """Make an active page addressable by content key ``h``."""
        if pid not in self._ref:
            raise ValueError(f"register of non-active page {pid}")
        old = self._pid_of.get(h)
        if old is not None and old != pid:
            # identical content already registered; keep the older page
            return
        self._pid_of[h] = pid
        self._hash_of[pid] = h

    def unregister(self, pid: int) -> None:
        """Forget a page's hash (it is about to be written in place)."""
        h = self._hash_of.pop(pid, None)
        if h is not None:
            self._pid_of.pop(h, None)

    # -- invariants (tests) ------------------------------------------------ #

    def check(self) -> None:
        free, active, cached = set(self._free), set(self._ref), \
            set(self._cached)
        assert SINK_PAGE not in free | active | cached
        assert not free & active and not free & cached \
            and not active & cached
        assert len(free) + len(active) + len(cached) == self.n_pages - 1
        assert all(n >= 1 for n in self._ref.values())
        assert cached <= set(self._hash_of)
        for h, pid in self._pid_of.items():
            assert self._hash_of.get(pid) == h


# --------------------------------------------------------------------------- #
#  host offload (staged fetch, streaming.py's double-buffer pattern)
# --------------------------------------------------------------------------- #

class BlockOffloader:
    """Host-side store of evicted pages + async device staging.

    ``offload`` (eviction path) copies a page's per-layer bytes to host
    synchronously — it runs inside an allocation that needs the device
    page now. ``schedule`` queues the reverse H2D transfer on a worker
    thread; ``get`` blocks until the staged device tree is ready. Fetches
    are scheduled at admit time and collected after the admit's prefill
    compute, so the copy overlaps compute exactly like the layer
    prefetcher's window reads.
    """

    def __init__(self, *, policy: Optional[IOPolicy] = None,
                 injector=None, tracer=None) -> None:
        self.policy = policy or IOPolicy()
        self.injector = injector          # faults.FaultInjector or None
        self.tracer = tracer or NULL_TRACER
        self.health = WorkerHealth(name="BlockOffloader")
        self.stall_s = 0.0                # get() blocked on a staging fetch
        self._host: Dict[int, Params] = {}                # hash -> np tree
        self._staged: Dict[int, Params] = {}              # hash -> jnp tree
        self._queue: List[int] = []
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._interrupted = False
        self._error: Optional[BaseException] = None
        self.events: List[PrefetchEvent] = []
        self.offloaded_bytes = 0
        self.fetched_bytes = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _h2d(self, tree: Params) -> Params:
        if self.injector is not None:
            self.injector.check("kv_h2d")
        return jax.tree.map(jnp.asarray, tree)            # H2D staging

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                h = self._queue.pop(0)
                tree = self._host.get(h)
            if tree is None:
                continue
            try:
                t0 = clock()
                staged = self.policy.run("kv_h2d",
                                         lambda: self._h2d(tree),
                                         health=self.health)
                t1 = clock()
            except (KeyboardInterrupt, SystemExit):
                # control flow: unblock waiters, then die loudly
                with self._cv:
                    self._stop = True
                    self._interrupted = True
                    self._cv.notify_all()
                raise
            except BaseException as e:   # surface in get(), don't deadlock
                with self._cv:
                    self._error = e
                    self._cv.notify_all()
                return
            nbytes = sum(np.asarray(a).nbytes
                         for a in jax.tree.leaves(tree))
            self.tracer.span_event(f"kv_h2d[{h}]", t0, t1, cat="kv",
                                   track="kv-offloader", nbytes=nbytes)
            with self._cv:
                self._staged[h] = staged
                self.events.append(PrefetchEvent(0, t0, t1, nbytes))
                self.fetched_bytes += nbytes
                self._cv.notify_all()

    # -- eviction side ----------------------------------------------------- #

    def offload(self, h: int, tree: Params) -> None:
        def put():
            if self.injector is not None:
                self.injector.check("kv_d2h")
            return sum(np.asarray(a).nbytes
                       for a in jax.tree.leaves(tree))

        # the D2H copy happened in the eviction callback; this commits the
        # host store (and is where an injected kv_d2h fault surfaces) —
        # transient faults retry under the shared policy
        t0 = clock()
        nbytes = self.policy.run("kv_d2h", put, health=self.health)
        self.tracer.span_event(f"kv_d2h[{h}]", t0, clock(), cat="kv",
                               track="kv-offloader", nbytes=nbytes)
        with self._cv:
            self._host[h] = tree
            self.offloaded_bytes += nbytes

    def holds(self, h: int) -> bool:
        with self._cv:
            return h in self._host

    # -- fetch side -------------------------------------------------------- #

    def schedule(self, h: int) -> None:
        with self._cv:
            if h in self._staged or h in self._queue:
                return
            self._queue.append(h)
            self._cv.notify_all()

    def get(self, h: int, *, timeout: Optional[float] = None) -> Params:
        if timeout is None:
            timeout = self.policy.get_timeout_s
        t_enter = clock()
        deadline = t_enter + timeout
        with self.tracer.phase("h2d", cat="kv", track="decode",
                               min_dur=2e-4, label=f"kv_wait[{h}]"):
            with self._cv:
                while h not in self._staged:
                    if self._error is not None:
                        raise RuntimeError(
                            f"offload fetch of page hash {h} failed "
                            f"({self.health.report()})") from self._error
                    if self._stop:
                        raise RuntimeError(
                            "offloader stopped" + (
                                " (worker interrupted)"
                                if self._interrupted else ""))
                    remaining = deadline - clock()
                    if remaining <= 0:
                        self.health.stalled = True
                        raise StallTimeout(
                            f"offloaded page not staged within "
                            f"{timeout:.1f}s "
                            f"({self.health.report()})", op="kv_h2d")
                    self._cv.wait(min(remaining, 0.25))
                staged = self._staged.pop(h)
                self._host.pop(h, None)  # back on device; host copy done
                self.stall_s += clock() - t_enter
                return staged

    def stats(self) -> PrefetchStats:
        """Uniform ``PrefetchStats`` view — the same surface the layer
        and ring-bank prefetchers expose, so stall/retry counters from
        all three staging paths read identically in reports."""
        with self._cv:
            events = list(self.events)
            fetched = self.fetched_bytes
        return PrefetchStats(
            events=events, peak_resident_bytes=0,
            total_bytes_read=fetched, stall_s=self.stall_s,
            layers_served=len(events), releases=0,
            retries=self.health.retries)

    def close(self, timeout: float = 5.0) -> bool:
        """Stop the worker (idempotent); True once it has joined, False
        with a logged stall report if it is stuck."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            self.health.stalled = True
            log.error("BlockOffloader.close: worker failed to join "
                      "within %.1fs — %s", timeout, self.health.report())
            return False
        self.health.closed = True
        return True


# --------------------------------------------------------------------------- #
#  paged cache manager
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class KVStats:
    """Allocator + traffic view of a paged-cache run (benchmarks/gates)."""

    n_pages: int
    page_tokens: int
    page_bytes: int                   # one page across all layers/leaves
    active_pages_highwater: int       # max simultaneously-referenced pages
    active_tokens_highwater: int      # max live tokens across slots
    prefix_hits: int                  # pages obtained by hash match
    cow_copies: int
    evictions: int
    offloaded_bytes: int
    fetched_bytes: int
    fetch_events: List[PrefetchEvent]
    fetch_stall_s: float = 0.0        # admits blocked on a staging fetch
    fetch_retries: int = 0            # transient I/O retries (IOPolicy)

    @property
    def highwater_bytes(self) -> int:
        return self.active_pages_highwater * self.page_bytes

    def dense_bytes(self, batch: int, max_len: int) -> int:
        """What the dense (L, B, max_len, ...) preallocation would hold."""
        per_tok = self.page_bytes / max(self.page_tokens, 1)
        return int(batch * max_len * per_tok)


def paged_cache_spec(cfg) -> Dict[str, Tuple[int, ...]]:
    """Per-leaf trailing shapes of one cache line (one token, one layer)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged KV cache unsupported for family {cfg.family} "
            "(recurrent state has no per-token pages)")
    if cfg.kv_dtype == "int8":
        raise NotImplementedError(
            "paged KV cache does not support int8 KV quantization yet")
    if cfg.mla:
        return {"latent": (cfg.kv_lora_rank + cfg.qk_rope_dim,)}
    return {"k": (max(cfg.kv_heads, 1), cfg.head_dim),
            "v": (max(cfg.kv_heads, 1), cfg.head_dim)}


class PagedKVCache:
    """Owner of the block pool + per-slot page lists for a serving batch.

    The device arrays live in the cache pytree this class *builds* but
    does not hold: every mutating method threads the cache through
    functionally, so the engine's usual ``cache = f(cache, ...)`` flow is
    preserved and jit boundaries see plain arrays.

    cache = {
      "pages":       {leaf: (L, P, page_tokens, ...)},
      "block_table": (B, max_pages_per_slot) int32,
      "len":         (B,) int32,
    }
    """

    def __init__(self, cfg, *, batch: int, ctx: int, n_pages: int,
                 page_tokens: int = 16, dtype=jnp.float32,
                 offload: bool = True,
                 io_policy: Optional[IOPolicy] = None, injector=None,
                 tracer=None):
        self.cfg = cfg
        self.B = batch
        self.page_tokens = page_tokens
        self.max_pages = -(-ctx // page_tokens)
        self.ctx = self.max_pages * page_tokens
        self.pool = BlockPool(n_pages, page_tokens)
        self.offloader = BlockOffloader(policy=io_policy,
                                        injector=injector,
                                        tracer=tracer) \
            if offload else None
        self._spec = paged_cache_spec(cfg)
        self.dtype = dtype
        # host mirrors
        self._slot_pages: List[List[int]] = [[] for _ in range(batch)]
        self._len = [0] * batch
        #: worst-case page budget reserved per live slot (admission
        #: control): with sum(reserved) <= usable pages, per-step growth
        #: and CoW can always be satisfied from free + evictable pages,
        #: so decode never dies mid-step — exhaustion is an admit-time
        #: signal the engine can defer on.
        self._reserved = [0] * batch
        self._usable = n_pages - 1
        self._dirty = set(range(batch))          # table rows to (re)write
        #: slot -> [(page kind, content key)] for the admit in flight
        #: between plan_admit and install ("shared"|"fetched"|"fresh")
        self._admit_meta: Dict[int, List[Tuple[str, Any]]] = {}
        # stats
        self._active_pages_hw = 0
        self._active_tokens_hw = 0
        self.prefix_hits = 0
        self.cow_copies = 0

    # -- construction ------------------------------------------------------ #

    def init_cache(self) -> Dict[str, Any]:
        L = self.cfg.n_layers
        P, bs = self.pool.n_pages, self.page_tokens
        pages = {name: jnp.zeros((L, P, bs) + trail, self.dtype)
                 for name, trail in self._spec.items()}
        return {"pages": pages,
                "block_table": jnp.zeros((self.B, self.max_pages),
                                         jnp.int32),
                "len": jnp.zeros((self.B,), jnp.int32)}

    @property
    def page_bytes(self) -> int:
        L, bs = self.cfg.n_layers, self.page_tokens
        itemsize = jnp.zeros((), self.dtype).dtype.itemsize
        return sum(L * bs * int(np.prod(trail, dtype=np.int64)) * itemsize
                   for trail in self._spec.values())

    # -- stats ------------------------------------------------------------- #

    def _note_highwater(self) -> None:
        self._active_pages_hw = max(self._active_pages_hw,
                                    self.pool.n_active)
        self._active_tokens_hw = max(self._active_tokens_hw,
                                     sum(self._len))

    def stats(self) -> KVStats:
        off = self.offloader
        return KVStats(
            n_pages=self.pool.n_pages, page_tokens=self.page_tokens,
            page_bytes=self.page_bytes,
            active_pages_highwater=self._active_pages_hw,
            active_tokens_highwater=self._active_tokens_hw,
            prefix_hits=self.prefix_hits, cow_copies=self.cow_copies,
            evictions=self.pool.evictions,
            offloaded_bytes=off.offloaded_bytes if off else 0,
            fetched_bytes=off.fetched_bytes if off else 0,
            fetch_events=list(off.events) if off else [],
            fetch_stall_s=off.stall_s if off else 0.0,
            fetch_retries=off.health.retries if off else 0)

    # -- page content ops (functional on the cache) ------------------------ #

    def _evict_cb(self, cache):
        """Eviction hook: offload the page's bytes to host before reuse."""
        if self.offloader is None:
            return None

        def cb(pid, h):
            tree = {name: np.asarray(arr[:, pid])
                    for name, arr in cache["pages"].items()}
            self.offloader.offload(h, tree)
        return cb

    def _copy_page(self, cache, src: int, dst: int):
        pages = {name: arr.at[:, dst].set(arr[:, src])
                 for name, arr in cache["pages"].items()}
        return {**cache, "pages": pages}

    def _scatter_pages(self, cache, pids: List[int],
                       trees: List[Params]):
        """Write page contents (``trees[i]``: {leaf: (L, bs, ...)}) into
        pool positions ``pids`` — ONE batched update per leaf, so an
        n-page admit costs one pool-array copy instead of n."""
        if not pids:
            return cache
        idx = jnp.asarray(pids, jnp.int32)
        pages = dict(cache["pages"])
        for name in pages:
            stacked = jnp.stack([jnp.asarray(t[name]) for t in trees],
                                axis=1)
            pages[name] = pages[name].at[:, idx].set(
                stacked.astype(pages[name].dtype))
        return {**cache, "pages": pages}

    def _sync_tables(self, cache):
        """Write dirty slots' page lists (and lengths) into the device
        cache. Runs before the decode writes of a step, when the host
        mirror and the device counter agree for every live slot."""
        if not self._dirty:
            return cache
        table = np.asarray(cache["block_table"]).copy()
        lens = np.asarray(cache["len"]).copy()
        for slot in self._dirty:
            row = np.full((self.max_pages,), SINK_PAGE, np.int32)
            pids = self._slot_pages[slot][:self.max_pages]
            row[:len(pids)] = pids
            table[slot] = row
            lens[slot] = self._len[slot]
        self._dirty.clear()
        return {**cache, "block_table": jnp.asarray(table),
                "len": jnp.asarray(lens)}

    # -- admit ------------------------------------------------------------- #

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """Could this request be admitted into an *empty* pool?

        False means deferral is pointless — no amount of completed slots
        frees enough pages — so the engine sheds the request immediately
        with a clear "pool too small" error instead of starving it.
        """
        total = prompt_len + max_new
        if total > self.ctx:
            return False
        worst = -(-total // self.page_tokens) + 1
        return worst <= self._usable

    def plan_admit(self, cache, slot: int, prompt: Sequence[int],
                   max_new: int) -> Dict[str, int]:
        """Reserve pages for a prompt: prefix-share where hashes match,
        schedule background fetches for offloaded matches, allocate the
        rest (the alloc-on-demand half of the admit contract — the only
        rejections are a request too long for the slot table and pool
        exhaustion, both with clear errors).

        Runs *before* the prefill compute so offload fetches overlap it;
        ``install`` collects them afterwards. ``cache`` is read-only here
        (eviction offload copies page bytes device->host).
        """
        bs = self.page_tokens
        S, total = len(prompt), len(prompt) + max_new
        if total > self.ctx:
            raise ValueError(
                f"request needs {total} positions (prompt {S} + max_new "
                f"{max_new}) but the paged slot addresses only "
                f"{self.ctx} ({self.max_pages} pages x {bs} tokens)")
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        # worst-case lifetime pages: every position paged, +1 for the
        # copy-on-write clone of a shared divergence page
        worst = -(-total // bs) + 1
        committed = sum(self._reserved) + worst
        if committed > self._usable:
            raise PoolExhausted(
                f"KV block pool exhausted: admitting would oversubscribe "
                f"{committed}/{self._usable} pages "
                f"({sum(1 for r in self._reserved if r)} slots live)")
        n_blocks = -(-S // bs)
        pids: List[int] = []
        meta: List[Tuple[str, Any]] = []
        h: tuple = ()
        try:
            for j in range(n_blocks):
                toks = prompt[j * bs:(j + 1) * bs]
                h = chain_key(h, toks, len(toks))
                pid = self.pool.lookup(h)
                if pid is not None:                      # resident hit
                    self.pool.retain(pid)
                    kind = "shared"
                elif self.offloader is not None and self.offloader.holds(h):
                    pid = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    self.offloader.schedule(h)
                    self.pool.register(h, pid)
                    kind = "fetched"
                else:
                    pid = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    self.pool.register(h, pid)
                    kind = "fresh"
                pids.append(pid)
                meta.append((kind, h))
        except PoolExhausted:
            # roll the reservation back whole: pages registered for this
            # admit were never filled, so they must not survive into the
            # prefix cache
            for pid, (kind, _) in zip(pids, meta):
                if kind != "shared":
                    self.pool.unregister(pid)
                self.pool.release(pid)
            raise
        self.prefix_hits += sum(1 for k, _ in meta if k != "fresh")
        self._slot_pages[slot] = pids
        self._admit_meta[slot] = meta
        self._reserved[slot] = worst
        self._dirty.add(slot)
        return {k: sum(1 for kk, _ in meta if kk == k)
                for k in ("shared", "fetched", "fresh")}

    def abort_admit(self, slot: int) -> None:
        """Undo a ``plan_admit`` whose prefill failed: return the slot's
        pages (un-registering never-filled ones so they cannot enter the
        prefix cache) and drop its reservation. The engine calls this on
        any error between plan and install."""
        meta = self._admit_meta.pop(slot, None)
        if meta is None:
            return
        for pid, (kind, _) in zip(self._slot_pages[slot], meta):
            if kind != "shared":
                self.pool.unregister(pid)
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._reserved[slot] = 0
        self._len[slot] = 0
        self._dirty.add(slot)

    def install(self, cache, slot: int, slot_layers: Params,
                length: int) -> Dict[str, Any]:
        """Scatter a freshly-prefilled sequence's KV into its pages.

        ``slot_layers``: the per-layer cache of a single-sequence prefill
        (leaves ``(L, 1, S_cap, ...)``). Pages obtained by prefix share
        are skipped — their bytes are already correct and rewriting them
        would defeat the point; offloaded matches are collected from the
        staging thread here, after the prefill compute they overlapped.
        """
        bs = self.page_tokens
        meta = self._admit_meta.pop(slot)
        pids_w: List[int] = []
        trees: List[Params] = []
        for j, (pid, (kind, h)) in enumerate(
                zip(self._slot_pages[slot], meta)):
            if kind == "shared":
                continue
            if kind == "fetched":
                pids_w.append(pid)
                trees.append(self.offloader.get(h))
                continue
            lo = j * bs
            blk = {}
            for name, arr in slot_layers.items():
                # slice/pad on device: no host round-trip of prompt KV
                piece = jnp.asarray(arr)[:, 0, lo:lo + bs]
                if piece.shape[1] < bs:                   # partial page
                    pad = [(0, 0)] * piece.ndim
                    pad[1] = (0, bs - piece.shape[1])
                    piece = jnp.pad(piece, pad)
                blk[name] = piece
            pids_w.append(pid)
            trees.append(blk)
        cache = self._scatter_pages(cache, pids_w, trees)
        self._len[slot] = length
        self._dirty.add(slot)
        cache = self._sync_tables(cache)
        self._note_highwater()
        return cache

    # -- per-step maintenance ---------------------------------------------- #

    def begin_step(self, cache, active: Sequence[int], n_tokens: int
                   ) -> Dict[str, Any]:
        """Make the next ``n_tokens`` positions of every active slot
        writable: grow page lists across boundaries, copy-on-write shared
        pages in the write range, unregister hashes of private pages
        about to be written, and flush table/len cleanup of freed slots.
        """
        bs = self.page_tokens
        for slot in active:
            ln = self._len[slot]
            need = -(-(ln + n_tokens) // bs)
            if need > self.max_pages:
                raise PoolExhausted(
                    f"slot {slot} needs {need} pages "
                    f"(len {ln} + {n_tokens}) > table width "
                    f"{self.max_pages}")
            pids = self._slot_pages[slot]
            while len(pids) < need:
                pids.append(self.pool.alloc(
                    evict_cb=self._evict_cb(cache)))
                self._dirty.add(slot)
            first_blk = ln // bs
            last_blk = (ln + n_tokens - 1) // bs
            for j in range(first_blk, last_blk + 1):
                pid = pids[j]
                if self.pool.refcount(pid) > 1:           # divergence: CoW
                    new = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    cache = self._copy_page(cache, pid, new)
                    self.pool.release(pid)
                    pids[j] = new
                    self.cow_copies += 1
                    self._dirty.add(slot)
                else:
                    self.pool.unregister(pid)     # content will change
        cache = self._sync_tables(cache)
        self._note_highwater()
        return cache

    def advance(self, slot: int, n: int = 1) -> None:
        """Commit ``n`` generated tokens (vanilla decode bookkeeping)."""
        self._len[slot] += n

    def length(self, slot: int) -> int:
        """Host-mirrored valid length of ``slot`` (== device ``len`` at
        step boundaries)."""
        return self._len[slot]

    def trim_to(self, slot: int, new_len: int) -> None:
        """Speculative rollback: keep pages covering ``new_len`` tokens,
        free the rest (rejected drafts past the accepted length)."""
        bs = self.page_tokens
        keep = -(-new_len // bs) if new_len > 0 else 0
        pids = self._slot_pages[slot]
        for pid in pids[keep:]:
            self.pool.release(pid)
        if len(pids) > keep:
            del pids[keep:]
            self._dirty.add(slot)
        self._len[slot] = new_len

    def release_slot(self, slot: int) -> None:
        """Finished sequence: drop its references. Hashed prompt pages
        fall into the LRU prefix cache for future admits; private pages
        return to the free list. Table/len cleanup is applied lazily at
        the next ``begin_step`` (stale rows only ever feed the masked
        region until then)."""
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._len[slot] = 0
        self._reserved[slot] = 0
        self._dirty.add(slot)

    def close(self) -> None:
        if self.offloader is not None:
            self.offloader.close()


# --------------------------------------------------------------------------- #
#  continuous-batching integration
# --------------------------------------------------------------------------- #

def make_paged_engine(params, cfg, batch: int, ctx: int, *, n_pages: int,
                      page_tokens: int = 16, eos_id: Optional[int] = None,
                      spec=None, offload: bool = True,
                      cache_dtype=jnp.float32,
                      io_policy: Optional[IOPolicy] = None,
                      injector=None, tracer=None):
    """Build a ``ContinuousBatcher`` over a paged KV cache.

    Returns ``(engine, kv)``; drive it with ``engine.run(kv.init_cache(),
    requests)``. The decode step is ``models.decode_step_paged`` — greedy
    output is byte-identical to the dense engine's, only where KV lives
    changes.
    """
    from ..models import model as M
    from .engine import ContinuousBatcher

    kv = PagedKVCache(cfg, batch=batch, ctx=ctx, n_pages=n_pages,
                      page_tokens=page_tokens, dtype=cache_dtype,
                      offload=offload, io_policy=io_policy,
                      injector=injector, tracer=tracer)

    def prefill_one(prompt):
        c1 = M.init_cache(cfg, 1, ctx, dtype=cache_dtype)
        logits, c1 = M.prefill(params, cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def decode(cache, tokens):
        return M.decode_step_paged(params, cfg, cache, tokens)

    def write_slot(cache, slot_cache, slot, length):   # paged: kv.install
        raise RuntimeError("paged engine installs via kv, not write_slot")

    eng = ContinuousBatcher(batch, prefill_one, write_slot, decode,
                            eos_id=eos_id, spec=spec, kv=kv,
                            tracer=tracer)
    return eng, kv
