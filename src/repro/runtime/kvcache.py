"""Paged KV cache: block-pool allocator, prefix reuse, host offload.

The dense cache (``models.model.init_cache``) preallocates
``(L, B, max_len, ...)`` — memory scales with ``batch * max_len`` no
matter how many tokens are actually live, which is what OOMs first on
low-RAM devices and caps ``ContinuousBatcher`` concurrency. This module
applies the paper's working-window recipe to KV state the way PR 2/3
applied it to weights:

  * **BlockPool** — fixed-size token pages with refcounts. Sequences own
    pages only for tokens they actually hold; HBM high-water tracks
    *active* tokens, not the batch envelope.
  * **Prefix reuse** — every full prompt page (and the final partial
    page) is content-addressed by its exact chained token key (compared
    by value — a collision can never silently share the wrong bytes);
    identical prompt prefixes retain the same refcounted pages instead
    of recomputing and re-storing them. Writes into a shared page copy-on-write at the
    divergence page; writes into a privately-held but still-addressable
    page unregister its hash first, so the content a hash names is
    immutable by construction.
  * **Host offload** — pages whose refcount drops to zero stay resident
    as a prefix cache; when the pool needs room they are evicted to
    pinned host copies instead of being discarded. A prefix hit on an
    offloaded page allocates a fresh device page and fetches the bytes
    back on a background staging thread (the double-buffer pattern of
    ``runtime.streaming``), so the H2D copy overlaps the admit's prefill
    compute exactly like layer prefetch overlaps decode. The fetch
    timeline reuses ``PrefetchEvent`` so ``core.latency`` can cross-check
    the offload-traffic term against measurement.
  * **Tiered budget** — every resident byte (the device pool, host
    copies, disk page files) leases from one shared
    ``runtime.memory.TierManager``; a full host tier spills the
    offloader's coldest pages to a ``PageFileStore`` disk tier
    (``kv_d2disk``/``kv_disk2h`` under the same retry policy and fault
    injector as every other I/O path) and eviction can be cost-model
    driven (``evict_policy="cost"``): the victim minimizes expected
    recall seconds priced by ``core.latency.kv_recall_costs``, not
    recency. ``quantize_page`` int8-compresses offloaded bytes
    (``offload_quant=True``), and idle **sessions park** to per-session
    disk files and restore byte-identically (``park_session`` /
    ``restore_session`` / ``sweep_parked``).

Device state lives in the engine-threaded cache pytree
(``{"pages", "block_table", "len"}``); this module's classes hold only
host bookkeeping plus the staging thread, and every device mutation
takes and returns the cache functionally.
"""
from __future__ import annotations

import dataclasses
import logging
import mmap
import os
import threading
import functools
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .iopolicy import BudgetExceeded, IOPolicy, StallTimeout, WorkerHealth
from .memory import TierManager
from .paramstore import _np_dtype
from .streaming import PrefetchEvent, PrefetchStats
from .telemetry import NULL_TRACER, clock

log = logging.getLogger(__name__)

Params = Dict[str, Any]

#: page id 0 is a write sink: freed slots keep decoding junk into it (the
#: batch is fixed-width, inactive rows still run), so it is never handed
#: out by the allocator and its content is never read unmasked.
SINK_PAGE = 0


class PoolExhausted(RuntimeError):
    """The block pool cannot satisfy an allocation (clear admit error)."""


def chain_key(prev: tuple, tokens: Sequence[int], count: int) -> tuple:
    """Content key of a prompt page given its predecessor's key.

    The key IS the (nested) token chain, not a digest — lookups compare
    the actual tokens, so a collision can never silently share another
    prompt's KV pages. ``count`` participates so a partial page
    (count < page_tokens) only matches a page with the identical token
    count — partial pages are shared only between byte-identical
    prompts. Start the chain with ``()``.
    """
    return (prev, count, tuple(int(t) for t in tokens))


# --------------------------------------------------------------------------- #
#  block pool (host-side allocator)
# --------------------------------------------------------------------------- #

class BlockPool:
    """Refcounted fixed-size page allocator with an LRU prefix cache.

    Page states:
      free     — on the free list, content meaningless;
      active   — refcount >= 1 (held by one or more slots);
      cached   — refcount 0 but still hash-addressable (prefix cache),
                 evicted LRU-first when the free list runs dry.

    ``release`` on a page that is not active raises — the double-free is
    a bug in the caller, not a condition to paper over.

    Eviction of cached (refcount-0) pages is pluggable:
    ``evict_policy="lru"`` keeps the original least-recently-used order;
    ``"cost"`` picks the victim minimizing *expected recall loss* —
    ``(1 + hit count) * recall_cost_fn(key)``, where ``recall_cost_fn``
    prices bringing the page back from wherever eviction would land it
    (``core.latency.kv_recall_costs`` terms) — so a hot page whose
    recall would come from disk outlives a cold page recallable from
    host, which plain LRU cannot express.

    Capacity stops being the pool's concern beyond its fixed page
    count: ``PagedKVCache`` leases the whole pool allocation from the
    shared :class:`~runtime.memory.TierManager` and derives ``n_pages``
    from the device budget, so this class never carries a standalone
    byte cap.
    """

    def __init__(self, n_pages: int, page_tokens: int, *,
                 evict_policy: str = "lru",
                 recall_cost_fn: Optional[Callable[[Any], float]] = None):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the write sink)")
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        if evict_policy not in ("lru", "cost"):
            raise ValueError(f"unknown evict_policy {evict_policy!r} "
                             f"(expected 'lru' or 'cost')")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.evict_policy = evict_policy
        self.recall_cost_fn = recall_cost_fn
        self._free: List[int] = list(range(n_pages - 1, SINK_PAGE, -1))
        self._ref: Dict[int, int] = {}
        self._hash_of: Dict[int, Any] = {}       # pid -> registered key
        self._pid_of: Dict[Any, int] = {}        # content key -> pid
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref 0
        self._freq: Dict[Any, int] = {}          # content key -> reuse hits
        self.alloc_count = 0
        self.evictions = 0

    # -- queries ----------------------------------------------------------- #

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def lookup(self, h) -> Optional[int]:
        """Device-resident page registered under content key ``h`` (or
        None). Keys are compared by value (the exact token chain), so a
        hit is always the right bytes. Hits feed the per-key reuse
        frequency the cost-model eviction weighs."""
        pid = self._pid_of.get(h)
        if pid is not None:
            self._freq[h] = self._freq.get(h, 0) + 1
        return pid

    def note_hit(self, h) -> None:
        """Record a reuse of key ``h`` served off-device (an offloaded
        copy) — same frequency signal as a resident ``lookup`` hit."""
        self._freq[h] = self._freq.get(h, 0) + 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return len(self._ref)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    def available(self) -> int:
        """Pages an alloc burst could obtain (free + evictable cached)."""
        return len(self._free) + len(self._cached)

    # -- lifecycle --------------------------------------------------------- #

    def alloc(self, *, evict_cb=None) -> int:
        """Take a page (refcount 1). Falls back to evicting the LRU cached
        page; ``evict_cb(pid, h)`` runs first so the owner can offload the
        content. Raises ``PoolExhausted`` when neither source has a page.
        """
        if self._free:
            pid = self._free.pop()
        elif self._cached:
            pid = self._pick_victim()
            del self._cached[pid]
            h = self._hash_of.pop(pid)
            del self._pid_of[h]
            self.evictions += 1
            if evict_cb is not None:
                evict_cb(pid, h)
        else:
            raise PoolExhausted(
                f"KV block pool exhausted: {self.n_pages - 1} pages, "
                f"{self.n_active} active, none cached/free")
        self._ref[pid] = 1
        self.alloc_count += 1
        return pid

    def _pick_victim(self) -> int:
        """Choose which cached page eviction reclaims.

        LRU: oldest entry. Cost: minimize expected recall loss,
        ``(1 + reuse hits) * modeled recall seconds`` — evicting the
        page we are least likely to miss, and cheapest to recall when
        we do. Falls back to LRU without a pricing function.
        """
        if self.evict_policy == "cost" and self.recall_cost_fn is not None:
            return min(
                self._cached,
                key=lambda p: (1 + self._freq.get(self._hash_of[p], 0))
                * self.recall_cost_fn(self._hash_of[p]))
        return next(iter(self._cached))                    # LRU

    def retain(self, pid: int) -> None:
        """Add a reference (prefix share / cached-page revival)."""
        if pid == SINK_PAGE:
            raise ValueError("cannot retain the sink page")
        if pid in self._cached:
            del self._cached[pid]
            self._ref[pid] = 1
        else:
            if pid not in self._ref:
                raise ValueError(f"retain of non-active page {pid}")
            self._ref[pid] += 1

    def release(self, pid: int) -> None:
        """Drop a reference; at zero the page goes to the prefix cache if
        hash-addressable, otherwise back to the free list."""
        n = self._ref.get(pid)
        if n is None:
            raise ValueError(f"double free of page {pid}")
        if n > 1:
            self._ref[pid] = n - 1
            return
        del self._ref[pid]
        if pid in self._hash_of:
            self._cached[pid] = None                       # MRU end
            self._cached.move_to_end(pid)
        else:
            self._free.append(pid)

    # -- hash addressing --------------------------------------------------- #

    def register(self, h, pid: int) -> None:
        """Make an active page addressable by content key ``h``."""
        if pid not in self._ref:
            raise ValueError(f"register of non-active page {pid}")
        old = self._pid_of.get(h)
        if old is not None and old != pid:
            # identical content already registered; keep the older page
            return
        self._pid_of[h] = pid
        self._hash_of[pid] = h

    def unregister(self, pid: int) -> None:
        """Forget a page's hash (it is about to be written in place)."""
        h = self._hash_of.pop(pid, None)
        if h is not None:
            self._pid_of.pop(h, None)

    # -- invariants (tests) ------------------------------------------------ #

    def check(self) -> None:
        free, active, cached = set(self._free), set(self._ref), \
            set(self._cached)
        assert SINK_PAGE not in free | active | cached
        assert not free & active and not free & cached \
            and not active & cached
        assert len(free) + len(active) + len(cached) == self.n_pages - 1
        assert all(n >= 1 for n in self._ref.values())
        assert cached <= set(self._hash_of)
        for h, pid in self._pid_of.items():
            assert self._hash_of.get(pid) == h


# --------------------------------------------------------------------------- #
#  int8 page quantization (quantize-on-write during offload)
# --------------------------------------------------------------------------- #

_SCALE_SUFFIX = "::scale"


def quantize_page(tree: Params) -> Params:
    """Symmetric per-vector int8 quantization of a page tree.

    Same scheme as the dense int8-KV path (``models.layers.quantize_kv``):
    each last-axis vector (one head's K or V for one token) gets an
    ``amax/127`` float32 scale stored under ``<leaf>::scale``. Halves the
    float32 page footprint (4B -> 1B + 4B/head_dim) on the host and disk
    tiers; lossy, so it is applied only to evicted prefix-cache pages —
    never to parked sessions, whose restore must be byte-identical.
    """
    out: Params = {}
    for name, a in tree.items():
        a = np.asarray(a)
        f = a.astype(np.float32)
        scale = np.max(np.abs(f), axis=-1, keepdims=True) / 127.0
        scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
        out[name] = np.clip(np.rint(f / scale), -127, 127).astype(np.int8)
        out[name + _SCALE_SUFFIX] = scale
    return out


def dequantize_page(tree: Params, dtype) -> Params:
    """Inverse of :func:`quantize_page` (cast back to the pool dtype)."""
    out: Params = {}
    for name, a in tree.items():
        if name.endswith(_SCALE_SUFFIX):
            continue
        scale = tree.get(name + _SCALE_SUFFIX)
        if scale is None:
            out[name] = np.asarray(a)
        else:
            out[name] = (np.asarray(a).astype(np.float32)
                         * scale).astype(dtype)
    return out


def is_quantized_page(tree: Params) -> bool:
    return any(k.endswith(_SCALE_SUFFIX) for k in tree)


# --------------------------------------------------------------------------- #
#  disk tier (per-session / per-page mmap page files)
# --------------------------------------------------------------------------- #

class PageFileStore:
    """Disk tier for KV pages: one flat binary file per key, read back
    through mmap views — ``ParamStore``'s layout at page granularity.

    Keys are arbitrary hashables (content chain keys for spilled
    prefix-cache pages, ``("sess", id, j)`` for a parked session's page
    files); the spec index lives in memory, so the store is scoped to
    one serving process like the pool it backs. Writes run under the
    shared :class:`IOPolicy` as op ``kv_d2disk`` and reads as
    ``kv_disk2h`` — both injectable by ``faults.FaultInjector`` and
    retried/deadlined exactly like layer reads. ``get`` copies out of
    the mapping, so restored bytes are private and byte-identical.
    """

    def __init__(self, directory: str, *,
                 policy: Optional[IOPolicy] = None, injector=None,
                 tracer=None):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.policy = policy or IOPolicy()
        self.injector = injector
        self.tracer = tracer or NULL_TRACER
        self.health = WorkerHealth(name="PageFileStore")
        # key -> (path, [(leaf name, shape, dtype name, offset, nbytes)])
        self._index: Dict[Any, Tuple[str, List[Tuple]]] = {}
        self._seq = 0
        self._lock = threading.Lock()
        self.written_bytes = 0
        self.read_bytes = 0
        self.events: List[PrefetchEvent] = []     # read (recall) timeline

    def holds(self, key) -> bool:
        with self._lock:
            return key in self._index

    def nbytes(self, key) -> int:
        with self._lock:
            ent = self._index.get(key)
            return sum(s[4] for s in ent[1]) if ent else 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._index)

    def put(self, key, tree: Params) -> int:
        """Persist a flat page tree under ``key``; returns bytes written.
        Atomic per key: the index only records a fully-written file, and
        a retried write starts the file over."""
        with self._lock:
            path = os.path.join(self.directory,
                                f"page_{self._seq:06d}.bin")
            self._seq += 1
        leaves = [(name, np.ascontiguousarray(tree[name]))
                  for name in sorted(tree)]
        specs: List[Tuple] = []
        offset = 0
        for name, arr in leaves:
            specs.append((name, arr.shape, arr.dtype.name
                          if arr.dtype.name != "void" else str(arr.dtype),
                          offset, arr.nbytes))
            offset += arr.nbytes

        def write() -> int:
            if self.injector is not None:
                self.injector.check("kv_d2disk", key=key)
            with open(path, "wb") as f:
                for _, arr in leaves:
                    f.write(arr.tobytes())
            return offset

        t0 = clock()
        total = self.policy.run("kv_d2disk", write, health=self.health)
        self.tracer.span_event(f"kv_d2disk[{key}]", t0, clock(), cat="kv",
                               track="kv-offloader", nbytes=total)
        with self._lock:
            self._index[key] = (path, specs)
            self.written_bytes += total
        return total

    def get(self, key) -> Params:
        """Read a page tree back (private copies, byte-identical)."""
        with self._lock:
            path, specs = self._index[key]

        def read() -> Params:
            if self.injector is not None:
                self.injector.check("kv_disk2h", key=key)
            with open(path, "rb") as f:
                mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
                try:
                    buf = np.frombuffer(mm, dtype=np.uint8)
                    out: Params = {}
                    for name, shape, dt, off, nb in specs:
                        out[name] = buf[off:off + nb] \
                            .view(_np_dtype(dt)).reshape(shape).copy()
                    return out
                finally:
                    del buf
                    mm.close()

        t0 = clock()
        out = self.policy.run("kv_disk2h", read, health=self.health)
        t1 = clock()
        total = sum(s[4] for s in specs)
        self.tracer.span_event(f"kv_disk2h[{key}]", t0, t1, cat="kv",
                               track="kv-offloader", nbytes=total)
        with self._lock:
            self.read_bytes += total
            self.events.append(PrefetchEvent(0, t0, t1, total))
        return out

    def drop(self, key) -> int:
        """Forget ``key`` and delete its file; returns bytes freed."""
        with self._lock:
            ent = self._index.pop(key, None)
        if ent is None:
            return 0
        path, specs = ent
        try:
            os.unlink(path)
        except OSError:       # pragma: no cover - already gone
            pass
        return sum(s[4] for s in specs)

    def close(self) -> None:
        with self._lock:
            entries = list(self._index.values())
            self._index.clear()
        for path, _ in entries:
            try:
                os.unlink(path)
            except OSError:   # pragma: no cover - already gone
                pass


# --------------------------------------------------------------------------- #
#  host offload (staged fetch, streaming.py's double-buffer pattern)
# --------------------------------------------------------------------------- #

class BlockOffloader:
    """Host-side store of evicted pages + async device staging.

    ``offload`` (eviction path) copies a page's per-layer bytes to host
    synchronously — it runs inside an allocation that needs the device
    page now. ``schedule`` queues the reverse H2D transfer on a worker
    thread; ``get`` blocks until the staged device tree is ready. Fetches
    are scheduled at admit time and collected after the admit's prefill
    compute, so the copy overlaps compute exactly like the layer
    prefetcher's window reads.

    Host copies lease from the shared memory budget's ``host`` tier
    (private/unbounded when no manager is passed — the seed behavior).
    A refused lease no longer grows past the budget: the offloader
    first **spills** its oldest host pages to the ``disk`` tier (a
    :class:`PageFileStore`, op ``kv_d2disk``) to make room, and only
    when there is no disk store — or it is full too — surfaces
    :class:`BudgetExceeded`, which the shared policy classifies
    transient (a finishing slot is usually about to release pages).
    ``quant=True`` int8-quantizes pages on write (``quantize_page``),
    halving host/disk bytes at the price of bounded dequantization
    drift on refetch.
    """

    def __init__(self, *, policy: Optional[IOPolicy] = None,
                 injector=None, tracer=None,
                 memory: Optional[TierManager] = None,
                 owner: str = "kv",
                 disk: Optional[PageFileStore] = None,
                 quant: bool = False, page_dtype=np.float32) -> None:
        self.policy = policy or IOPolicy()
        self.injector = injector          # faults.FaultInjector or None
        self.tracer = tracer or NULL_TRACER
        self.memory = memory if memory is not None \
            else TierManager(tracer=tracer, name="kv-offload-memory")
        self.owner = owner
        self.disk = disk
        self.quant = quant
        self.page_dtype = page_dtype
        self.health = WorkerHealth(name="BlockOffloader")
        self.stall_s = 0.0                # get() blocked on a staging fetch
        self._host: Dict[Any, Tuple[Params, int]] = {}  # key -> (tree, nb)
        self._disk_keys: Dict[Any, int] = {}            # spilled key -> nb
        self._staged: Dict[Any, Params] = {}            # key -> jnp tree
        self._queue: List[Any] = []
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._interrupted = False
        self._error: Optional[BaseException] = None
        self.events: List[PrefetchEvent] = []
        self.offloaded_bytes = 0
        self.fetched_bytes = 0
        self.spilled_pages = 0            # host pages demoted to disk
        self.fetched_disk_pages = 0       # recalls served from disk
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _h2d(self, tree: Params) -> Params:
        if self.injector is not None:
            self.injector.check("kv_h2d")
        if is_quantized_page(tree):       # dequantize-on-read (lossy tier)
            tree = dequantize_page(tree, self.page_dtype)
        return jax.tree.map(jnp.asarray, tree)            # H2D staging

    def _fetch_tree(self, h) -> Tuple[Optional[Params], str, int]:
        """Locate a page's bytes: host hit, or disk recall (kv_disk2h)
        staged through a transient host lease."""
        with self._cv:
            ent = self._host.get(h)
            if ent is not None:
                return ent[0], "host", ent[1]
            on_disk = h in self._disk_keys
        if on_disk:
            nbytes = self.disk.nbytes(h)
            # the staging lease must not deadlock against our own host
            # copies: when the host tier is full of offloaded pages,
            # spill the coldest to disk to make room; only wait on the
            # budget once there is nothing left of ours to demote
            acquired = False
            with self._cv:
                while not acquired:
                    acquired = self.memory.try_lease("host", nbytes,
                                                     self.owner)
                    if not acquired and not self._spill_one_locked():
                        break
            if not acquired:
                self.memory.lease("host", nbytes, self.owner, wait=True,
                                  timeout=self.policy.op_deadline_s,
                                  cancelled=lambda: self._stop)
            try:
                tree = self.disk.get(h)   # policy + injector inside
            except BaseException:
                self.memory.release("host", nbytes, self.owner)
                raise
            return tree, "disk", nbytes
        return None, "none", 0

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                h = self._queue.pop(0)
            try:
                tree, src, nbytes = self._fetch_tree(h)
                if tree is None:
                    continue
                t0 = clock()
                staged = self.policy.run("kv_h2d",
                                         lambda: self._h2d(tree),
                                         health=self.health)
                t1 = clock()
            except (KeyboardInterrupt, SystemExit):
                # control flow: unblock waiters, then die loudly
                with self._cv:
                    self._stop = True
                    self._interrupted = True
                    self._cv.notify_all()
                raise
            except BaseException as e:   # surface in get(), don't deadlock
                with self._cv:
                    self._error = e
                    self._cv.notify_all()
                return
            if src == "disk":
                # staged on device now: drop the transient host lease,
                # the disk copy and its disk-tier lease
                self.memory.release("host", nbytes, self.owner)
                with self._cv:
                    disk_nb = self._disk_keys.pop(h, 0)
                self.disk.drop(h)
                self.memory.release("disk", disk_nb, self.owner)
                self.fetched_disk_pages += 1
            self.tracer.span_event(f"kv_h2d[{h}]", t0, t1, cat="kv",
                                   track="kv-offloader", nbytes=nbytes)
            with self._cv:
                self._staged[h] = staged
                self.events.append(PrefetchEvent(0, t0, t1, nbytes))
                self.fetched_bytes += nbytes
                self._cv.notify_all()

    # -- eviction side ----------------------------------------------------- #

    def _spill_one_locked(self) -> bool:
        """Demote the oldest host page to the disk tier to make room.
        Returns False when there is nothing to spill or no disk store."""
        if self.disk is None or not self._host:
            return False
        key = next(iter(self._host))
        tree, nbytes = self._host[key]
        # claim disk capacity first (refusal -> BudgetExceeded before any
        # bytes move), then write; roll the move back if the write fails
        self.memory.move("host", "disk", nbytes, self.owner)
        try:
            self.disk.put(key, tree)      # op kv_d2disk under the policy
        except BaseException:
            self.memory.move("disk", "host", nbytes, self.owner)
            raise
        del self._host[key]
        self._disk_keys[key] = nbytes
        self.spilled_pages += 1
        return True

    def offload(self, h, tree: Params) -> None:
        if self.quant:                    # quantize-on-write: host/disk
            tree = quantize_page(tree)    # hold the int8 + scale bytes
        nbytes = sum(np.asarray(a).nbytes
                     for a in jax.tree.leaves(tree))

        def put():
            if self.injector is not None:
                self.injector.check("kv_d2h")
            # enforce the host budget: spill cold pages to disk until the
            # lease fits; a refusal with no disk room left surfaces as
            # BudgetExceeded (transient under the policy — a finishing
            # slot may free host bytes before the retries exhaust)
            with self._cv:
                while not self.memory.try_lease("host", nbytes,
                                                self.owner):
                    if not self._spill_one_locked():
                        st = self.memory.stats()["host"]
                        raise BudgetExceeded(
                            f"KV offload of {nbytes} B refused: host "
                            f"tier {st.used}/{st.capacity} B used and "
                            f"no disk tier to spill to",
                            tier="host", requested=nbytes, used=st.used,
                            capacity=st.capacity or 0)
            return nbytes

        # the D2H copy happened in the eviction callback; this commits the
        # host store (and is where an injected kv_d2h fault surfaces) —
        # transient faults retry under the shared policy
        t0 = clock()
        self.policy.run("kv_d2h", put, health=self.health)
        self.tracer.span_event(f"kv_d2h[{h}]", t0, clock(), cat="kv",
                               track="kv-offloader", nbytes=nbytes)
        with self._cv:
            self._host[h] = (tree, nbytes)
            self.offloaded_bytes += nbytes

    def holds(self, h) -> bool:
        with self._cv:
            return h in self._host or h in self._disk_keys

    # -- fetch side -------------------------------------------------------- #

    def schedule(self, h: int) -> None:
        with self._cv:
            if h in self._staged or h in self._queue:
                return
            self._queue.append(h)
            self._cv.notify_all()

    def get(self, h: int, *, timeout: Optional[float] = None) -> Params:
        if timeout is None:
            timeout = self.policy.get_timeout_s
        t_enter = clock()
        deadline = t_enter + timeout
        with self.tracer.phase("h2d", cat="kv", track="decode",
                               min_dur=2e-4, label=f"kv_wait[{h}]"):
            with self._cv:
                while h not in self._staged:
                    if self._error is not None:
                        raise RuntimeError(
                            f"offload fetch of page hash {h} failed "
                            f"({self.health.report()})") from self._error
                    if self._stop:
                        raise RuntimeError(
                            "offloader stopped" + (
                                " (worker interrupted)"
                                if self._interrupted else ""))
                    remaining = deadline - clock()
                    if remaining <= 0:
                        self.health.stalled = True
                        raise StallTimeout(
                            f"offloaded page not staged within "
                            f"{timeout:.1f}s "
                            f"({self.health.report()})", op="kv_h2d")
                    self._cv.wait(min(remaining, 0.25))
                staged = self._staged.pop(h)
                ent = self._host.pop(h, None)   # back on device
                if ent is not None:             # host copy done: unlease
                    self.memory.release("host", ent[1], self.owner)
                self.stall_s += clock() - t_enter
                return staged

    def stats(self) -> PrefetchStats:
        """Uniform ``PrefetchStats`` view — the same surface the layer
        and ring-bank prefetchers expose, so stall/retry counters from
        all three staging paths read identically in reports."""
        with self._cv:
            events = list(self.events)
            fetched = self.fetched_bytes
        return PrefetchStats(
            events=events, peak_resident_bytes=0,
            total_bytes_read=fetched, stall_s=self.stall_s,
            layers_served=len(events), releases=0,
            retries=self.health.retries,
            budget_refusals=sum(s.refusals
                                for s in self.memory.stats().values()))

    def close(self, timeout: float = 5.0) -> bool:
        """Stop the worker (idempotent); True once it has joined, False
        with a logged stall report if it is stuck. Host copies hand
        their leases back so a shared budget balances after shutdown."""
        with self._cv:
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            self.health.stalled = True
            log.error("BlockOffloader.close: worker failed to join "
                      "within %.1fs — %s", timeout, self.health.report())
            return False
        with self._cv:
            for h in list(self._host):
                _, nbytes = self._host.pop(h)
                self.memory.release("host", nbytes, self.owner)
            for h in list(self._disk_keys):
                self.memory.release("disk", self._disk_keys.pop(h),
                                    self.owner)
        self.health.closed = True
        return True


# --------------------------------------------------------------------------- #
#  paged cache manager
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class KVStats:
    """Allocator + traffic view of a paged-cache run (benchmarks/gates)."""

    n_pages: int
    page_tokens: int
    page_bytes: int                   # one page across all layers/leaves
    active_pages_highwater: int       # max simultaneously-referenced pages
    active_tokens_highwater: int      # max live tokens across slots
    prefix_hits: int                  # pages obtained by hash match
    cow_copies: int
    evictions: int
    offloaded_bytes: int
    fetched_bytes: int
    fetch_events: List[PrefetchEvent]
    fetch_stall_s: float = 0.0        # admits blocked on a staging fetch
    fetch_retries: int = 0            # transient I/O retries (IOPolicy)
    disk_bytes_written: int = 0       # kv_d2disk traffic (spills + parks)
    disk_bytes_read: int = 0          # kv_disk2h traffic (recalls)
    spilled_pages: int = 0            # host pages demoted to disk
    fetched_disk_pages: int = 0       # prefix recalls served from disk
    parked_sessions: int = 0          # lifetime park count
    restored_sessions: int = 0        # lifetime restore count
    budget_refusals: int = 0          # tier leases the budget refused

    @property
    def highwater_bytes(self) -> int:
        return self.active_pages_highwater * self.page_bytes

    def dense_bytes(self, batch: int, max_len: int) -> int:
        """What the dense (L, B, max_len, ...) preallocation would hold."""
        per_tok = self.page_bytes / max(self.page_tokens, 1)
        return int(batch * max_len * per_tok)


def paged_cache_spec(cfg) -> Dict[str, Tuple[int, ...]]:
    """Per-leaf trailing shapes of one cache line (one token, one layer)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged KV cache unsupported for family {cfg.family} "
            "(recurrent state has no per-token pages)")
    if cfg.mla:
        if cfg.kv_dtype == "int8":
            raise NotImplementedError(
                "paged MLA latent storage does not support int8 "
                "quantization (the latent is already compressed)")
        return {"latent": (cfg.kv_lora_rank + cfg.qk_rope_dim,)}
    hk, hd = max(cfg.kv_heads, 1), cfg.head_dim
    if cfg.kv_dtype == "int8":
        # int8 K/V plus per-(position, kv-head) scales
        # (``layers.quantize_kv`` convention) — the paged flash kernels
        # read the quantized leaves directly, dequant fused.
        return {"k": (hk, hd), "v": (hk, hd),
                "k_scale": (hk,), "v_scale": (hk,)}
    return {"k": (hk, hd), "v": (hk, hd)}


def paged_leaf_dtype(name: str, cfg, pool_dtype):
    """Storage dtype of a paged-cache leaf: int8 for quantized K/V,
    the pool dtype for everything else (scales included)."""
    if cfg.kv_dtype == "int8" and name in ("k", "v"):
        return jnp.int8
    return pool_dtype


@dataclasses.dataclass
class ParkedSession:
    """A session's KV lifted off the device tier between requests.

    ``tier == "host"``: ``pages`` holds the np page trees. ``tier ==
    "disk"``: pages live in per-session :class:`PageFileStore` files
    (keys ``("sess", session, j)``) and ``pages`` is None. ``meta`` is
    an opaque engine blob (resume token) returned verbatim on restore —
    the cache parks bytes, not scheduling state.
    """

    session: str
    length: int
    n_pages: int
    nbytes: int
    tier: str
    pages: Optional[List[Params]]
    meta: dict
    parked_t: float


class PagedKVCache:
    """Owner of the block pool + per-slot page lists for a serving batch.

    The device arrays live in the cache pytree this class *builds* but
    does not hold: every mutating method threads the cache through
    functionally, so the engine's usual ``cache = f(cache, ...)`` flow is
    preserved and jit boundaries see plain arrays.

    cache = {
      "pages":       {leaf: (L, P, page_tokens, ...)},
      "block_table": (B, max_pages_per_slot) int32,
      "len":         (B,) int32,
    }

    Tiered-memory integration (``memory``): the whole pool allocation
    leases from the shared ``device`` tier at construction (``n_pages``
    may be omitted and is then derived from the device budget), the
    offloader's host copies lease from ``host``, and the disk tier
    (``disk_dir``) holds spilled prefix pages plus **parked sessions**:
    ``park_session`` lifts an idle slot's pages off the device
    (host first, demoted to per-session page files by ``sweep_parked``
    after ``park_idle_s`` seconds), and ``restore_session`` brings them
    back byte-identically on the session's next request.
    """

    def __init__(self, cfg, *, batch: int, ctx: int,
                 n_pages: Optional[int] = None,
                 page_tokens: int = 16, dtype=jnp.float32,
                 offload: bool = True,
                 io_policy: Optional[IOPolicy] = None, injector=None,
                 tracer=None, memory: Optional[TierManager] = None,
                 evict_policy: str = "lru", offload_quant: bool = False,
                 disk_dir: Optional[str] = None,
                 park_idle_s: Optional[float] = None,
                 recall_costs=None):
        self.cfg = cfg
        self.B = batch
        self.page_tokens = page_tokens
        self.max_pages = -(-ctx // page_tokens)
        self.ctx = self.max_pages * page_tokens
        self._spec = paged_cache_spec(cfg)
        self.dtype = dtype
        self.memory = memory if memory is not None \
            else TierManager(tracer=tracer, name="kv-memory")
        if n_pages is None:
            avail = self.memory.available("device")
            if avail is None:
                raise ValueError(
                    "n_pages omitted: pass a memory manager with a "
                    "device budget to derive the pool size from it")
            n_pages = max(int(avail // max(self.page_bytes, 1)), 2)
        if recall_costs is None:
            from ..core.latency import kv_recall_costs
            recall_costs = kv_recall_costs(self.page_bytes)
        self.recall_costs = recall_costs
        self.pool = BlockPool(
            n_pages, page_tokens, evict_policy=evict_policy,
            recall_cost_fn=self._recall_cost
            if evict_policy == "cost" else None)
        # the pool array is one fixed device allocation — lease it whole
        # (construction fails loudly if the budget cannot hold it)
        self._pool_lease = n_pages * self.page_bytes
        self.memory.lease("device", self._pool_lease, "kv")
        self.disk = PageFileStore(disk_dir, policy=io_policy,
                                  injector=injector, tracer=tracer) \
            if disk_dir else None
        np_dtype = np.dtype(jnp.zeros((), dtype).dtype)
        self.offloader = BlockOffloader(policy=io_policy,
                                        injector=injector,
                                        tracer=tracer,
                                        memory=self.memory,
                                        disk=self.disk,
                                        quant=offload_quant,
                                        page_dtype=np_dtype) \
            if offload else None
        self.park_idle_s = park_idle_s
        self._parked: Dict[str, ParkedSession] = {}
        self.parked_count = 0
        self.restored_count = 0
        # host mirrors
        self._slot_pages: List[List[int]] = [[] for _ in range(batch)]
        self._len = [0] * batch
        #: worst-case page budget reserved per live slot (admission
        #: control): with sum(reserved) <= usable pages, per-step growth
        #: and CoW can always be satisfied from free + evictable pages,
        #: so decode never dies mid-step — exhaustion is an admit-time
        #: signal the engine can defer on.
        self._reserved = [0] * batch
        self._usable = n_pages - 1
        self._dirty = set(range(batch))          # table rows to (re)write
        #: slot -> [(page kind, content key)] for the admit in flight
        #: between plan_admit and install ("shared"|"fetched"|"fresh")
        self._admit_meta: Dict[int, List[Tuple[str, Any]]] = {}
        #: slots mid chunked admission: their device table row stays all
        #: sink (decode steps interleaved between chunks must not write
        #: into the half-filled real pages); chunk steps address the
        #: pages through a private ``chunk_table`` row instead
        self._chunking: set = set()
        # stats
        self._active_pages_hw = 0
        self._active_tokens_hw = 0
        self.prefix_hits = 0
        self.cow_copies = 0

    # -- construction ------------------------------------------------------ #

    def init_cache(self) -> Dict[str, Any]:
        L = self.cfg.n_layers
        P, bs = self.pool.n_pages, self.page_tokens
        pages = {name: jnp.zeros((L, P, bs) + trail,
                                 paged_leaf_dtype(name, self.cfg,
                                                  self.dtype))
                 for name, trail in self._spec.items()}
        return {"pages": pages,
                "block_table": jnp.zeros((self.B, self.max_pages),
                                         jnp.int32),
                "len": jnp.zeros((self.B,), jnp.int32)}

    @property
    def page_bytes(self) -> int:
        L, bs = self.cfg.n_layers, self.page_tokens
        return sum(
            L * bs * int(np.prod(trail, dtype=np.int64))
            * jnp.zeros((), paged_leaf_dtype(name, self.cfg, self.dtype)
                        ).dtype.itemsize
            for name, trail in self._spec.items())

    # -- stats ------------------------------------------------------------- #

    def _note_highwater(self) -> None:
        self._active_pages_hw = max(self._active_pages_hw,
                                    self.pool.n_active)
        self._active_tokens_hw = max(self._active_tokens_hw,
                                     sum(self._len))

    def stats(self) -> KVStats:
        off = self.offloader
        return KVStats(
            n_pages=self.pool.n_pages, page_tokens=self.page_tokens,
            page_bytes=self.page_bytes,
            active_pages_highwater=self._active_pages_hw,
            active_tokens_highwater=self._active_tokens_hw,
            prefix_hits=self.prefix_hits, cow_copies=self.cow_copies,
            evictions=self.pool.evictions,
            offloaded_bytes=off.offloaded_bytes if off else 0,
            fetched_bytes=off.fetched_bytes if off else 0,
            fetch_events=list(off.events) if off else [],
            fetch_stall_s=off.stall_s if off else 0.0,
            fetch_retries=off.health.retries if off else 0,
            disk_bytes_written=self.disk.written_bytes if self.disk else 0,
            disk_bytes_read=self.disk.read_bytes if self.disk else 0,
            spilled_pages=off.spilled_pages if off else 0,
            fetched_disk_pages=off.fetched_disk_pages if off else 0,
            parked_sessions=self.parked_count,
            restored_sessions=self.restored_count,
            budget_refusals=sum(
                s.refusals for s in self.memory.stats().values()))

    # -- cost-model eviction pricing --------------------------------------- #

    def _recall_cost(self, h) -> float:
        """Modeled seconds to recall page ``h`` if evicted now — the
        ``core.latency.kv_recall_costs`` term for the tier eviction
        would land it in (host normally; disk when it already lives
        there or the host tier has no room left)."""
        if self.offloader is None:
            return self.recall_costs.disk_s      # content would be lost
        if self.disk is not None:
            if self.disk.holds(h):
                return self.recall_costs.disk_s
            avail = self.memory.available("host")
            if avail is not None and avail < self.page_bytes:
                return self.recall_costs.disk_s  # eviction would spill
        return self.recall_costs.host_s

    # -- page content ops (functional on the cache) ------------------------ #

    def _evict_cb(self, cache):
        """Eviction hook: offload the page's bytes to host before reuse."""
        if self.offloader is None:
            return None

        def cb(pid, h):
            tree = {name: np.asarray(arr[:, pid])
                    for name, arr in cache["pages"].items()}
            self.offloader.offload(h, tree)
        return cb

    def _copy_page(self, cache, src: int, dst: int):
        pages = {name: arr.at[:, dst].set(arr[:, src])
                 for name, arr in cache["pages"].items()}
        return {**cache, "pages": pages}

    def _scatter_pages(self, cache, pids: List[int],
                       trees: List[Params]):
        """Write page contents (``trees[i]``: {leaf: (L, bs, ...)}) into
        pool positions ``pids`` — ONE batched update per leaf, so an
        n-page admit costs one pool-array copy instead of n."""
        if not pids:
            return cache
        idx = jnp.asarray(pids, jnp.int32)
        pages = dict(cache["pages"])
        for name in pages:
            stacked = jnp.stack([jnp.asarray(t[name]) for t in trees],
                                axis=1)
            pages[name] = pages[name].at[:, idx].set(
                stacked.astype(pages[name].dtype))
        return {**cache, "pages": pages}

    def _sync_tables(self, cache):
        """Write dirty slots' page lists (and lengths) into the device
        cache. Runs before the decode writes of a step, when the host
        mirror and the device counter agree for every live slot."""
        if not self._dirty:
            return cache
        table = np.asarray(cache["block_table"]).copy()
        lens = np.asarray(cache["len"]).copy()
        for slot in self._dirty:
            row = np.full((self.max_pages,), SINK_PAGE, np.int32)
            if slot not in self._chunking:       # mid-chunk: stay masked
                pids = self._slot_pages[slot][:self.max_pages]
                row[:len(pids)] = pids
            table[slot] = row
            lens[slot] = 0 if slot in self._chunking else self._len[slot]
        self._dirty.clear()
        return {**cache, "block_table": jnp.asarray(table),
                "len": jnp.asarray(lens)}

    # -- admit ------------------------------------------------------------- #

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """Could this request be admitted into an *empty* pool?

        False means deferral is pointless — no amount of completed slots
        frees enough pages — so the engine sheds the request immediately
        with a clear "pool too small" error instead of starving it.
        """
        total = prompt_len + max_new
        if total > self.ctx:
            return False
        worst = -(-total // self.page_tokens) + 1
        return worst <= self._usable

    def plan_admit(self, cache, slot: int, prompt: Sequence[int],
                   max_new: int, *, register: bool = True
                   ) -> Dict[str, int]:
        """Reserve pages for a prompt: prefix-share where hashes match,
        schedule background fetches for offloaded matches, allocate the
        rest (the alloc-on-demand half of the admit contract — the only
        rejections are a request too long for the slot table and pool
        exhaustion, both with clear errors).

        Runs *before* the prefill compute so offload fetches overlap it;
        ``install`` collects them afterwards. ``cache`` is read-only here
        (eviction offload copies page bytes device->host).

        ``register=False`` defers hash registration of fresh pages to
        ``finish_chunked_admit``: chunked admission fills them over many
        interleaved steps, and a concurrent admit must not prefix-share
        a page whose bytes are not all there yet. (The dense path fills
        pages in one ``install`` with no interleaving, so it registers
        eagerly and keeps the plan-to-install overlap.)
        """
        bs = self.page_tokens
        S, total = len(prompt), len(prompt) + max_new
        if total > self.ctx:
            raise ValueError(
                f"request needs {total} positions (prompt {S} + max_new "
                f"{max_new}) but the paged slot addresses only "
                f"{self.ctx} ({self.max_pages} pages x {bs} tokens)")
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        # worst-case lifetime pages: every position paged, +1 for the
        # copy-on-write clone of a shared divergence page
        worst = -(-total // bs) + 1
        committed = sum(self._reserved) + worst
        if committed > self._usable:
            raise PoolExhausted(
                f"KV block pool exhausted: admitting would oversubscribe "
                f"{committed}/{self._usable} pages "
                f"({sum(1 for r in self._reserved if r)} slots live)")
        n_blocks = -(-S // bs)
        pids: List[int] = []
        meta: List[Tuple[str, Any]] = []
        h: tuple = ()
        try:
            for j in range(n_blocks):
                toks = prompt[j * bs:(j + 1) * bs]
                h = chain_key(h, toks, len(toks))
                pid = self.pool.lookup(h)
                if pid is not None:                      # resident hit
                    self.pool.retain(pid)
                    kind = "shared"
                elif self.offloader is not None and self.offloader.holds(h):
                    pid = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    self.offloader.schedule(h)
                    self.pool.register(h, pid)
                    self.pool.note_hit(h)    # off-device reuse: same
                    kind = "fetched"         # frequency signal as lookup
                else:
                    pid = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    if register:
                        self.pool.register(h, pid)
                    kind = "fresh"
                pids.append(pid)
                meta.append((kind, h))
        except PoolExhausted:
            # roll the reservation back whole: pages registered for this
            # admit were never filled, so they must not survive into the
            # prefix cache
            for pid, (kind, _) in zip(pids, meta):
                if kind != "shared":
                    self.pool.unregister(pid)
                self.pool.release(pid)
            raise
        self.prefix_hits += sum(1 for k, _ in meta if k != "fresh")
        self._slot_pages[slot] = pids
        self._admit_meta[slot] = meta
        self._reserved[slot] = worst
        self._dirty.add(slot)
        return {k: sum(1 for kk, _ in meta if kk == k)
                for k in ("shared", "fetched", "fresh")}

    def abort_admit(self, slot: int) -> None:
        """Undo a ``plan_admit`` whose prefill failed: return the slot's
        pages (un-registering never-filled ones so they cannot enter the
        prefix cache) and drop its reservation. The engine calls this on
        any error between plan and install."""
        meta = self._admit_meta.pop(slot, None)
        if meta is None:
            return
        for pid, (kind, _) in zip(self._slot_pages[slot], meta):
            if kind != "shared":
                self.pool.unregister(pid)
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._reserved[slot] = 0
        self._len[slot] = 0
        self._chunking.discard(slot)
        self._dirty.add(slot)

    def install(self, cache, slot: int, slot_layers: Params,
                length: int) -> Dict[str, Any]:
        """Scatter a freshly-prefilled sequence's KV into its pages.

        ``slot_layers``: the per-layer cache of a single-sequence prefill
        (leaves ``(L, 1, S_cap, ...)``). Pages obtained by prefix share
        are skipped — their bytes are already correct and rewriting them
        would defeat the point; offloaded matches are collected from the
        staging thread here, after the prefill compute they overlapped.
        """
        bs = self.page_tokens
        meta = self._admit_meta.pop(slot)
        pids_w: List[int] = []
        trees: List[Params] = []
        for j, (pid, (kind, h)) in enumerate(
                zip(self._slot_pages[slot], meta)):
            if kind == "shared":
                continue
            if kind == "fetched":
                pids_w.append(pid)
                trees.append(self.offloader.get(h))
                continue
            lo = j * bs
            blk = {}
            for name, arr in slot_layers.items():
                # slice/pad on device: no host round-trip of prompt KV
                piece = jnp.asarray(arr)[:, 0, lo:lo + bs]
                if piece.shape[1] < bs:                   # partial page
                    pad = [(0, 0)] * piece.ndim
                    pad[1] = (0, bs - piece.shape[1])
                    piece = jnp.pad(piece, pad)
                blk[name] = piece
            pids_w.append(pid)
            trees.append(blk)
        cache = self._scatter_pages(cache, pids_w, trees)
        self._len[slot] = length
        self._dirty.add(slot)
        cache = self._sync_tables(cache)
        self._note_highwater()
        return cache

    # -- chunked admission (prompt KV computed straight into pages) --------- #

    def begin_chunked_admit(self, cache, slot: int, prompt_len: int
                            ) -> Tuple[Dict[str, Any], int]:
        """Prepare a planned admit (``plan_admit(register=False)``) for
        chunk-direct writes: collect offloaded prefix matches into their
        device pages now (chunk attention reads them, so the fetch can
        no longer overlap the whole prefill), compute how many leading
        prompt tokens are already materialized (shared + fetched prefix
        — chunk compute starts after them), and mask the slot's device
        table row (all sink, len 0) so decode steps interleaved between
        chunks cannot write into the half-filled pages. Chunk steps
        address the pages through ``chunk_table`` instead.

        Returns ``(cache, skip_tokens)``.
        """
        meta = self._admit_meta[slot]
        pids = self._slot_pages[slot]
        pids_w: List[int] = []
        trees: List[Params] = []
        for pid, (kind, h) in zip(pids, meta):
            if kind == "fetched":
                pids_w.append(pid)
                trees.append(self.offloader.get(h))
        cache = self._scatter_pages(cache, pids_w, trees)
        skip = 0
        for kind, _ in meta:
            if kind == "fresh":
                break
            skip += 1
        skip_tokens = prompt_len if skip >= len(meta) \
            else skip * self.page_tokens
        self._chunking.add(slot)
        self._dirty.add(slot)
        cache = self._sync_tables(cache)
        return cache, skip_tokens

    def chunk_table(self, slot: int) -> np.ndarray:
        """(1, max_pages) int32 block-table row for chunk steps of a
        mid-admission slot (its row in the shared device table is masked
        until ``finish_chunked_admit``)."""
        row = np.full((1, self.max_pages), SINK_PAGE, np.int32)
        pids = self._slot_pages[slot][:self.max_pages]
        row[0, :len(pids)] = pids
        return row

    def finish_chunked_admit(self, cache, slot: int, length: int
                             ) -> Dict[str, Any]:
        """Complete a chunked admit: the prompt's KV is fully in pages,
        so register the fresh pages' content keys (future admits may now
        prefix-share them) and unmask the slot's table row."""
        meta = self._admit_meta.pop(slot)
        for pid, (kind, h) in zip(self._slot_pages[slot], meta):
            if kind == "fresh":
                self.pool.register(h, pid)
        self._chunking.discard(slot)
        self._len[slot] = length
        self._dirty.add(slot)
        cache = self._sync_tables(cache)
        self._note_highwater()
        return cache

    # -- per-step maintenance ---------------------------------------------- #

    def begin_step(self, cache, active: Sequence[int], n_tokens: int
                   ) -> Dict[str, Any]:
        """Make the next ``n_tokens`` positions of every active slot
        writable: grow page lists across boundaries, copy-on-write shared
        pages in the write range, unregister hashes of private pages
        about to be written, and flush table/len cleanup of freed slots.
        """
        bs = self.page_tokens
        for slot in active:
            ln = self._len[slot]
            need = -(-(ln + n_tokens) // bs)
            if need > self.max_pages:
                raise PoolExhausted(
                    f"slot {slot} needs {need} pages "
                    f"(len {ln} + {n_tokens}) > table width "
                    f"{self.max_pages}")
            pids = self._slot_pages[slot]
            while len(pids) < need:
                pids.append(self.pool.alloc(
                    evict_cb=self._evict_cb(cache)))
                self._dirty.add(slot)
            first_blk = ln // bs
            last_blk = (ln + n_tokens - 1) // bs
            for j in range(first_blk, last_blk + 1):
                pid = pids[j]
                if self.pool.refcount(pid) > 1:           # divergence: CoW
                    new = self.pool.alloc(evict_cb=self._evict_cb(cache))
                    cache = self._copy_page(cache, pid, new)
                    self.pool.release(pid)
                    pids[j] = new
                    self.cow_copies += 1
                    self._dirty.add(slot)
                else:
                    self.pool.unregister(pid)     # content will change
        cache = self._sync_tables(cache)
        self._note_highwater()
        return cache

    def advance(self, slot: int, n: int = 1) -> None:
        """Commit ``n`` generated tokens (vanilla decode bookkeeping)."""
        self._len[slot] += n

    def length(self, slot: int) -> int:
        """Host-mirrored valid length of ``slot`` (== device ``len`` at
        step boundaries)."""
        return self._len[slot]

    def trim_to(self, slot: int, new_len: int) -> None:
        """Speculative rollback: keep pages covering ``new_len`` tokens,
        free the rest (rejected drafts past the accepted length)."""
        bs = self.page_tokens
        keep = -(-new_len // bs) if new_len > 0 else 0
        pids = self._slot_pages[slot]
        for pid in pids[keep:]:
            self.pool.release(pid)
        if len(pids) > keep:
            del pids[keep:]
            self._dirty.add(slot)
        self._len[slot] = new_len

    def release_slot(self, slot: int) -> None:
        """Finished sequence: drop its references. Hashed prompt pages
        fall into the LRU prefix cache for future admits; private pages
        return to the free list. Table/len cleanup is applied lazily at
        the next ``begin_step`` (stale rows only ever feed the masked
        region until then)."""
        for pid in self._slot_pages[slot]:
            self.pool.release(pid)
        self._slot_pages[slot] = []
        self._len[slot] = 0
        self._reserved[slot] = 0
        self._dirty.add(slot)

    # -- session parking (disk-tier resumable sessions) --------------------- #

    @property
    def parking(self) -> bool:
        """Whether session parking is configured (``park_idle_s``)."""
        return self.park_idle_s is not None

    def is_parked(self, session: str) -> bool:
        return session in self._parked

    def _session_key(self, session: str, j: int) -> tuple:
        return ("sess", session, j)

    def _write_session_files(self, session: str,
                             trees: List[Params]) -> None:
        for j, tree in enumerate(trees):
            self.disk.put(self._session_key(session, j), tree)

    def _drop_session_files(self, session: str, n: int) -> None:
        for j in range(n):
            self.disk.drop(self._session_key(session, j))

    def park_session(self, cache, slot: int, session: str,
                     meta: dict) -> None:
        """Lift ``slot``'s pages off the device tier under ``session``.

        Copies every page's bytes to leased host buffers (or straight
        to per-session disk files when the host tier refuses) and frees
        the device pages — the slot is immediately reusable. ``meta``
        (the engine's resume token) rides along and comes back verbatim
        from :meth:`restore_session`. Parking is always lossless:
        quantize-on-write applies only to the offloader's prefix tier,
        never here, so the restored token stream is byte-identical.
        Raises :class:`BudgetExceeded` when neither host nor disk can
        hold the session (the caller drops it instead of overshooting).
        """
        if session in self._parked:      # stale park: a newer request
            self._drop_parked(session)   # supersedes the old KV
        pids = self._slot_pages[slot]
        trees = [{name: np.asarray(arr[:, pid])
                  for name, arr in cache["pages"].items()}
                 for pid in pids]
        nbytes = sum(a.nbytes for t in trees for a in t.values())
        if self.memory.try_lease("host", nbytes, "kv"):
            tier = "host"
        else:
            if self.disk is None:
                st = self.memory.stats()["host"]
                raise BudgetExceeded(
                    f"cannot park session {session!r}: host tier "
                    f"{st.used}/{st.capacity} B used and no disk tier",
                    tier="host", requested=nbytes, used=st.used,
                    capacity=st.capacity or 0)
            self.memory.lease("disk", nbytes, "kv")   # BudgetExceeded ok
            try:
                self._write_session_files(session, trees)
            except BaseException:
                self.memory.release("disk", nbytes, "kv")
                raise
            tier = "disk"
        self._parked[session] = ParkedSession(
            session=session, length=self._len[slot], n_pages=len(pids),
            nbytes=nbytes, tier=tier,
            pages=trees if tier == "host" else None, meta=dict(meta),
            parked_t=clock())
        self.parked_count += 1
        self.release_slot(slot)    # device pages free; prompt pages may
        self._note_highwater()     # still serve the prefix cache

    def sweep_parked(self) -> int:
        """Demote host-parked sessions idle for ``park_idle_s`` seconds
        to per-session disk page files; returns sessions demoted. A full
        disk tier leaves a session on host (retried next sweep)."""
        if not self.parking or self.disk is None:
            return 0
        now = clock()
        n = 0
        for ps in self._parked.values():
            if ps.tier != "host" or now - ps.parked_t < self.park_idle_s:
                continue
            try:
                self.memory.move("host", "disk", ps.nbytes, "kv")
            except BudgetExceeded:
                continue                 # disk full: stay on host
            try:
                self._write_session_files(ps.session, ps.pages)
            except BaseException:
                self.memory.move("disk", "host", ps.nbytes, "kv")
                raise
            ps.tier = "disk"
            ps.pages = None
            n += 1
        return n

    def restore_session(self, cache, slot: int, session: str, *,
                        max_new: int):
        """Bring a parked session's pages back onto the device into
        ``slot``; returns ``(cache, meta, length)`` with ``meta`` the
        blob ``park_session`` recorded. Restored bytes are identical to
        the parked bytes (host copies or disk page files — both
        lossless), so decode continues exactly where it left off.
        Raises ``PoolExhausted`` (the session stays parked) when the
        pool cannot hold it right now — the engine's deferral path.
        """
        ps = self._parked[session]
        bs = self.page_tokens
        total = ps.length + max_new
        if total > self.ctx:
            raise ValueError(
                f"session {session!r} needs {total} positions "
                f"(parked len {ps.length} + max_new {max_new}) but the "
                f"paged slot addresses only {self.ctx}")
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        worst = -(-total // bs) + 1
        committed = sum(self._reserved) + worst
        if committed > self._usable:
            raise PoolExhausted(
                f"KV block pool exhausted: restoring session "
                f"{session!r} would oversubscribe "
                f"{committed}/{self._usable} pages")
        pids: List[int] = []
        try:
            for _ in range(ps.n_pages):
                pids.append(self.pool.alloc(
                    evict_cb=self._evict_cb(cache)))
        except PoolExhausted:
            for pid in pids:
                self.pool.release(pid)
            raise                        # still parked; admit defers
        if ps.tier == "host":
            trees = ps.pages
        else:
            trees = [self.disk.get(self._session_key(session, j))
                     for j in range(ps.n_pages)]   # op kv_disk2h
        cache = self._scatter_pages(cache, pids, trees)
        self._slot_pages[slot] = pids
        self._len[slot] = ps.length
        self._reserved[slot] = worst
        self._dirty.add(slot)
        cache = self._sync_tables(cache)
        del self._parked[session]
        self.memory.release(ps.tier, ps.nbytes, "kv")
        if ps.tier == "disk":
            self._drop_session_files(session, ps.n_pages)
        self.restored_count += 1
        self._note_highwater()
        return cache, ps.meta, ps.length

    def _drop_parked(self, session: str) -> None:
        ps = self._parked.pop(session, None)
        if ps is None:
            return
        self.memory.release(ps.tier, ps.nbytes, "kv")
        if ps.tier == "disk":
            self._drop_session_files(session, ps.n_pages)

    def close(self) -> None:
        for session in list(self._parked):
            self._drop_parked(session)
        if self.offloader is not None:
            self.offloader.close()
        if self.disk is not None:
            self.disk.close()
        if self._pool_lease:           # idempotent: lease returns once
            self.memory.release("device", self._pool_lease, "kv")
            self._pool_lease = 0


# --------------------------------------------------------------------------- #
#  continuous-batching integration
# --------------------------------------------------------------------------- #

# module-level jits so the compile cache is shared across engine builds
# (benchmarks tear engines down between scenarios; warmup must survive).
# ``cfg`` is a frozen dataclass -> hashable static; ``write`` selects the
# prefix-hit replay variant that must not touch pages.
@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_paged_jit(params, cfg, cache, tokens):
    from ..models import model as M

    return M.decode_step_paged(params, cfg, cache, tokens)


@functools.partial(jax.jit, static_argnames=("cfg", "write"))
def _prefill_chunk_jit(params, cfg, view, tokens, write):
    from ..models import model as M

    return M.prefill_chunk_paged(params, cfg, view, tokens, write=write)


def make_paged_engine(params, cfg, batch: int, ctx: int, *,
                      n_pages: Optional[int] = None,
                      page_tokens: int = 16, eos_id: Optional[int] = None,
                      spec=None, offload: bool = True,
                      cache_dtype=jnp.float32,
                      io_policy: Optional[IOPolicy] = None,
                      injector=None, tracer=None,
                      memory: Optional[TierManager] = None,
                      evict_policy: str = "lru",
                      offload_quant: bool = False,
                      disk_dir: Optional[str] = None,
                      park_idle_s: Optional[float] = None,
                      prefill_chunk: Optional[int] = None,
                      metrics=None):
    """Build a ``ContinuousBatcher`` over a paged KV cache.

    Returns ``(engine, kv)``; drive it with ``engine.run(kv.init_cache(),
    requests)``. The decode step is ``models.decode_step_paged`` — greedy
    output is byte-identical to the dense engine's, only where KV lives
    changes.

    ``prefill_chunk``: admit prompts in chunks of this many tokens
    (rounded to a page multiple), computed straight into the slot's
    pages (``models.prefill_chunk_paged``) and interleaved with decode
    steps for the already-active slots — a long admit no longer stalls
    every decoding stream for its whole prefill. Token streams stay
    byte-identical to the one-shot dense prefill. None = classic
    dense-scratch prefill + scatter install.
    """
    from ..models import model as M
    from .engine import ContinuousBatcher

    kv = PagedKVCache(cfg, batch=batch, ctx=ctx, n_pages=n_pages,
                      page_tokens=page_tokens, dtype=cache_dtype,
                      offload=offload, io_policy=io_policy,
                      injector=injector, tracer=tracer, memory=memory,
                      evict_policy=evict_policy,
                      offload_quant=offload_quant, disk_dir=disk_dir,
                      park_idle_s=park_idle_s)

    def prefill_one(prompt):
        c1 = M.init_cache(cfg, 1, ctx, dtype=cache_dtype)
        logits, c1 = M.prefill(params, cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def decode(cache, tokens):
        # jitted steady-state step: the paged hot path runs compiled,
        # not op-by-op (the one-shot dense-scratch prefill stays eager —
        # chunked admission is the fast path that replaces it)
        return _decode_paged_jit(params, cfg, cache, tokens)

    def chunk_step(view, tokens, write=True):
        return _prefill_chunk_jit(params, cfg, view, tokens, write)

    def write_slot(cache, slot_cache, slot, length):   # paged: kv.install
        raise RuntimeError("paged engine installs via kv, not write_slot")

    if prefill_chunk is not None:
        # page-sized chunks: chunk boundaries must align with page
        # boundaries so fresh pages are filled whole before a future
        # admit may share them
        prefill_chunk = max(prefill_chunk // page_tokens, 1) * page_tokens
    eng = ContinuousBatcher(batch, prefill_one, write_slot, decode,
                            eos_id=eos_id, spec=spec, kv=kv,
                            tracer=tracer, metrics=metrics,
                            prefill_chunk=prefill_chunk,
                            chunk_step=chunk_step)
    return eng, kv
