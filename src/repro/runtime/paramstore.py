"""Layer-sharded, mmap-backed parameter store.

The paper's low-RAM regime keeps model weights on disk (mmap'd) and
streams a *window* of layers through memory; prima.cpp inherits
llama.cpp's single-file GGUF mmap. Here the store is **layer-sharded**:
each decoder layer's leaves are packed into one flat file
(``layer_00017.bin``) next to a JSON manifest, so

  * a layer is one sequential read (the unit the latency model prices as
    ``layer_bytes / disk_speed``),
  * releasing a layer behind the compute front is one ``madvise`` on one
    mapping — prefetch (ahead of the front) and release (behind it) touch
    disjoint files and can never fight over the same pages (the paper's
    prefetch-release conflict, §3.1),
  * the head (embedding / final norm / lm head) lives in ``head.bin`` and
    stays resident, mirroring the paper's head-device accounting.

``ParamStore.layer(i)`` returns zero-copy numpy views into the mapping;
the async prefetcher (``runtime.streaming``) copies them into staging
buffers off-thread. ``ResidentSource`` adapts an in-memory pytree to the
same ``ParamSource`` interface so every layer-wise consumer can run
resident or streamed without branching.

Version-2 manifests persist **quantized** leaves: a ``QuantizedTensor``
(packed int4/int2 values + bf16 group scales, ``quant.grouped``) is
stored as two flat sub-leaves — ``part: "packed"`` and ``part: "scale"``
— that share a ``quant: {bits, group, shape}`` record, and ``layer(i)``
reassembles the ``QuantizedTensor`` from zero-copy mmap views. This is
the paper's Q4K-weights-on-disk regime: the disk term the latency model
prices is ``layer_bytes / s_disk``, and packing int4 in the store cuts
``layer_bytes`` ~4x against bf16 in exactly the window the prefetcher
streams. Version-1 manifests (unquantized) load unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import mmap
import os
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from ..quant.grouped import QuantizedTensor
from .iopolicy import ShortReadError

Params = Dict[str, Any]

MANIFEST = "manifest.json"
HEAD_FILE = "head.bin"
SUPPORTED_VERSIONS = (1, 2)

#: families whose per-layer stack lives under params["blocks"] with a
#: leading layer axis — the layout the store shards.
STACKED_FAMILIES = ("dense", "moe", "vlm", "ssm")


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _dtype_name(dt) -> str:
    return np.dtype(dt).name if np.dtype(dt).name != "void" else str(dt)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One flat sub-leaf inside a layer (or head) file.

    Unquantized leaves are one spec (``part is None``). A quantized leaf
    is two specs sharing ``key``: ``part == "packed"`` (int4/int2 codes)
    and ``part == "scale"`` (bf16 group scales), each carrying the same
    ``quant = {bits, group, shape}`` record (``shape`` is the original
    unpacked weight shape, layer axis stripped for layer files).
    """

    key: str                 # "/"-joined dict path, e.g. "attn/wq"
    shape: Tuple[int, ...]   # per-layer shape (layer axis stripped)
    dtype: str
    offset: int              # byte offset inside the file
    nbytes: int
    part: Optional[str] = None       # None | "packed" | "scale"
    quant: Optional[dict] = None     # {bits, group, shape} (v2 manifests)

    @classmethod
    def from_dict(cls, d: dict) -> "LeafSpec":
        return cls(key=d["key"], shape=tuple(d["shape"]), dtype=d["dtype"],
                   offset=d["offset"], nbytes=d["nbytes"],
                   part=d.get("part"), quant=d.get("quant"))

    def to_dict(self) -> dict:
        out = {"key": self.key, "shape": list(self.shape),
               "dtype": self.dtype, "offset": self.offset,
               "nbytes": self.nbytes}
        if self.part is not None:        # v1 manifests stay byte-identical
            out["part"] = self.part
            out["quant"] = self.quant
        return out


def _iter_leaves(tree: Params, prefix: str = "") -> Iterator[Tuple[str, Any]]:
    """Deterministic (sorted) walk of a nested-dict pytree."""
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            yield from _iter_leaves(v, path + "/")
        else:
            yield path, v


def _unflatten(leaves: Dict[str, Any]) -> Params:
    out: Params = {}
    for key, v in leaves.items():
        parts = key.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def _layer_file(i: int) -> str:
    return f"layer_{i:05d}.bin"


def _flat_parts(tree: Params, *, strip_layer_axis: bool
                ) -> List[Tuple[str, Optional[str], np.ndarray,
                                Optional[dict]]]:
    """Flatten a pytree into (key, part, array, quant) write records.

    A ``QuantizedTensor`` leaf becomes two records (packed values + group
    scales) sharing a ``quant`` metadata dict; everything else is one
    plain record. One device->host transfer per leaf, not per layer.
    """
    out: List[Tuple[str, Optional[str], np.ndarray, Optional[dict]]] = []
    for key, leaf in _iter_leaves(tree):
        if isinstance(leaf, QuantizedTensor):
            shape = list(leaf.shape[1:] if strip_layer_axis else leaf.shape)
            q = {"bits": int(leaf.bits), "group": int(leaf.group),
                 "shape": shape}
            out.append((key, "packed", np.asarray(leaf.packed), q))
            out.append((key, "scale", np.asarray(leaf.scale), q))
        else:
            out.append((key, None, np.asarray(leaf), None))
    return out


# --------------------------------------------------------------------------- #
#  save
# --------------------------------------------------------------------------- #

def save_param_store(params: Params, cfg, directory: str) -> str:
    """Persist ``params`` as a layer-sharded store; returns ``directory``.

    ``params["blocks"]`` leaves must be layer-stacked (leading L axis) —
    the layout ``models.init_params`` produces for dense/moe/vlm/ssm.
    Leaves may be ``QuantizedTensor``s (``quant.quantize_tree`` /
    ``serve.quantize_ring_params`` output): packed values and group
    scales are persisted as sub-leaves and the manifest bumps to
    version 2. Ring-permuted banks are not supported (save the
    global-layer-ordered tree; the ring prefetcher permutes at read).
    """
    if cfg.family not in STACKED_FAMILIES:
        raise ValueError(f"param store unsupported for family {cfg.family}")
    os.makedirs(directory, exist_ok=True)
    L = cfg.n_layers

    layer_specs: List[dict] = []
    offset = 0
    flat = _flat_parts(params["blocks"], strip_layer_axis=True)
    for key, part, arr, q in flat:
        if arr.shape[0] != L:
            raise ValueError(f"{key}: leading axis {arr.shape[0]} != L={L}")
        per = arr[0]
        layer_specs.append(LeafSpec(
            key=key, shape=tuple(per.shape), dtype=_dtype_name(arr.dtype),
            offset=offset, nbytes=per.nbytes, part=part,
            quant=q).to_dict())
        offset += per.nbytes
    layer_nbytes = offset

    for i in range(L):
        with open(os.path.join(directory, _layer_file(i)), "wb") as f:
            for key, part, arr, q in flat:
                f.write(np.ascontiguousarray(arr[i]).tobytes())

    head_specs: List[dict] = []
    offset = 0
    head_tree = {k: v for k, v in params.items() if k != "blocks"}
    with open(os.path.join(directory, HEAD_FILE), "wb") as f:
        for key, part, arr, q in _flat_parts(head_tree,
                                             strip_layer_axis=False):
            arr = np.ascontiguousarray(arr)
            head_specs.append(LeafSpec(
                key=key, shape=tuple(arr.shape),
                dtype=_dtype_name(arr.dtype), offset=offset,
                nbytes=arr.nbytes, part=part, quant=q).to_dict())
            f.write(arr.tobytes())
            offset += arr.nbytes

    quantized = any(d.get("part") for d in layer_specs + head_specs)
    manifest = {
        "version": 2 if quantized else 1,
        "model": cfg.name,
        "family": cfg.family,
        "n_layers": L,
        "layer_nbytes": layer_nbytes,
        "leaves": layer_specs,
        "head_leaves": head_specs,
    }
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    return directory


# --------------------------------------------------------------------------- #
#  sources
# --------------------------------------------------------------------------- #

class ParamSource:
    """Layer-wise parameter access: what the layer-wise forward consumes.

    ``layer(i)`` returns the per-layer block pytree (no leading layer
    axis); ``head()`` the non-block params (embed / final_norm / unembed).
    Implementations: ``ResidentSource`` (in-memory pytree, the parity
    baseline), ``ParamStore`` (cold mmap reads), and
    ``streaming.StreamingParamSource`` (async prefetch window).
    """

    n_layers: int

    def layer(self, i: int) -> Params:
        raise NotImplementedError

    def head(self) -> Params:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ResidentSource(ParamSource):
    """Adapt a fully-resident stacked pytree to the ParamSource interface."""

    def __init__(self, params: Params):
        self._params = params
        self.n_layers = int(
            jax.tree.leaves(params["blocks"])[0].shape[0])

    def layer(self, i: int) -> Params:
        return jax.tree.map(lambda a: a[i], self._params["blocks"])

    def head(self) -> Params:
        return {k: v for k, v in self._params.items() if k != "blocks"}


class ParamStore(ParamSource):
    """Read side of the layer-sharded store (one mmap per layer file).

    ``layer(i)`` returns numpy views into the mapping — pages fault in on
    first touch (the "mmap offloading" the paper starts from).
    ``release(i)`` advises the kernel to drop layer i's pages
    (``MADV_DONTNEED``), the explicit release half of the
    prefetch-release fix; it is a no-op where madvise is unavailable.
    """

    def __init__(self, directory: str):
        self.directory = directory
        path = os.path.join(directory, MANIFEST)
        try:
            with open(path) as f:
                m = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"corrupt param-store manifest {path}: {e}") \
                from e
        if not isinstance(m, dict):
            raise ValueError(f"corrupt param-store manifest {path}: "
                             f"expected an object, got {type(m).__name__}")
        self.version = int(m.get("version", 1))
        if self.version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported param-store manifest version {self.version} "
                f"(supported: {SUPPORTED_VERSIONS})")
        try:
            self.manifest = m
            self.n_layers = int(m["n_layers"])
            self.layer_nbytes = int(m["layer_nbytes"])
            self.family = m["family"]
            self._leaves = [LeafSpec.from_dict(d) for d in m["leaves"]]
            self._head_leaves = [LeafSpec.from_dict(d)
                                 for d in m["head_leaves"]]
        except KeyError as e:
            raise ValueError(
                f"corrupt param-store manifest {path}: missing {e}") from e
        self._maps: Dict[int, mmap.mmap] = {}
        self._files: Dict[int, Any] = {}
        self.released = 0          # release() calls that actually dropped
        self.released_bytes = 0    # bytes those drops returned to the OS

    @property
    def quant_format(self) -> Optional[str]:
        """"q4"/"q2" if any persisted leaf is quantized, else None."""
        bits = {s.quant["bits"] for s in self._leaves + self._head_leaves
                if s.quant is not None}
        return f"q{max(bits)}" if bits else None

    # -- mapping lifecycle ------------------------------------------------ #

    def _map(self, i: int) -> mmap.mmap:
        mm = self._maps.get(i)
        if mm is None:
            path = os.path.join(self.directory, _layer_file(i))
            f = open(path, "rb")
            try:
                mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            except ValueError as e:      # zero-length file: truncated away
                f.close()
                raise ShortReadError(
                    f"layer {i}: cannot map {path} "
                    f"({os.path.getsize(path)} bytes, manifest requires "
                    f"{self.layer_nbytes}): {e}", layer=i, path=path,
                    expected=self.layer_nbytes,
                    got=os.path.getsize(path)) from e
            self._files[i] = f
            self._maps[i] = mm
        return mm

    def reopen(self, i: int) -> None:
        """Drop layer ``i``'s cached mapping so the next read re-opens and
        re-maps the file — ``IOPolicy``'s retry hook after a transient
        read error (flaky disk, file replaced/re-flushed underneath us).
        """
        mm = self._maps.pop(i, None)
        f = self._files.pop(i, None)
        if mm is not None:
            try:
                mm.close()
            except BufferError:   # an old view pins the map; re-map fresh
                pass
        if f is not None:
            f.close()

    @staticmethod
    def _read_leaves(specs: List[LeafSpec], buf: np.ndarray, *,
                     copy: bool = False) -> Params:
        """Materialize leaves (views into ``buf``) from their specs.

        Quantized sub-leaf pairs reassemble into ``QuantizedTensor``s —
        packed values and scales both stay zero-copy views unless
        ``copy`` is set.
        """
        leaves: Dict[str, Any] = {}
        pending: Dict[str, dict] = {}
        for spec in specs:
            raw = buf[spec.offset:spec.offset + spec.nbytes]
            arr = raw.view(_np_dtype(spec.dtype)).reshape(spec.shape)
            if copy:
                arr = arr.copy()
            if spec.part is None:
                leaves[spec.key] = arr
            else:
                ent = pending.setdefault(spec.key,
                                         dict(spec.quant or {}))
                ent[spec.part] = arr
        for key, ent in pending.items():
            if "packed" not in ent or "scale" not in ent:
                raise ValueError(
                    f"quantized leaf {key}: manifest is missing its "
                    f"{'scale' if 'packed' in ent else 'packed'} sub-leaf")
            if not {"bits", "group", "shape"} <= ent.keys():
                raise ValueError(
                    f"quantized leaf {key}: manifest quant record is "
                    f"missing {sorted({'bits', 'group', 'shape'} - ent.keys())}")
            leaves[key] = QuantizedTensor(
                packed=ent["packed"], scale=ent["scale"],
                bits=int(ent["bits"]), group=int(ent["group"]),
                shape=tuple(ent["shape"]))
        return _unflatten(leaves)

    def layer(self, i: int) -> Params:
        if not 0 <= i < self.n_layers:
            raise IndexError(i)
        mm = self._map(i)
        if len(mm) < self.layer_nbytes:
            # the file shrank after the manifest loaded: classify it as a
            # short read naming the layer/file instead of letting
            # np.frombuffer throw a bare ValueError (fatal under IOPolicy,
            # which would mask that a retry with reopen() could succeed)
            path = os.path.join(self.directory, _layer_file(i))
            raise ShortReadError(
                f"layer {i} short read: {path} maps {len(mm)} bytes but "
                f"the manifest requires {self.layer_nbytes} "
                f"(file truncated after manifest load?)",
                layer=i, path=path, expected=self.layer_nbytes,
                got=len(mm))
        buf = np.frombuffer(mm, dtype=np.uint8, count=self.layer_nbytes)
        return self._read_leaves(self._leaves, buf)

    def head(self) -> Params:
        path = os.path.join(self.directory, HEAD_FILE)
        with open(path, "rb") as f:
            raw = f.read()
        buf = np.frombuffer(raw, dtype=np.uint8)
        return self._read_leaves(self._head_leaves, buf, copy=True)

    def release(self, i: int) -> None:
        """Drop layer i's page-cache mapping behind the compute front.

        The madvise is advisory, but the accounting is not: every
        successful drop adds ``layer_nbytes`` to ``released_bytes`` so a
        tier-budget audit can balance bytes-read against bytes-returned
        (surfaced through ``PrefetchStats.released_bytes`` and the
        ``store/released_bytes`` telemetry counter).
        """
        mm = self._maps.get(i)
        if mm is None:
            return
        try:
            if hasattr(mmap, "MADV_DONTNEED"):
                mm.madvise(mmap.MADV_DONTNEED)
                self.released += 1
                self.released_bytes += self.layer_nbytes
        except (OSError, ValueError):  # pragma: no cover - platform quirks
            pass

    def willneed(self, i: int) -> None:
        """Hint the kernel to start reading layer i (prefetch side).

        Bounds-checked like ``layer()``, and ``_map()`` failures (a
        missing/unreadable ``layer_*.bin`` is store corruption) propagate
        — only the madvise call itself, a pure hint, is best-effort.
        """
        if not 0 <= i < self.n_layers:
            raise IndexError(i)
        mm = self._map(i)
        if hasattr(mmap, "MADV_WILLNEED"):
            try:
                mm.madvise(mmap.MADV_WILLNEED)
            except (OSError, ValueError):  # pragma: no cover - hint only
                pass

    def close(self) -> None:
        for mm in self._maps.values():
            try:
                mm.close()
            except BufferError:     # a caller still holds a layer() view
                pass
        for f in self._files.values():
            f.close()
        self._maps.clear()
        self._files.clear()

    def __enter__(self) -> "ParamStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_resident(store: ParamStore) -> Params:
    """Materialize a full stacked pytree from a store (test utility — the
    inverse of ``save_param_store`` up to leaf copies)."""
    layers = [store.layer(i) for i in range(store.n_layers)]
    blocks = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                          *layers)
    out = dict(store.head())
    out["blocks"] = blocks
    return out
