"""Layer-sharded, mmap-backed parameter store.

The paper's low-RAM regime keeps model weights on disk (mmap'd) and
streams a *window* of layers through memory; prima.cpp inherits
llama.cpp's single-file GGUF mmap. Here the store is **layer-sharded**:
each decoder layer's leaves are packed into one flat file
(``layer_00017.bin``) next to a JSON manifest, so

  * a layer is one sequential read (the unit the latency model prices as
    ``layer_bytes / disk_speed``),
  * releasing a layer behind the compute front is one ``madvise`` on one
    mapping — prefetch (ahead of the front) and release (behind it) touch
    disjoint files and can never fight over the same pages (the paper's
    prefetch-release conflict, §3.1),
  * the head (embedding / final norm / lm head) lives in ``head.bin`` and
    stays resident, mirroring the paper's head-device accounting.

``ParamStore.layer(i)`` returns zero-copy numpy views into the mapping;
the async prefetcher (``runtime.streaming``) copies them into staging
buffers off-thread. ``ResidentSource`` adapts an in-memory pytree to the
same ``ParamSource`` interface so every layer-wise consumer can run
resident or streamed without branching.
"""
from __future__ import annotations

import dataclasses
import json
import mmap
import os
from typing import Any, Dict, Iterator, List, Tuple

import jax
import numpy as np

Params = Dict[str, Any]

MANIFEST = "manifest.json"
HEAD_FILE = "head.bin"

#: families whose per-layer stack lives under params["blocks"] with a
#: leading layer axis — the layout the store shards.
STACKED_FAMILIES = ("dense", "moe", "vlm", "ssm")


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _dtype_name(dt) -> str:
    return np.dtype(dt).name if np.dtype(dt).name != "void" else str(dt)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One leaf inside a flat layer (or head) file."""

    key: str                 # "/"-joined dict path, e.g. "attn/wq"
    shape: Tuple[int, ...]   # per-layer shape (layer axis stripped)
    dtype: str
    offset: int              # byte offset inside the file
    nbytes: int

    @classmethod
    def from_dict(cls, d: dict) -> "LeafSpec":
        return cls(key=d["key"], shape=tuple(d["shape"]), dtype=d["dtype"],
                   offset=d["offset"], nbytes=d["nbytes"])

    def to_dict(self) -> dict:
        return {"key": self.key, "shape": list(self.shape),
                "dtype": self.dtype, "offset": self.offset,
                "nbytes": self.nbytes}


def _iter_leaves(tree: Params, prefix: str = "") -> Iterator[Tuple[str, Any]]:
    """Deterministic (sorted) walk of a nested-dict pytree."""
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            yield from _iter_leaves(v, path + "/")
        else:
            yield path, v


def _unflatten(leaves: Dict[str, Any]) -> Params:
    out: Params = {}
    for key, v in leaves.items():
        parts = key.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def _layer_file(i: int) -> str:
    return f"layer_{i:05d}.bin"


# --------------------------------------------------------------------------- #
#  save
# --------------------------------------------------------------------------- #

def save_param_store(params: Params, cfg, directory: str) -> str:
    """Persist ``params`` as a layer-sharded store; returns ``directory``.

    ``params["blocks"]`` leaves must be layer-stacked (leading L axis) —
    the layout ``models.init_params`` produces for dense/moe/vlm/ssm.
    Quantized ring banks are not supported (convert before quantizing).
    """
    if cfg.family not in STACKED_FAMILIES:
        raise ValueError(f"param store unsupported for family {cfg.family}")
    os.makedirs(directory, exist_ok=True)
    L = cfg.n_layers

    layer_specs: List[dict] = []
    offset = 0
    # one device->host transfer per leaf (not per leaf per layer)
    flat = [(key, np.asarray(leaf))
            for key, leaf in _iter_leaves(params["blocks"])]
    for key, arr in flat:
        if arr.shape[0] != L:
            raise ValueError(f"{key}: leading axis {arr.shape[0]} != L={L}")
        per = arr[0]
        layer_specs.append(LeafSpec(
            key=key, shape=tuple(per.shape), dtype=_dtype_name(arr.dtype),
            offset=offset, nbytes=per.nbytes).to_dict())
        offset += per.nbytes
    layer_nbytes = offset

    for i in range(L):
        with open(os.path.join(directory, _layer_file(i)), "wb") as f:
            for key, arr in flat:
                f.write(np.ascontiguousarray(arr[i]).tobytes())

    head_specs: List[dict] = []
    offset = 0
    head_tree = {k: v for k, v in params.items() if k != "blocks"}
    head_flat = list(_iter_leaves(head_tree))
    with open(os.path.join(directory, HEAD_FILE), "wb") as f:
        for key, leaf in head_flat:
            arr = np.ascontiguousarray(np.asarray(leaf))
            head_specs.append(LeafSpec(
                key=key, shape=tuple(arr.shape),
                dtype=_dtype_name(arr.dtype), offset=offset,
                nbytes=arr.nbytes).to_dict())
            f.write(arr.tobytes())
            offset += arr.nbytes

    manifest = {
        "version": 1,
        "model": cfg.name,
        "family": cfg.family,
        "n_layers": L,
        "layer_nbytes": layer_nbytes,
        "leaves": layer_specs,
        "head_leaves": head_specs,
    }
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    return directory


# --------------------------------------------------------------------------- #
#  sources
# --------------------------------------------------------------------------- #

class ParamSource:
    """Layer-wise parameter access: what the layer-wise forward consumes.

    ``layer(i)`` returns the per-layer block pytree (no leading layer
    axis); ``head()`` the non-block params (embed / final_norm / unembed).
    Implementations: ``ResidentSource`` (in-memory pytree, the parity
    baseline), ``ParamStore`` (cold mmap reads), and
    ``streaming.StreamingParamSource`` (async prefetch window).
    """

    n_layers: int

    def layer(self, i: int) -> Params:
        raise NotImplementedError

    def head(self) -> Params:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ResidentSource(ParamSource):
    """Adapt a fully-resident stacked pytree to the ParamSource interface."""

    def __init__(self, params: Params):
        self._params = params
        self.n_layers = int(
            jax.tree.leaves(params["blocks"])[0].shape[0])

    def layer(self, i: int) -> Params:
        return jax.tree.map(lambda a: a[i], self._params["blocks"])

    def head(self) -> Params:
        return {k: v for k, v in self._params.items() if k != "blocks"}


class ParamStore(ParamSource):
    """Read side of the layer-sharded store (one mmap per layer file).

    ``layer(i)`` returns numpy views into the mapping — pages fault in on
    first touch (the "mmap offloading" the paper starts from).
    ``release(i)`` advises the kernel to drop layer i's pages
    (``MADV_DONTNEED``), the explicit release half of the
    prefetch-release fix; it is a no-op where madvise is unavailable.
    """

    def __init__(self, directory: str):
        self.directory = directory
        with open(os.path.join(directory, MANIFEST)) as f:
            m = json.load(f)
        self.manifest = m
        self.n_layers = int(m["n_layers"])
        self.layer_nbytes = int(m["layer_nbytes"])
        self.family = m["family"]
        self._leaves = [LeafSpec.from_dict(d) for d in m["leaves"]]
        self._head_leaves = [LeafSpec.from_dict(d) for d in m["head_leaves"]]
        self._maps: Dict[int, mmap.mmap] = {}
        self._files: Dict[int, Any] = {}
        self.released = 0          # release() calls that actually dropped

    # -- mapping lifecycle ------------------------------------------------ #

    def _map(self, i: int) -> mmap.mmap:
        mm = self._maps.get(i)
        if mm is None:
            f = open(os.path.join(self.directory, _layer_file(i)), "rb")
            mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            self._files[i] = f
            self._maps[i] = mm
        return mm

    def layer(self, i: int) -> Params:
        if not 0 <= i < self.n_layers:
            raise IndexError(i)
        mm = self._map(i)
        buf = np.frombuffer(mm, dtype=np.uint8, count=self.layer_nbytes)
        leaves = {}
        for spec in self._leaves:
            raw = buf[spec.offset:spec.offset + spec.nbytes]
            leaves[spec.key] = raw.view(_np_dtype(spec.dtype)).reshape(
                spec.shape)
        return _unflatten(leaves)

    def head(self) -> Params:
        path = os.path.join(self.directory, HEAD_FILE)
        leaves = {}
        with open(path, "rb") as f:
            raw = f.read()
        buf = np.frombuffer(raw, dtype=np.uint8)
        for spec in self._head_leaves:
            chunk = buf[spec.offset:spec.offset + spec.nbytes]
            leaves[spec.key] = chunk.view(_np_dtype(spec.dtype)).reshape(
                spec.shape).copy()
        return _unflatten(leaves)

    def release(self, i: int) -> None:
        """Drop layer i's page-cache mapping behind the compute front."""
        mm = self._maps.get(i)
        if mm is None:
            return
        try:
            if hasattr(mmap, "MADV_DONTNEED"):
                mm.madvise(mmap.MADV_DONTNEED)
                self.released += 1
        except (OSError, ValueError):  # pragma: no cover - platform quirks
            pass

    def willneed(self, i: int) -> None:
        """Hint the kernel to start reading layer i (prefetch side)."""
        try:
            if hasattr(mmap, "MADV_WILLNEED"):
                self._map(i).madvise(mmap.MADV_WILLNEED)
        except (OSError, ValueError):  # pragma: no cover
            pass

    def close(self) -> None:
        for mm in self._maps.values():
            try:
                mm.close()
            except BufferError:     # a caller still holds a layer() view
                pass
        for f in self._files.values():
            f.close()
        self._maps.clear()
        self._files.clear()

    def __enter__(self) -> "ParamStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_resident(store: ParamStore) -> Params:
    """Materialize a full stacked pytree from a store (test utility — the
    inverse of ``save_param_store`` up to leaf copies)."""
    layers = [store.layer(i) for i in range(store.n_layers)]
    blocks = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                          *layers)
    out = dict(store.head())
    out["blocks"] = blocks
    return out
