"""Jit'd wrappers that dispatch to the Pallas kernels on TPU and to
``interpret=True`` (or the jnp oracle) elsewhere.

``use_kernels(False)`` forces the pure-jnp path — used by the GSPMD
dry-run, where the module must lower for the host platform.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_decode import flash_decode as _flash_decode_kernel
from .flash_decode import flash_verify as _flash_verify_kernel
from .paged_decode import paged_decode as _paged_decode_kernel
from .paged_decode import paged_decode_quant as _paged_decode_quant_kernel
from .paged_decode import paged_verify as _paged_verify_kernel
from .paged_decode import paged_verify_quant as _paged_verify_quant_kernel
from .paged_prefill import paged_prefill as _paged_prefill_kernel
from .q4_matmul import q4_matmul as _q4_matmul_kernel
from .ssd_scan import ssd_scan as _ssd_scan_kernel

_FORCE_REF = False


def use_kernels(enable: bool) -> None:
    global _FORCE_REF
    _FORCE_REF = not enable


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def kernels_active() -> bool:
    """True when the compiled Pallas kernels would actually run (TPU
    backend, not forced to ref) — model layers use this to pick between
    the fused kernel and the pure-jnp path at trace time."""
    return not _FORCE_REF and not _interpret()


def q4_matmul(x, packed, scale, *, group: int = 64):
    if _FORCE_REF:
        return ref.q4_matmul_ref(x, packed, scale, group=group)
    return _q4_matmul_kernel(x, packed, scale, group=group,
                             interpret=_interpret())


def flash_decode(q, k, v, kv_len, *, window: Optional[int] = None):
    if _FORCE_REF:
        return ref.flash_decode_ref(q, k, v, kv_len, window=window)
    return _flash_decode_kernel(q, k, v, kv_len, window=window,
                                interpret=_interpret())


def flash_verify(q, k, v, kv_len, *, window: Optional[int] = None):
    if _FORCE_REF:
        return ref.flash_verify_ref(q, k, v, kv_len, window=window)
    return _flash_verify_kernel(q, k, v, kv_len, window=window,
                                interpret=_interpret())


def paged_decode(q, k_pages, v_pages, table, kv_len, *,
                 window: Optional[int] = None):
    if _FORCE_REF:
        return ref.paged_decode_ref(q, k_pages, v_pages, table, kv_len,
                                    window=window)
    return _paged_decode_kernel(q, k_pages, v_pages, table, kv_len,
                                window=window, interpret=_interpret())


def paged_verify(q, k_pages, v_pages, table, kv_len, *,
                 window: Optional[int] = None):
    if _FORCE_REF:
        return ref.paged_verify_ref(q, k_pages, v_pages, table, kv_len,
                                    window=window)
    return _paged_verify_kernel(q, k_pages, v_pages, table, kv_len,
                                window=window, interpret=_interpret())


def paged_prefill(q, k_pages, v_pages, table, kv_len, *,
                  window: Optional[int] = None):
    if _FORCE_REF:
        return ref.paged_prefill_ref(q, k_pages, v_pages, table, kv_len,
                                     window=window)
    return _paged_prefill_kernel(q, k_pages, v_pages, table, kv_len,
                                 window=window, interpret=_interpret())


def paged_decode_quant(q, k_pages, v_pages, k_scale, v_scale, table,
                       kv_len, *, window: Optional[int] = None):
    if _FORCE_REF:
        return ref.paged_decode_quant_ref(q, k_pages, v_pages, k_scale,
                                          v_scale, table, kv_len,
                                          window=window)
    return _paged_decode_quant_kernel(q, k_pages, v_pages, k_scale,
                                      v_scale, table, kv_len,
                                      window=window,
                                      interpret=_interpret())


def paged_verify_quant(q, k_pages, v_pages, k_scale, v_scale, table,
                       kv_len, *, window: Optional[int] = None):
    if _FORCE_REF:
        return ref.paged_verify_quant_ref(q, k_pages, v_pages, k_scale,
                                          v_scale, table, kv_len,
                                          window=window)
    return _paged_verify_quant_kernel(q, k_pages, v_pages, k_scale,
                                      v_scale, table, kv_len,
                                      window=window,
                                      interpret=_interpret())


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk: int = 128):
    if _FORCE_REF:
        return ref.ssd_scan_ref(x, dt, A, Bmat, Cmat, chunk=chunk)
    return _ssd_scan_kernel(x, dt, A, Bmat, Cmat, chunk=chunk,
                            interpret=_interpret())
