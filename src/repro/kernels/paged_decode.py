"""Pallas TPU kernel: paged decode/verify attention (block-table gather).

The paged KV cache (``runtime.kvcache``) stores K/V in a global pool of
fixed-size token pages; each sequence addresses its pages through a
per-slot block table. This kernel is ``flash_decode.flash_verify`` with
the KV-chunk axis routed through that table: grid position ``j`` is the
*logical* page of the sequence (covering absolute positions
``j*bs .. (j+1)*bs - 1``) and the BlockSpec index map reads the
scalar-prefetched table to fetch the *physical* page — the gather costs
no extra HBM traffic, pages stream into VMEM exactly like contiguous
chunks would.

Both the block table and ``kv_len`` arrive via scalar prefetch
(``PrefetchScalarGridSpec``): index maps need the table before the body
runs, and masking needs real lengths. Everything else — the online
softmax across the sequential page axis, the (draft position, GQA rep)
row flattening, the causal mask among draft tokens — is unchanged from
the contiguous kernel.

Block working set (bs=page_tokens rounded to >= 8 sublanes, T=8, n_rep=8,
D=128) matches the contiguous kernel's at block_s = bs.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_verify_kernel(kv_len_ref, table_ref, q_ref, k_ref, v_ref,
                         out_ref, acc_ref, m_ref, l_ref, *, block_s: int,
                         window: Optional[int], n_chunks: int, n_draft: int,
                         n_rep: int):
    """Identical math to ``flash_decode._verify_kernel``; the page index
    ``s_idx`` is logical — physical routing happened in the index maps."""
    b = pl.program_id(0)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    rows = n_draft * n_rep
    q = q_ref[0, 0]                                  # (rows, D)
    k = k_ref[0, 0]                                  # (bs, D)
    v = v_ref[0, 0]
    kv_len = kv_len_ref[b]

    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.dot(q.astype(jnp.float32) * scale, k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)  # (rows, bs)

    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32,
                                                     (1, block_s), 1)
    t_row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // n_rep
    qpos = kv_len - n_draft + t_row                  # (rows, 1)
    mask = pos <= qpos                               # (rows, bs)
    if window is not None:
        mask &= pos > (qpos - window)
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_ref[...]                              # (rows, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_chunks - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                 table: jnp.ndarray, kv_len: jnp.ndarray, *,
                 window: Optional[int] = None,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, T, H, D); k_pages/v_pages: (P, bs, h_kv, D);
    table: (B, nb) int32 page ids; kv_len: (B,) -> (B, T, H, D).

    Scores T draft positions against a paged KV cache in one pass.
    ``kv_len`` counts valid positions *including* the T draft tokens the
    caller already wrote through the table, so T = 1 is ordinary paged
    decode attention. Table entries past ``ceil(kv_len/bs)`` may be any
    valid page id (sink/stale) — those positions are masked.
    """
    B, T, H, D = q.shape
    P, bs, h_kv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = table.shape[1]
    n_rep = H // h_kv
    rows = T * n_rep
    # (B, h_kv, T*n_rep, D) with row = t * n_rep + rep
    qg = q.reshape(B, T, h_kv, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, h_kv, rows, D)
    kt = k_pages.transpose(0, 2, 1, 3)               # (P, h_kv, bs, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    grid = (B, h_kv, nb)
    out = pl.pallas_call(
        functools.partial(_paged_verify_kernel, block_s=bs, window=window,
                          n_chunks=nb, n_draft=T, n_rep=n_rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                   # kv_len, block table
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, j, kv_len, tab: (b, h, 0, 0)),
                # physical page routed through the prefetched table
                pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, j, kv_len, tab:
                             (tab[b, j], h, 0, 0)),
                pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, j, kv_len, tab:
                             (tab[b, j], h, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, j, kv_len, tab:
                                   (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, D), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, h_kv, rows, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), table.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, h_kv, T, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, T, H, D)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                 table: jnp.ndarray, kv_len: jnp.ndarray, *,
                 window: Optional[int] = None,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D) -> (B, H, D): the T = 1 slice of ``paged_verify``."""
    return paged_verify(q[:, None], k_pages, v_pages, table, kv_len,
                        window=window, interpret=interpret)[:, 0]


def _paged_verify_quant_kernel(kv_len_ref, table_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, out_ref, acc_ref, m_ref,
                               l_ref, *, block_s: int,
                               window: Optional[int], n_chunks: int,
                               n_draft: int, n_rep: int):
    """``_paged_verify_kernel`` over int8 pages: K/V blocks arrive packed
    (one byte per element) plus a per-(position, kv-head) scale block;
    dequantization is fused into the f32 upcast the attention math does
    anyway, so the only HBM traffic for KV is the quantized bytes."""
    b = pl.program_id(0)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    rows = n_draft * n_rep
    q = q_ref[0, 0]                                  # (rows, D)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]   # (bs, D) * (bs, 1)
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
    kv_len = kv_len_ref[b]

    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.dot(q.astype(jnp.float32) * scale, k.T,
                preferred_element_type=jnp.float32)  # (rows, bs)

    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32,
                                                     (1, block_s), 1)
    t_row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // n_rep
    qpos = kv_len - n_draft + t_row                  # (rows, 1)
    mask = pos <= qpos                               # (rows, bs)
    if window is not None:
        mask &= pos > (qpos - window)
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_ref[...]                              # (rows, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_chunks - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify_quant(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, k_scale: jnp.ndarray,
                       v_scale: jnp.ndarray, table: jnp.ndarray,
                       kv_len: jnp.ndarray, *,
                       window: Optional[int] = None,
                       interpret: bool = False) -> jnp.ndarray:
    """``paged_verify`` over int8 pages. k_pages/v_pages: (P, bs, h_kv, D)
    int8; k_scale/v_scale: (P, bs, h_kv) per-(position, kv-head) scales
    (``layers.quantize_kv`` convention: amax/127). Dequant happens inside
    the kernel — the pages are never inflated in HBM."""
    B, T, H, D = q.shape
    bs, h_kv = k_pages.shape[1], k_pages.shape[2]
    nb = table.shape[1]
    n_rep = H // h_kv
    rows = T * n_rep
    qg = q.reshape(B, T, h_kv, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, h_kv, rows, D)
    kt = k_pages.transpose(0, 2, 1, 3)               # (P, h_kv, bs, D)
    vt = v_pages.transpose(0, 2, 1, 3)
    kst = k_scale.transpose(0, 2, 1)[..., None] \
        .astype(jnp.float32)                         # (P, h_kv, bs, 1)
    vst = v_scale.transpose(0, 2, 1)[..., None].astype(jnp.float32)

    page_spec = pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, j, kv_len, tab:
                             (tab[b, j], h, 0, 0))
    scale_spec = pl.BlockSpec((1, 1, bs, 1),
                              lambda b, h, j, kv_len, tab:
                              (tab[b, j], h, 0, 0))
    grid = (B, h_kv, nb)
    out = pl.pallas_call(
        functools.partial(_paged_verify_quant_kernel, block_s=bs,
                          window=window, n_chunks=nb, n_draft=T,
                          n_rep=n_rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                   # kv_len, block table
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, j, kv_len, tab: (b, h, 0, 0)),
                page_spec, page_spec, scale_spec, scale_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, j, kv_len, tab:
                                   (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, D), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, h_kv, rows, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), table.astype(jnp.int32), qg, kt, vt,
      kst, vst)
    return out.reshape(B, h_kv, T, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, T, H, D)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_quant(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, k_scale: jnp.ndarray,
                       v_scale: jnp.ndarray, table: jnp.ndarray,
                       kv_len: jnp.ndarray, *,
                       window: Optional[int] = None,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D) -> (B, H, D): the T = 1 slice of
    ``paged_verify_quant``."""
    return paged_verify_quant(q[:, None], k_pages, v_pages, k_scale,
                              v_scale, table, kv_len, window=window,
                              interpret=interpret)[:, 0]
