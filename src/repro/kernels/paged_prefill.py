"""Pallas TPU kernel: paged flash-*prefill* attention (chunk vs pages).

Chunked admission (``runtime.engine``) processes a prompt in page-sized
chunks written directly into the ``BlockPool``: chunk ``j`` holds the S
newest prompt positions, every earlier position already lives in pages
addressed by the slot's block table. Attention for the chunk is then
"S query rows against the paged prefix plus a causal triangle among
themselves" — exactly the ``paged_decode.paged_verify`` geometry with
``n_draft = S``, so the kernel shares its structure: scalar-prefetched
block table in the index maps, online softmax across the sequential
page axis, (chunk position, GQA rep) row flattening.

What is prefill-specific is the dead-page guard: during a long admit
most logical pages of the table are either *ahead* of the chunk's
causal frontier (allocated for positions not yet written) or *behind*
its attention window — their blocks would be fully masked. The kernel
skips the matmul/softmax work for those pages with ``pl.when`` (the
DMA still streams them; block shapes are static), which matters when
the table is sized for the full context but the chunk sits near the
front of it.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_prefill_kernel(kv_len_ref, table_ref, q_ref, k_ref, v_ref,
                          out_ref, acc_ref, m_ref, l_ref, *, block_s: int,
                          window: Optional[int], n_chunks: int, chunk: int,
                          n_rep: int):
    b = pl.program_id(0)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = kv_len_ref[b]
    blk_lo = s_idx * block_s
    # newest query position is kv_len - 1; a page whose first position is
    # past it is entirely future (fully masked). With a sliding window the
    # oldest position any row can see is the first chunk row's window
    # start, kv_len - chunk - window, so a page that ends before it is
    # entirely expired.
    live = blk_lo < kv_len
    if window is not None:
        live &= (blk_lo + block_s) > (kv_len - chunk - window)

    @pl.when(live)
    def _compute():
        rows = chunk * n_rep
        q = q_ref[0, 0]                              # (rows, D)
        k = k_ref[0, 0]                              # (bs, D)
        v = v_ref[0, 0]

        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jnp.dot(q.astype(jnp.float32) * scale,
                    k.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)  # (rows, bs)

        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        t_row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // n_rep
        qpos = kv_len - chunk + t_row                # (rows, 1)
        mask = pos <= qpos                           # (rows, bs)
        if window is not None:
            mask &= pos > (qpos - window)
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_ref[...]                          # (rows, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s_idx == n_chunks - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_prefill(q: jnp.ndarray, k_pages: jnp.ndarray,
                  v_pages: jnp.ndarray, table: jnp.ndarray,
                  kv_len: jnp.ndarray, *, window: Optional[int] = None,
                  interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, D) — one prompt chunk of S tokens per sequence;
    k_pages/v_pages: (P, bs, h_kv, D); table: (B, nb) int32 page ids;
    kv_len: (B,) valid positions *including* the S chunk tokens the
    caller already wrote through the table -> (B, S, H, D).

    Chunk position t sits at absolute position ``kv_len - S + t`` and
    attends causally over everything at or before it (minus the sliding
    window, if any). Table entries past ``ceil(kv_len/bs)`` may be any
    valid page id (sink/stale) — those pages are skipped, not just
    masked.
    """
    B, S, H, D = q.shape
    bs, h_kv = k_pages.shape[1], k_pages.shape[2]
    nb = table.shape[1]
    n_rep = H // h_kv
    rows = S * n_rep
    qg = q.reshape(B, S, h_kv, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, h_kv, rows, D)
    kt = k_pages.transpose(0, 2, 1, 3)               # (P, h_kv, bs, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    grid = (B, h_kv, nb)
    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, block_s=bs, window=window,
                          n_chunks=nb, chunk=S, n_rep=n_rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,                   # kv_len, block table
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, j, kv_len, tab: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, j, kv_len, tab:
                             (tab[b, j], h, 0, 0)),
                pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, j, kv_len, tab:
                             (tab[b, j], h, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, j, kv_len, tab:
                                   (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, D), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, h_kv, rows, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), table.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, h_kv, S, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, D)
