"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

Grid (B, nh, S/chunk): the chunk axis is sequential; the running state
(P, N) lives in VMEM scratch and flows across chunk steps. Each program
computes the intra-chunk quadratic part on the MXU and folds the
inter-chunk recurrence — the TPU-native shape of the paper's "split the
work into blocks small enough for fast memory" insight applied to SSD.

Block working set (chunk=128, P=64, N=128):
  x (chunk, P), B/C (chunk, N), L mask (chunk, chunk), state (P, N):
  all f32 ~ 0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hlast_ref, h_ref,
            *, chunk: int, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)              # (chunk, P)
    dt = dt_ref[0, 0].astype(jnp.float32)            # (chunk, 1)
    A = a_ref[0, 0]                                  # scalar (1,1) f32
    Bm = b_ref[0].astype(jnp.float32)                # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)                # (chunk, N)

    dA = dt * A                                      # (chunk, 1) <= 0
    cum = jnp.cumsum(dA, axis=0)                     # (chunk, 1)

    # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) dt_s x_s
    diff = cum - cum.T                               # (chunk, chunk)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * Lmat
    y_intra = jnp.dot(scores, dt * x,
                      preferred_element_type=jnp.float32)   # (chunk, P)

    # inter-chunk: y[t] += exp(cum_t) C_t . h_prev
    h_prev = h_ref[...]                              # (P, N)
    y_inter = jnp.exp(cum) * jnp.dot(Cm, h_prev.T,
                                     preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(total) h_prev + sum_s exp(cum_end - cum_s) dt_s x_s B_s^T
    total = cum[-1:, :]                              # (1, 1)
    decay = jnp.exp(total - cum)                     # (chunk, 1)
    h_new = h_prev * jnp.exp(total) + jnp.dot(
        (decay * dt * x).T, Bm, preferred_element_type=jnp.float32)
    h_ref[...] = h_new

    @pl.when(c_idx == n_chunks - 1)
    def _done():
        hlast_ref[0, 0] = h_new.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bmat: jnp.ndarray, Cmat: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False):
    """SSD over a sequence (zero initial state).

    x: (B, S, nh, P); dt: (B, S, nh); A: (nh,) <= 0; Bmat/Cmat: (B, S, N).
    Returns (y (B, S, nh, P), h_final (B, nh, P, N)).
    """
    Bsz, S, nh, P = x.shape
    N = Bmat.shape[-1]
    ck = min(chunk, S)
    assert S % ck == 0, (S, ck)
    n_chunks = S // ck

    xt = x.transpose(0, 2, 1, 3)                     # (B, nh, S, P)
    dtt = dt.transpose(0, 2, 1)[..., None]           # (B, nh, S, 1)
    a2 = jnp.broadcast_to(A[None, :, None, None].astype(jnp.float32),
                          (Bsz, nh, 1, 1))
    grid = (Bsz, nh, n_chunks)
    y, h_fin = pl.pallas_call(
        functools.partial(_kernel, chunk=ck, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, ck, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, ck, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, ck, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ck, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nh, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a2, Bmat, Cmat)
    return y.transpose(0, 2, 1, 3), h_fin
