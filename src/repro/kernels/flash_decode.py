"""Pallas TPU kernel: decode/verify attention (T >= 1 query positions).

One kernel serves both ordinary decode (T = 1) and the speculative
multi-token verify pass (T = gamma + 1 draft positions scored against
the KV cache with causal masking among the drafts).

Grid (B, h_kv, S/bs): each program handles one (batch, kv-head) pair and
one KV chunk; the q tile flattens (draft position, GQA rep) into
T*n_rep rows. Online softmax keeps running (m, l, acc) in VMEM scratch
across the sequential KV-chunk axis; ``kv_len`` arrives via scalar
prefetch so chunk masking (and the optional sliding window) uses real
lengths.

Block working set (bs=512, T=8, n_rep=8, D=128):
  k/v tiles 2 * 512*128*2  = 256 KiB
  q tile    64*128*2       = 16 KiB
  acc       64*128*4       = 32 KiB
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _verify_kernel(kv_len_ref, q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref,
                   l_ref, *, block_s: int, window: Optional[int],
                   n_chunks: int, n_draft: int, n_rep: int):
    """Multi-query verify: rows of the q tile flatten (draft t, GQA rep).

    Query t's absolute position is ``kv_len - n_draft + t`` (``kv_len``
    includes the draft block), giving causal masking among the draft
    tokens: row (t, rep) sees cache positions <= kv_len - n_draft + t.
    """
    b = pl.program_id(0)
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    rows = n_draft * n_rep
    q = q_ref[0, 0]                                  # (rows, D)
    k = k_ref[0, 0]                                  # (bs, D)
    v = v_ref[0, 0]
    kv_len = kv_len_ref[b]

    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.dot(q.astype(jnp.float32) * scale, k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)  # (rows, bs)

    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32,
                                                     (1, block_s), 1)
    t_row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // n_rep
    qpos = kv_len - n_draft + t_row                  # (rows, 1)
    mask = pos <= qpos                               # (rows, bs)
    if window is not None:
        mask &= pos > (qpos - window)
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_ref[...]                              # (rows, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_chunks - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)
                         ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_s",
                                             "interpret"))
def flash_verify(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 kv_len: jnp.ndarray, *, window: Optional[int] = None,
                 block_s: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, T, H, D); k/v: (B, S, h_kv, D); kv_len: (B,) -> (B, T, H, D).

    Scores T draft positions against the KV cache in one pass. ``kv_len``
    counts valid cache entries *including* the T draft tokens (which the
    caller has already written at positions kv_len-T .. kv_len-1), so the
    T = 1 case is ordinary decode attention.
    """
    B, T, H, D = q.shape
    S, h_kv = k.shape[1], k.shape[2]
    n_rep = H // h_kv
    rows = T * n_rep
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_chunks = S // bs
    # (B, h_kv, T*n_rep, D) with row = t * n_rep + rep
    qg = q.reshape(B, T, h_kv, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, h_kv, rows, D)
    kt = k.transpose(0, 2, 1, 3)                     # (B, h_kv, S, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, h_kv, n_chunks)
    out = pl.pallas_call(
        functools.partial(_verify_kernel, block_s=bs, window=window,
                          n_chunks=n_chunks, n_draft=T, n_rep=n_rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, s, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, D), lambda b, h, s, *_: (b, h, s, 0)),
                pl.BlockSpec((1, 1, bs, D), lambda b, h, s, *_: (b, h, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, s, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, D), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, h_kv, rows, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, h_kv, T, n_rep, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, T, H, D)


@functools.partial(jax.jit, static_argnames=("window", "block_s",
                                             "interpret"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 kv_len: jnp.ndarray, *, window: Optional[int] = None,
                 block_s: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k/v: (B, S, h_kv, D); kv_len: (B,) -> out (B, H, D).

    The T = 1 slice of ``flash_verify``: with one draft position the
    causal mask reduces to ``pos < kv_len`` and the q tile is the plain
    GQA group, so a single kernel serves both paths.
    """
    return flash_verify(q[:, None], k, v, kv_len, window=window,
                        block_s=block_s, interpret=interpret)[:, 0]
