"""Pallas TPU kernel: W4A16 grouped-quantized matmul, dequant-in-kernel.

The paper's hot loop is Q4K matvec/matmul on CPU/CUDA; the TPU-native
adaptation streams int4-packed weights HBM->VMEM (half the bytes of bf16,
which matters because decode is weight-bandwidth-bound) and dequantizes
tile-by-tile in VMEM right before feeding the MXU.

Layout: x (M, K) activations; packed (K/2, N) int8 (two int4 per byte along
the contraction axis); scale (K/group, N). Block sizes keep every tile
MXU-aligned (multiples of 128 on the matmul dims) and the working set
within VMEM:

  x tile (bm, bk) bf16            : bm*bk*2
  packed tile (bk/2, bn) int8     : bk*bn/2
  scale tile (bk/g, bn)           : small
  out tile (bm, bn) f32 (+acc)    : bm*bn*4

Default (256, 512, 256): 256*512*2 + 512*256/2 + 256*256*4 ~ 0.6 MiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(x_ref, packed_ref, scale_ref, out_ref, *, group: int,
            n_k_blocks: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                                   # (bm, bk)
    packed = packed_ref[...]                         # (bk/2, bn)
    scale = scale_ref[...]                           # (bk/g, bn)

    # unpack two int4 per byte (sign-extended)
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    kh, bn = packed.shape
    w_q = jnp.stack([lo, hi], axis=1).reshape(kh * 2, bn)   # (bk, bn)

    # broadcast per-group scales to per-row
    g_rows = scale.shape[0]
    scale_full = jnp.broadcast_to(scale[:, None, :], (g_rows, group, bn)
                                  ).reshape(g_rows * group, bn)
    w = w_q.astype(jnp.float32) * scale_full.astype(jnp.float32)

    out_ref[...] += jnp.dot(x.astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "block_m", "block_n",
                                             "block_k", "interpret"))
def q4_matmul(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray, *,
              group: int = 64, block_m: int = 256, block_n: int = 512,
              block_k: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (M, K); packed: (K/2, N) int8; scale: (K/group, N). -> (M, N) f32."""
    M, K = x.shape
    N = packed.shape[1]
    assert packed.shape[0] * 2 == K
    assert scale.shape == (K // group, N), (scale.shape, K, group, N)
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    assert K % bk == 0 and bk % group == 0
    assert M % bm == 0 and N % bn == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, group=group, n_k_blocks=K // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, packed, scale)
