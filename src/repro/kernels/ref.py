"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.layers import (decode_attention, paged_verify_attention,
                             ssd_chunked, verify_attention)
from ..quant.grouped import QuantizedTensor, dequantize_q4


def q4_matmul_ref(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                  *, group: int = 64) -> jnp.ndarray:
    """Dequantize-then-matmul oracle."""
    K = packed.shape[0] * 2
    N = packed.shape[1]
    qt = QuantizedTensor(packed=packed, scale=scale, bits=4, group=group,
                         shape=(K, N))
    w = dequantize_q4(qt, jnp.float32)
    return x.astype(jnp.float32) @ w


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, H, D) -> (B, H, D) via the model-layer decode attention."""
    out = decode_attention(q[:, None], k, v, kv_len, window=window)
    return out[:, 0]


def flash_verify_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, T, H, D) -> (B, T, H, D) via the model-layer verify attention
    (causal among the T draft positions; kv_len includes the draft block)."""
    return verify_attention(q, k, v, kv_len, window=window)


def paged_verify_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                     v_pages: jnp.ndarray, table: jnp.ndarray,
                     kv_len: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, T, H, D); pages (P, bs, h_kv, D); table (B, nb) ->
    (B, T, H, D) via the model-layer paged attention (gather through the
    block table, then verify attention; kv_len includes the T tokens)."""
    return paged_verify_attention(q, k_pages, v_pages, table, kv_len,
                                  window=window)


def paged_decode_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                     v_pages: jnp.ndarray, table: jnp.ndarray,
                     kv_len: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, H, D) -> (B, H, D): the T = 1 slice of ``paged_verify_ref``."""
    return paged_verify_ref(q[:, None], k_pages, v_pages, table, kv_len,
                            window=window)[:, 0]


def paged_prefill_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                      v_pages: jnp.ndarray, table: jnp.ndarray,
                      kv_len: jnp.ndarray, *,
                      window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, S, H, D) — one prompt chunk whose KV the caller already
    wrote through the table (kv_len includes it). Chunk-vs-pages causal
    attention is the verify geometry with T = S, so the oracle is the
    same model-layer paged attention."""
    return paged_verify_attention(q, k_pages, v_pages, table, kv_len,
                                  window=window)


def _dequant_pages(pages: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """(P, bs, h_kv, D) int8 + (P, bs, h_kv) scales -> f32 pages."""
    return pages.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def paged_verify_quant_ref(q, k_pages, v_pages, k_scale, v_scale, table,
                           kv_len, *, window: Optional[int] = None):
    """Dequant-then-attend oracle for the fused int8-KV paged kernel:
    inflate the quantized pages to f32 (exactly what the kernel fuses
    away), then run the standard paged verify attention."""
    return paged_verify_attention(q, _dequant_pages(k_pages, k_scale),
                                  _dequant_pages(v_pages, v_scale),
                                  table, kv_len, window=window)


def paged_decode_quant_ref(q, k_pages, v_pages, k_scale, v_scale, table,
                           kv_len, *, window: Optional[int] = None):
    """q: (B, H, D) -> (B, H, D): T = 1 slice of the int8 oracle."""
    return paged_verify_quant_ref(q[:, None], k_pages, v_pages, k_scale,
                                  v_scale, table, kv_len,
                                  window=window)[:, 0]


def ssd_scan_ref(x, dt, A, Bmat, Cmat, *, chunk: int = 128):
    """SSD oracle: the model-layer chunked scan (itself validated against a
    sequential recurrence in tests)."""
    return ssd_chunked(x, dt, A, Bmat, Cmat, chunk=chunk)


def ssd_sequential_ref(x, dt, A, Bmat, Cmat):
    """O(S) sequential recurrence — ground truth for both SSD paths."""
    Bsz, S, nh, P = x.shape
    N = Bmat.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,nh,P),(B,nh),(B,N),(B,N)
        dA = jnp.exp(dtt * A[None, :])              # (B, nh)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bmat.transpose(1, 0, 2).astype(jnp.float32),
          Cmat.transpose(1, 0, 2).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_fin.astype(x.dtype)
