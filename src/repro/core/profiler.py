"""Device profiler — measures the quantities the Halda latency model
consumes (paper Appendix A.3's "device profiler" component).

On a home device this measures the actual machine; on a TPU stage it
measures the chip. All measurements are medians of repeated runs with
warmup, so a profile is stable enough to feed the scheduler
(the paper's limitation (d): latency varies with co-located load — the
profiler can simply be re-run and the schedule re-solved, which is the
elastic path).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from .profiles import GiB, OS, QUANTS, DeviceProfile


def _median_time(fn, *, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    out.sort()
    return out[len(out) // 2]


def measure_flops(n: int = 1024, dtype="float32") -> float:
    """Matmul FLOP/s of the local jax backend."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), dtype)
    b = jnp.ones((n, n), dtype)
    f = jax.jit(lambda x, y: x @ y)
    jax.block_until_ready(f(a, b))
    dt = _median_time(lambda: jax.block_until_ready(f(a, b)))
    return 2.0 * n ** 3 / dt


def measure_membw(nbytes: int = 1 << 26) -> float:
    """Bytes/s for a streaming read+write (copy) on the local backend."""
    import jax
    import jax.numpy as jnp

    x = jnp.ones((nbytes // 4,), jnp.float32)
    f = jax.jit(lambda v: v * 1.0000001)
    jax.block_until_ready(f(x))
    dt = _median_time(lambda: jax.block_until_ready(f(x)))
    return 2.0 * nbytes / dt


def measure_kv_copy(kv_bytes: int = 4096) -> float:
    """Seconds to append one token's KV line into a cache buffer."""
    import jax
    import jax.numpy as jnp

    cache = jnp.zeros((1024, kv_bytes // 2), jnp.bfloat16)
    line = jnp.ones((1, kv_bytes // 2), jnp.bfloat16)

    f = jax.jit(lambda c, t, i: jax.lax.dynamic_update_slice(c, t, (i, 0)))
    jax.block_until_ready(f(cache, line, 3))
    return _median_time(lambda: jax.block_until_ready(f(cache, line, 3)))


def measure_disk(nbytes: int = 64 << 20, path: Optional[str] = None
                 ) -> float:
    """Sequential read bytes/s through the filesystem (page cache dropped
    is not possible unprivileged — this measures the warm path, an upper
    bound; the scheduler cares about relative ordering)."""
    fd, tmp = tempfile.mkstemp(dir=path)
    try:
        blob = np.random.default_rng(0).bytes(nbytes)
        with os.fdopen(fd, "wb") as f:
            f.write(blob)

        def read():
            with open(tmp, "rb") as f:
                while f.read(8 << 20):
                    pass

        dt = _median_time(read, warmup=1, iters=3)
        return nbytes / dt
    finally:
        os.unlink(tmp)


def measure_disk_random(nbytes: int = 32 << 20, block: int = 1 << 20,
                        path: Optional[str] = None, seed: int = 0) -> float:
    """Random-offset read bytes/s (the macOS-style mmap reload pattern,
    ``DeviceProfile.disk_rand_bps``). Reads ``block``-sized chunks at
    shuffled offsets of a fresh file."""
    fd, tmp = tempfile.mkstemp(dir=path)
    try:
        blob = np.random.default_rng(seed).bytes(nbytes)
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        offsets = np.arange(0, nbytes, block)
        np.random.default_rng(seed + 1).shuffle(offsets)

        def read():
            with open(tmp, "rb") as f:
                for off in offsets:
                    f.seek(int(off))
                    f.read(block)

        dt = _median_time(read, warmup=1, iters=3)
        return nbytes / dt
    finally:
        os.unlink(tmp)


def measure_stream_read(layer_nbytes: int = 8 << 20, n_layers: int = 4,
                        path: Optional[str] = None) -> float:
    """Bytes/s of the weight-streaming access pattern itself: per-layer
    flat files read end to end through mmap with a private staging copy —
    exactly what ``runtime.streaming.LayerPrefetcher`` does per layer.
    This is the probe the streaming disk terms in ``core.latency`` should
    be fed from (``measure_disk`` reads one big file; the layer-sharded
    store pays per-file open/fault overhead too)."""
    import mmap as _mmap

    d = tempfile.mkdtemp(dir=path)
    files = []
    try:
        blob = np.random.default_rng(0).bytes(layer_nbytes)
        for i in range(n_layers):
            p = os.path.join(d, f"layer_{i:05d}.bin")
            with open(p, "wb") as f:
                f.write(blob)
            files.append(p)

        def read():
            for p in files:
                with open(p, "rb") as f:
                    mm = _mmap.mmap(f.fileno(), 0,
                                    access=_mmap.ACCESS_READ)
                    np.array(np.frombuffer(mm, dtype=np.uint8), copy=True)
                    mm.close()

        dt = _median_time(read, warmup=1, iters=3)
        return n_layers * layer_nbytes / dt
    finally:
        for p in files:
            os.unlink(p)
        os.rmdir(d)


def profile_local_device(name: str = "local", *, quick: bool = True
                         ) -> DeviceProfile:
    """Build a DeviceProfile of this machine for the Halda scheduler."""
    import psutil  # optional
    ram_avail = 8 * GiB
    try:
        ram_avail = float(psutil.virtual_memory().available)
    except Exception:
        pass
    flops = measure_flops(512 if quick else 2048)
    membw = measure_membw(1 << 24 if quick else 1 << 28)
    kv = measure_kv_copy()
    # disk_seq_bps feeds the Linux mmap-reload term of the latency model,
    # so probe the streaming access pattern itself (per-layer files
    # through mmap + staging copy), bounded above by the raw read path
    seq = min(measure_disk(8 << 20 if quick else 256 << 20),
              measure_stream_read(1 << 20 if quick else 16 << 20,
                                  n_layers=4))
    rand = measure_disk_random(4 << 20 if quick else 64 << 20)
    return DeviceProfile(
        name=name, os=OS.LINUX, ram_avail=ram_avail,
        cpu_flops={q: flops for q in QUANTS},
        cpu_membw=membw, t_kv_copy_cpu=kv,
        disk_seq_bps=seq, disk_rand_bps=rand,
        t_comm=1e-4)


def profile_local_device_noopt(name: str = "local") -> DeviceProfile:
    """psutil-free variant (used by tests)."""
    flops = measure_flops(512)
    membw = measure_membw(1 << 24)
    kv = measure_kv_copy()
    seq = min(measure_disk(8 << 20), measure_stream_read(1 << 20))
    rand = measure_disk_random(4 << 20)
    return DeviceProfile(
        name=name, os=OS.LINUX, ram_avail=8 * GiB,
        cpu_flops={q: flops for q in QUANTS},
        cpu_membw=membw, t_kv_copy_cpu=kv,
        disk_seq_bps=seq, disk_rand_bps=rand,
        t_comm=1e-4)
