"""Token-latency model (paper Appendix A.3, eqs. 11-21) and case logic.

Everything here is pure analytic modelling; no JAX. These functions are
shared by the Halda scheduler (which linearizes them into ILP coefficients)
and by the benchmarks (which evaluate candidate assignments).

Conventions (decode, single request, steady state):
  w[m] : layer window size on device m          (decision)
  n[m] : GPU layers inside the window on m      (decision)
  k    : rounds per token, k = L / sum(w)
  l_m  = k * w[m]   total layers on device m    (Assumption 1, R = 0)
  l_m^gpu = k * n[m]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .profiles import Case, DeviceProfile, ModelProfile, OS

#: Disk speed below which overloading a device is never worthwhile (paper's
#: s^disk_threshold). Tuned to the Table-2 cluster: the Mac Air's 0.39 GB/s
#: disk lands below, the phones' UFS above.
DISK_SPEED_THRESHOLD = 0.30e9


def _sum_q(flops: Dict[str, float], speed: Dict[str, float]) -> float:
    """sum_q f^q / s^q over quant formats present in the model file."""
    total = 0.0
    for q, f in flops.items():
        s = speed.get(q)
        if s is None or s <= 0.0:
            s = max(speed.values()) if speed else 1e9
        total += f / s
    return total


@dataclasses.dataclass(frozen=True)
class DeviceCoeffs:
    """Per-device linearized latency coefficients (paper A.3)."""

    alpha: float   # per-CPU-layer latency  (compute + kv copy + mem load)
    beta: float    # delta per layer moved to GPU (usually negative)
    xi: float      # per-window overhead (PCIe copies + ring hop)


# ---------------------------------------------------------------------------
# Memoized per-cluster coefficient table (numpy vectorization)
# ---------------------------------------------------------------------------
#
# ``token_latency``/``ttft`` sit inside Halda's k-enumeration fixed point
# (and its 2^M case enumeration), so the per-device Python loops are a
# measured hot spot of ``benchmarks/halda_scaling.py``. All per-device
# quantities are static for a (devices, model) pair; we extract them ONCE
# into (M,)-shaped numpy arrays keyed by a value signature (profiles are
# frozen dataclasses) and evaluate the latency model as pure array math.
#
# The compute/KV terms are additionally split from the weight-streaming
# terms so the same table prices *multi-token* verify passes (speculative
# decoding): FLOPs, KV copies and KV memory reads scale with the tokens
# per pass, while weight streaming (RAM and disk) is paid once — the
# amortization that makes batched verification win on these clusters.

def _sig_dev(d: DeviceProfile) -> tuple:
    return (d.name, d.os, d.ram_avail, d.vram_avail, d.swap_avail,
            d.bytes_can_swap, d.has_metal, d.has_cuda, d.uma,
            d.cpu_membw, d.gpu_membw, d.t_kv_copy_cpu, d.t_kv_copy_gpu,
            d.t_ram_vram, d.t_vram_ram, d.disk_seq_bps, d.disk_rand_bps,
            d.t_comm, tuple(sorted(d.cpu_flops.items())),
            tuple(sorted(d.gpu_flops.items())))


def _sig_model(m: ModelProfile) -> tuple:
    return (m.name, m.n_layers, m.layer_bytes, m.input_bytes,
            m.output_bytes, m.embed_dim, m.vocab, m.kv_heads, m.head_dim,
            m.n_kv, tuple(sorted(m.flops_layer.items())),
            tuple(sorted(m.flops_output.items())), m.c_cpu, m.c_gpu,
            m.state_bytes)


@dataclasses.dataclass(frozen=True)
class _CoeffTable:
    """Per-device (M,) arrays for the vectorized latency model."""

    # alpha/gpu split: <term>(seq) = seq * <x>_seq + <x>_fix
    cpu_seq: np.ndarray      # per-layer CPU flops + kv copy + kv membw
    cpu_fix: np.ndarray      # per-layer weight membw (streamed once/pass)
    gpu_seq: np.ndarray
    gpu_fix: np.ndarray
    has_gpu: np.ndarray      # bool
    xi: np.ndarray           # per-window overhead
    disk: np.ndarray         # effective reload bytes/s
    swap: np.ndarray         # usable Android swap
    ram: np.ndarray
    vram: np.ndarray
    macos_nometal: np.ndarray    # bool masks for the case logic
    macos_metal: np.ndarray
    slow_disk: np.ndarray
    # classification shortcut: per-device overload case code (M4 for
    # slow-disk devices), memory budget, and the w/n-independent part of
    # the working-set size (head bytes + compute buffers)
    over_case: np.ndarray
    budget: np.ndarray
    need_const: np.ndarray
    count_gpu_resident: np.ndarray   # 1.0 where GPU layers escape RAM (M3)
    # objective shortcut: per-case disk coefficients and kappa terms
    bprime_disk: np.ndarray      # b' / disk
    lb_disk: np.ndarray          # layer_bytes / disk
    kappa_m1: np.ndarray         # (c_cpu - ram) / disk
    kappa_m3: np.ndarray         # (c_cpu - ram - swap) / disk
    xi_sum: float
    # raw per-device rates (ttft's prefill terms)
    cpu_flops_t: np.ndarray      # sum_q flops_layer / cpu_flops
    gpu_flops_t: np.ndarray      # same on GPU (0 where no GPU)
    membw: np.ndarray            # cpu_membw
    # head-device scalars (+ seq-scaling output compute)
    head_out_flops: float
    head_fixed: float        # lm-head membw + embedding-row disk read
    head_out_disk: float     # output_bytes / disk (paid unless head is M4)


_TABLES: Dict[tuple, _CoeffTable] = {}
#: id-based fast path. Entries pin strong references to their profile
#: objects, so a cached id can never be recycled for a different profile.
_TABLES_BY_ID: Dict[tuple, tuple] = {}


def _coeff_table(devices: Sequence[DeviceProfile], model: ModelProfile
                 ) -> _CoeffTable:
    id_key = (tuple(id(d) for d in devices), id(model))
    hit = _TABLES_BY_ID.get(id_key)
    if hit is not None:
        return hit[2]
    key = (tuple(_sig_dev(d) for d in devices), _sig_model(model))
    tab = _TABLES.get(key)
    if tab is not None:
        if len(_TABLES_BY_ID) > 256:
            _TABLES_BY_ID.clear()
        _TABLES_BY_ID[id_key] = (list(devices), model, tab)
        return tab

    kv_bytes = model.kv_bytes_layer
    cpu_seq, cpu_fix, gpu_seq, gpu_fix = [], [], [], []
    has_gpu, xi, disk, swap, ram, vram = [], [], [], [], [], []
    mac_nm, mac_m, slow = [], [], []
    cpu_ft, gpu_ft, membw = [], [], []
    for dev in devices:
        cpu_ft.append(_sum_q(model.flops_layer, dev.cpu_flops))
        membw.append(dev.cpu_membw)
        cpu_seq.append(cpu_ft[-1] + dev.t_kv_copy_cpu
                       + kv_bytes / dev.cpu_membw)
        cpu_fix.append(model.layer_bytes / dev.cpu_membw)
        if dev.has_gpu and dev.gpu_flops:
            gbw = max(dev.gpu_membw, 1.0)
            gpu_ft.append(_sum_q(model.flops_layer, dev.gpu_flops))
            gpu_seq.append(gpu_ft[-1] + dev.t_kv_copy_gpu + kv_bytes / gbw)
            gpu_fix.append(model.layer_bytes / gbw)
            has_gpu.append(True)
        else:
            gpu_ft.append(0.0)
            gpu_seq.append(0.0)
            gpu_fix.append(0.0)
            has_gpu.append(False)
        xi.append((dev.t_ram_vram + dev.t_vram_ram)
                  * (0.0 if dev.uma else 1.0) + dev.t_comm)
        disk.append(dev.disk_speed())
        swap.append(min(dev.bytes_can_swap, dev.swap_avail)
                    if dev.os == OS.ANDROID else 0.0)
        ram.append(dev.ram_avail)
        vram.append(dev.vram_avail)
        mac_nm.append(dev.os == OS.MACOS and not dev.has_metal)
        mac_m.append(dev.os == OS.MACOS and dev.has_metal)
        slow.append(dev.disk_speed() < DISK_SPEED_THRESHOLD)

    head = devices[0]
    disk_a = np.asarray(disk)
    ram_a = np.asarray(ram)
    vram_a = np.asarray(vram)
    swap_a = np.asarray(swap)
    mac_nm_a = np.asarray(mac_nm)
    mac_m_a = np.asarray(mac_m)
    macos = mac_nm_a | mac_m_a
    over_case = np.where(mac_nm_a, int(Case.M1),
                         np.where(mac_m_a, int(Case.M2), int(Case.M3)))
    over_case = np.where(np.asarray(slow), int(Case.M4), over_case)
    budget = np.where(mac_nm_a, ram_a,
                      np.where(mac_m_a, vram_a, ram_a + swap_a))
    need_const = np.full(len(devices), model.c_cpu)
    need_const[0] += model.head_extra_bytes()
    need_const += np.where(mac_m_a, model.c_gpu, 0.0)
    tab = _CoeffTable(
        cpu_seq=np.asarray(cpu_seq), cpu_fix=np.asarray(cpu_fix),
        gpu_seq=np.asarray(gpu_seq), gpu_fix=np.asarray(gpu_fix),
        has_gpu=np.asarray(has_gpu), xi=np.asarray(xi),
        disk=disk_a, swap=swap_a, ram=ram_a, vram=vram_a,
        macos_nometal=mac_nm_a, macos_metal=mac_m_a,
        slow_disk=np.asarray(slow),
        over_case=over_case.astype(int), budget=budget,
        need_const=need_const,
        count_gpu_resident=np.where(macos, 0.0, 1.0),
        bprime_disk=model.b_prime / disk_a,
        lb_disk=model.layer_bytes / disk_a,
        kappa_m1=(model.c_cpu - ram_a) / disk_a,
        kappa_m3=(model.c_cpu - ram_a - swap_a) / disk_a,
        xi_sum=float(np.sum(xi)),
        cpu_flops_t=np.asarray(cpu_ft), gpu_flops_t=np.asarray(gpu_ft),
        membw=np.asarray(membw),
        head_out_flops=_sum_q(model.flops_output, head.cpu_flops),
        head_fixed=(model.head_extra_bytes() / head.cpu_membw
                    + (model.input_bytes / model.vocab)
                    / head.disk_speed()),
        head_out_disk=model.output_bytes / head.disk_speed(),
    )
    if len(_TABLES) > 64:        # bound the memo (benchmark sweeps)
        _TABLES.clear()
        _TABLES_BY_ID.clear()
    _TABLES[key] = tab
    _TABLES_BY_ID[id_key] = (list(devices), model, tab)
    return tab


def classify_cases(devices: Sequence[DeviceProfile], model: ModelProfile,
                   w: Sequence[int], n: Sequence[int], k: int,
                   forced_m4: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Vectorized ``classify_device`` over the cluster: (M,) int codes.

    Every case compares the device's would-be working set against its
    memory budget; only which layers count (all vs CPU-streamed) and the
    budget (RAM / Metal pool / RAM+swap) differ per OS — both precomputed
    in the coefficient table, so this is a handful of array ops.
    """
    tab = _coeff_table(devices, model)
    kvb = model.kv_bytes_per_token_layer * model.n_kv + model.state_bytes
    eff_l = k * (np.asarray(w, dtype=float)
                 - tab.count_gpu_resident * np.asarray(n, dtype=float))
    need = eff_l * (model.layer_bytes + kvb) + tab.need_const
    cases = np.where(need > tab.budget, tab.over_case, int(Case.M4))
    if forced_m4 is not None:
        cases = np.where(np.asarray(forced_m4, dtype=bool), int(Case.M4),
                         cases)
    return cases


def device_coeffs(dev: DeviceProfile, model: ModelProfile) -> DeviceCoeffs:
    b_prime = model.b_prime
    alpha = (_sum_q(model.flops_layer, dev.cpu_flops)
             + dev.t_kv_copy_cpu
             + b_prime / dev.cpu_membw)
    if dev.has_gpu and dev.gpu_flops:
        gpu_term = (_sum_q(model.flops_layer, dev.gpu_flops)
                    + dev.t_kv_copy_gpu
                    + b_prime / max(dev.gpu_membw, 1.0))
        beta = gpu_term - alpha
    else:
        beta = 0.0
    xi = (dev.t_ram_vram + dev.t_vram_ram) * (0.0 if dev.uma else 1.0) \
        + dev.t_comm
    return DeviceCoeffs(alpha=alpha, beta=beta, xi=xi)


# ---------------------------------------------------------------------------
# Case assignment (Section 3.2 Cases 1-4)
# ---------------------------------------------------------------------------

def b_cio(dev_index: int, model: ModelProfile) -> float:
    """(b_i/V + b_o) * I[m==head] + c^cpu   (eq. 34)."""
    extra = model.head_extra_bytes() if dev_index == 0 else 0.0
    return extra + model.c_cpu


def classify_device(dev: DeviceProfile, dev_index: int, model: ModelProfile,
                    w_m: int, n_m: int, k: int,
                    forced_m4: bool = False) -> Case:
    """Assign device to M1..M4 given the current decision variables."""
    if forced_m4:
        return Case.M4
    if dev.disk_speed() < DISK_SPEED_THRESHOLD:
        return Case.M4
    l_m = k * w_m
    l_gpu = k * n_m
    kvb = model.kv_bytes_per_token_layer * model.n_kv + model.state_bytes
    head = model.head_extra_bytes() if dev_index == 0 else 0.0
    if dev.os == OS.MACOS and not dev.has_metal:
        need = l_m * model.layer_bytes + head + kvb * l_m + model.c_cpu
        return Case.M1 if need > dev.ram_avail else Case.M4
    if dev.os == OS.MACOS and dev.has_metal:
        need = (l_m * model.layer_bytes + head + kvb * l_m
                + model.c_cpu + model.c_gpu)
        return Case.M2 if need > dev.vram_avail else Case.M4
    # Linux / Android / TPU stage: only the CPU-side (streamed) layers can
    # overload RAM; CUDA/HBM-resident layers are pinned by the driver.
    swap = 0.0
    if dev.os == OS.ANDROID:
        swap = min(dev.bytes_can_swap, dev.swap_avail)
    need = (l_m - l_gpu) * (model.layer_bytes + kvb) + head + model.c_cpu
    return Case.M3 if need > dev.ram_avail + swap else Case.M4


# ---------------------------------------------------------------------------
# Objective coefficient vectors a, b, c and constant kappa (Definition 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ObjectiveData:
    """Vectorized LDA coefficients for a fixed case assignment."""

    a: List[float]          # coefficient of w_m
    b: List[float]          # coefficient of n_m
    c: List[float]          # constant per device (xi)
    kappa: float            # global constant
    cases: List[Case]
    # memory bounds, already divided by (L * b'): constraint (4)-(5) use
    # z * W with W = sum(w).
    z_ram: List[float]      # per-device RAM bound (sign per case)
    z_gpu: List[float]      # per-device VRAM bound


def build_objective(devices: Sequence[DeviceProfile], model: ModelProfile,
                    cases: Sequence[Case]) -> ObjectiveData:
    L = model.n_layers
    b_prime = model.b_prime
    a: List[float] = []
    b: List[float] = []
    c: List[float] = []
    z_ram: List[float] = []
    z_gpu: List[float] = []
    kappa = 0.0

    # Head-device constants (output layer runs on CPU of device 1).
    head = devices[0]
    kappa += _sum_q(model.flops_output, head.cpu_flops)
    kappa += model.head_extra_bytes() / head.cpu_membw
    kappa += (model.input_bytes / model.vocab) / head.disk_speed()
    if cases[0] != Case.M4:
        kappa += model.output_bytes / head.disk_speed()

    for i, (dev, case) in enumerate(zip(devices, cases)):
        co = device_coeffs(dev, model)
        sdisk = dev.disk_speed()
        if case == Case.M1:
            a.append(co.alpha + b_prime / sdisk)
            b.append(0.0)
            kappa += (model.c_cpu - dev.ram_avail) / sdisk
        elif case == Case.M2:
            a.append(co.alpha + model.layer_bytes / sdisk)
            b.append(co.beta)
        elif case == Case.M3:
            swap = (min(dev.bytes_can_swap, dev.swap_avail)
                    if dev.os == OS.ANDROID else 0.0)
            a.append(co.alpha + b_prime / sdisk)
            b.append(co.beta - b_prime / sdisk)
            kappa += (model.c_cpu - dev.ram_avail - swap) / sdisk
        else:  # M4
            a.append(co.alpha)
            b.append(co.beta)
        c.append(co.xi)

        # RAM bound (constraints 28-33), normalized by (L b').
        bc = b_cio(i, model)
        swap = (min(dev.bytes_can_swap, dev.swap_avail)
                if dev.os == OS.ANDROID else 0.0)
        if case == Case.M2:
            bound = (dev.vram_avail - bc - model.c_gpu) / (L * b_prime)
        elif dev.os == OS.MACOS and dev.has_metal:
            bound = (dev.vram_avail - bc - model.c_gpu) / (L * b_prime)
        else:
            bound = (dev.ram_avail + swap - bc) / (L * b_prime)
        z_ram.append(bound)

        # VRAM bound (constraints 35-36).
        if dev.has_cuda:
            g = (dev.vram_avail - model.c_gpu) / (L * b_prime)
        elif dev.has_metal:
            bo = model.output_bytes if i == 0 else 0.0
            g = (dev.vram_avail - model.c_gpu - bo) / (L * b_prime)
        else:
            g = 0.0
        z_gpu.append(max(g, 0.0))

    return ObjectiveData(a=a, b=b, c=c, kappa=kappa, cases=list(cases),
                         z_ram=z_ram, z_gpu=z_gpu)


def token_latency(devices: Sequence[DeviceProfile], model: ModelProfile,
                  w: Sequence[int], n: Sequence[int],
                  cases: Optional[Sequence[Case]] = None, *,
                  seq: int = 1) -> float:
    """Analytic per-step latency T for an assignment (objective (1)).

    Vectorized over devices (numpy; memoized coefficient table) — this
    sits inside Halda's k-enumeration loop and the 2^M case enumeration.

    ``seq``: tokens scored per pass. 1 is the paper's decode objective;
    seq = gamma + 1 prices a speculative *verify* pass, where FLOPs / KV
    copies / KV reads scale with seq but weight streaming (memory AND
    disk) is paid once per pass — the batched-verify amortization.
    """
    W = sum(w)
    if W == 0:
        return math.inf
    L = model.n_layers
    k = L / W
    tab = _coeff_table(devices, model)
    wv = np.asarray(w, dtype=float)
    nv = np.asarray(n, dtype=float)
    if cases is None:
        codes = classify_cases(devices, model, w, n, max(int(round(k)), 1))
    else:
        codes = np.asarray(cases, dtype=int)

    alpha = seq * tab.cpu_seq + tab.cpu_fix
    beta = tab.has_gpu * (seq * tab.gpu_seq + tab.gpu_fix - alpha)

    m1 = codes == int(Case.M1)
    m2 = codes == int(Case.M2)
    m3 = codes == int(Case.M3)
    a = alpha + (m1 | m3) * tab.bprime_disk + m2 * tab.lb_disk
    b = beta * ~m1 - m3 * tab.bprime_disk
    kappa = float(m1 @ tab.kappa_m1 + m3 @ tab.kappa_m3)

    # head-device constants (output layer on device 1's CPU)
    kappa += seq * tab.head_out_flops + tab.head_fixed
    if codes[0] != int(Case.M4):
        kappa += tab.head_out_disk

    lin = float(a @ wv + b @ nv) + tab.xi_sum
    return L / W * lin + kappa


def expected_tokens_per_cycle(acceptance: float, gamma: int) -> float:
    """E[tokens emitted per draft/verify cycle] at per-draft acceptance
    rate a: sum_{j<g} (j+1) a^j (1-a) + (g+1) a^g = (1 - a^{g+1})/(1 - a).
    """
    if acceptance >= 1.0:
        return gamma + 1.0
    if acceptance <= 0.0:
        return 1.0
    return (1.0 - acceptance ** (gamma + 1)) / (1.0 - acceptance)


@dataclasses.dataclass(frozen=True)
class SpecEstimate:
    """Acceptance-aware speculative throughput estimate."""

    tps: float                   # expected tokens/s
    tpot: float                  # expected seconds/token (1 / tps)
    cycle_latency: float         # draft + verify seconds per cycle
    verify_latency: float        # the multi-token target pass alone
    draft_latency: float         # the gamma+1 draft decodes per cycle
    tokens_per_cycle: float      # E[emitted]
    speedup: float               # vs the vanilla one-token decode loop


def speculative_estimate(devices: Sequence[DeviceProfile],
                         model: ModelProfile, w: Sequence[int],
                         n: Sequence[int], *, gamma: int,
                         acceptance: float,
                         draft_token_latency: float,
                         cases: Optional[Sequence[Case]] = None
                         ) -> SpecEstimate:
    """TPOT/TPS model for speculative decoding on an assignment.

    ``draft_token_latency``: one draft-model decode step (the draft runs
    resident on the head device; gamma + 1 steps per cycle — gamma
    proposals plus the KV-banking step, see ``runtime.speculative``).
    Halda assignments can be compared with and without speculation by
    evaluating this against ``token_latency`` for candidate (w, n).
    """
    t_vanilla = token_latency(devices, model, w, n, cases)
    t_verify = token_latency(devices, model, w, n, cases, seq=gamma + 1)
    t_draft = (gamma + 1) * draft_token_latency
    e = expected_tokens_per_cycle(acceptance, gamma)
    t_cycle = t_verify + t_draft
    tps = e / t_cycle
    return SpecEstimate(tps=tps, tpot=t_cycle / e, cycle_latency=t_cycle,
                        verify_latency=t_verify, draft_latency=t_draft,
                        tokens_per_cycle=e,
                        speedup=tps * t_vanilla)


@dataclasses.dataclass(frozen=True)
class StreamingCheck:
    """Measured prefetch timeline vs the analytic disk term."""

    predicted_layer_s: float     # layer_bytes / disk_speed (model term)
    measured_layer_s: float      # median staged-read time per layer
    measured_bps: float          # aggregate staging throughput
    modeled_bps: float           # the profile's disk_speed()
    ratio: float                 # measured_layer_s / predicted_layer_s

    @property
    def consistent(self) -> bool:
        """Within an order of magnitude — the model is a scheduler input,
        not a cycle-accurate simulator; page cache and file-open overhead
        move absolute numbers while relative ordering survives."""
        return 0.1 <= self.ratio <= 10.0


def streaming_disk_term(dev: DeviceProfile, layer_bytes: float) -> float:
    """Seconds the latency model charges to stream one layer from disk —
    the per-layer unit inside the M1-M3 ``b'/s_disk`` objective terms."""
    return layer_bytes / dev.disk_speed()


def quantized_layer_bytes(layer_bytes: float, *, bits: int = 4,
                          group: int = 64, weight_bytes: float = 2.0,
                          scale_bytes: float = 2.0,
                          quant_fraction: float = 1.0) -> float:
    """Reduced per-layer byte count ``b`` after grouped weight quantization
    — the quantity the disk term prices for a quantized (v2) layer store.

    ``layer_bytes`` is the unquantized store's bytes/layer at
    ``weight_bytes`` per weight (2.0 = bf16); the quantized fraction of it
    shrinks to ``bits/8 + scale_bytes/group`` bytes per weight (packed
    values + one bf16 scale per group, matching ``QuantizedTensor.nbytes``
    and the paper's Q4K ~4.5 bits/weight accounting), while the rest
    (norms, biases — ``1 - quant_fraction``) streams at full width. For
    q4/group-64 over bf16 this is ~0.27x, which is why persisting packed
    int4 moves the dominant ``layer_bytes / s_disk`` roofline term ~4x.
    """
    per_weight = bits / 8.0 + scale_bytes / group
    quantized = layer_bytes * quant_fraction * per_weight / weight_bytes
    return quantized + layer_bytes * (1.0 - quant_fraction)


# ---------------------------------------------------------------------------
# Paged KV-cache byte terms (runtime.kvcache)
# ---------------------------------------------------------------------------
#
# The dense cache's footprint is an envelope — batch * max_len — while the
# paged cache's tracks *live* tokens plus one partially-filled page per
# sequence. These terms price both so the scheduler (and the benchmark
# gates) can reason about KV growth and cold-page offload traffic the
# same way the streaming terms price weight movement.

def kv_bytes_per_token(model: ModelProfile) -> float:
    """KV bytes one decoded token adds across the whole stack — the paged
    cache's unit of allocation pressure (page_bytes = this * page_tokens).
    """
    return model.kv_bytes_per_token_layer * model.n_layers


def dense_kv_bytes(model: ModelProfile, batch: int, max_len: int) -> float:
    """Footprint of the dense (L, B, max_len, ...) preallocation."""
    return kv_bytes_per_token(model) * batch * max_len


def paged_kv_highwater(model: ModelProfile, active_tokens: int,
                       batch: int, page_tokens: int) -> float:
    """Upper bound on paged-cache HBM at ``active_tokens`` live tokens:
    every live token is paged, plus at most one partially-filled page per
    sequence (internal fragmentation is bounded by the page size)."""
    pages = -(-active_tokens // max(page_tokens, 1)) + batch
    return pages * kv_bytes_per_token(model) * page_tokens


@dataclasses.dataclass(frozen=True)
class PagedKVEstimate:
    """Analytic view of a paged-KV configuration (benchmark cross-checks)."""

    bytes_per_token: float       # per-token KV growth, whole stack
    page_bytes: float
    highwater_bytes: float       # paged bound at the active token count
    dense_bytes: float           # the batch * max_len envelope
    fetch_s_per_page: float      # host->device cold-page fetch term

    @property
    def savings(self) -> float:
        return self.dense_bytes / max(self.highwater_bytes, 1e-12)


def paged_kv_estimate(model: ModelProfile, *, active_tokens: int,
                      batch: int, max_len: int, page_tokens: int,
                      dev: Optional[DeviceProfile] = None
                      ) -> PagedKVEstimate:
    """Price a paged-KV configuration: per-token growth, high-water bound
    vs the dense envelope, and the cold-page fetch term (host offload
    moves page_bytes over the host memory bus, the analogue of the
    ``layer_bytes / s_disk`` weight-streaming term)."""
    bpt = kv_bytes_per_token(model)
    page_bytes = bpt * page_tokens
    bw = dev.cpu_membw if dev is not None else 10e9
    return PagedKVEstimate(
        bytes_per_token=bpt, page_bytes=page_bytes,
        highwater_bytes=paged_kv_highwater(model, active_tokens, batch,
                                           page_tokens),
        dense_bytes=dense_kv_bytes(model, batch, max_len),
        fetch_s_per_page=page_bytes / max(bw, 1.0))


def kv_offload_crosscheck(page_bytes: float, bw: float,
                          events: Sequence) -> StreamingCheck:
    """Cross-check the cold-page fetch term against the offloader's
    measured staging timeline (``runtime.kvcache.BlockOffloader.events``)
    — same closed loop as ``streaming_crosscheck``, with the host memory
    bus in place of the disk."""
    predicted = page_bytes / max(bw, 1.0)
    measured = median_event_duration(events)
    return StreamingCheck(
        predicted_layer_s=predicted, measured_layer_s=measured,
        measured_bps=aggregate_bps(events), modeled_bps=bw,
        ratio=measured / max(predicted, 1e-12))


@dataclasses.dataclass(frozen=True)
class TierRecallCosts:
    """Modeled seconds to recall one KV page into the device tier from
    each rung of the memory hierarchy — the pricing the tiered memory
    manager's cost-model eviction minimizes (expected recall loss =
    hit frequency x the victim's recall cost), in place of plain LRU.

    The terms are the same profiled quantities Halda's objective prices:
    a host recall moves ``page_bytes`` over the host memory bus
    (``cpu_membw``), a disk recall first reads the page file
    (``disk_speed``) and then still pays the host->device hop. Device is
    zero — the page is already where compute needs it.
    """

    page_bytes: float
    device_s: float = 0.0
    host_s: float = 0.0
    disk_s: float = 0.0

    def cost(self, tier: str) -> float:
        return {"device": self.device_s, "host": self.host_s,
                "disk": self.disk_s}[tier]


def kv_recall_costs(page_bytes: float, *,
                    dev: Optional[DeviceProfile] = None,
                    membw: Optional[float] = None,
                    disk_bps: Optional[float] = None) -> TierRecallCosts:
    """Price per-tier KV page recall from a device profile (or explicit
    bandwidths; defaults are a commodity host bus and SSD)."""
    bw = membw if membw is not None else (
        dev.cpu_membw if dev is not None else 10e9)
    dbps = disk_bps if disk_bps is not None else (
        dev.disk_speed() if dev is not None else 500e6)
    host_s = page_bytes / max(bw, 1.0)
    return TierRecallCosts(
        page_bytes=page_bytes, device_s=0.0, host_s=host_s,
        disk_s=page_bytes / max(dbps, 1.0) + host_s)


def tier_recall_crosscheck(costs: TierRecallCosts, tier: str,
                           events: Sequence) -> StreamingCheck:
    """Cross-check a tier's modeled recall term against the measured
    fetch timeline of that tier (``BlockOffloader.events`` for host
    recalls, the disk store's read events for disk recalls) — the same
    closed loop ``streaming_crosscheck`` runs on the weight path, so a
    recall-cost table that drifts from observed stalls is detectable
    instead of silently mis-evicting."""
    predicted = max(costs.cost(tier), 1e-12)
    measured = median_event_duration(events)
    return StreamingCheck(
        predicted_layer_s=predicted, measured_layer_s=measured,
        measured_bps=aggregate_bps(events),
        modeled_bps=costs.page_bytes / predicted,
        ratio=measured / predicted)


def median_event_duration(events: Sequence) -> float:
    """Median duration of a prefetch timeline (single definition, shared
    with ``runtime.streaming.PrefetchStats``). Zero-byte events (ring
    padding rows) are excluded."""
    durs = sorted(e.duration for e in events if e.nbytes > 0)
    return durs[len(durs) // 2] if durs else 0.0


def aggregate_bps(events: Sequence) -> float:
    """Aggregate staging throughput of a prefetch timeline."""
    nbytes = sum(e.nbytes for e in events)
    span = sum(e.duration for e in events)
    return nbytes / max(span, 1e-12)


def streaming_crosscheck(dev: DeviceProfile, layer_bytes: float,
                         events: Sequence) -> StreamingCheck:
    """Cross-check the analytic disk terms against a measured prefetch
    timeline (``runtime.streaming.PrefetchEvent`` list: each event is one
    background layer read into staging).

    This closes the loop the paper's profiler opens: the same quantity —
    seconds per streamed layer — exists both as a model coefficient
    (``layer_bytes / disk_speed``) and as a measurement (the prefetcher's
    per-layer read durations), so a profile whose disk numbers drift from
    reality is detectable rather than silently mis-scheduling.
    """
    predicted = streaming_disk_term(dev, layer_bytes)
    measured = median_event_duration(events)
    measured_bps = aggregate_bps(events)
    return StreamingCheck(
        predicted_layer_s=predicted, measured_layer_s=measured,
        measured_bps=measured_bps, modeled_bps=dev.disk_speed(),
        ratio=measured / max(predicted, 1e-12))


@dataclasses.dataclass(frozen=True)
class TermDrift:
    """One latency-model term vs its observed per-token counterpart."""

    term: str            # "disk" | "compute" | "comms"
    modeled_s: float     # seconds/token the Halda model charges
    measured_s: float    # seconds/token observed by the tracer

    @property
    def ratio(self) -> float:
        return self.measured_s / max(self.modeled_s, 1e-12)

    @property
    def consistent(self) -> bool:
        """Same order-of-magnitude budget as :class:`StreamingCheck` —
        the model is a scheduler input, not a simulator."""
        return 0.1 <= self.ratio <= 10.0


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Modeled-vs-measured drift across the latency model's terms.

    This is the signal an online Halda re-solve consumes (ROADMAP
    item 4): when a term's observed cost drifts outside its consistency
    band, the profile coefficient it came from no longer describes the
    hardware and the placement deserves a re-plan.
    """

    terms: Tuple[TermDrift, ...]
    tokens: int                    # token steps the measurement averages

    def term(self, name: str) -> Optional[TermDrift]:
        for t in self.terms:
            if t.term == name:
                return t
        return None

    @property
    def drifted(self) -> Tuple[str, ...]:
        return tuple(t.term for t in self.terms if not t.consistent)

    @property
    def consistent(self) -> bool:
        return not self.drifted

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        return {t.term: {"modeled_s": t.modeled_s,
                         "measured_s": t.measured_s,
                         "ratio": t.ratio,
                         "consistent": t.consistent}
                for t in self.terms}

    def report(self) -> str:
        lines = [f"drift report over {self.tokens} token(s):"]
        for t in self.terms:
            flag = "ok" if t.consistent else "DRIFT"
            lines.append(
                f"  {t.term:8s} modeled {t.modeled_s * 1e3:8.3f} ms/tok  "
                f"measured {t.measured_s * 1e3:8.3f} ms/tok  "
                f"ratio {t.ratio:6.2f}  [{flag}]")
        return "\n".join(lines)


def telemetry_crosscheck(dev: DeviceProfile, layer_bytes: float,
                         n_layers: int, *, stalls: Sequence = (),
                         prefetch_events: Sequence = (),
                         model: Optional[ModelProfile] = None,
                         n_hops: int = 0) -> DriftReport:
    """Compare a traced run's per-token splits against the model's terms.

    The unified tracer (``runtime.telemetry``) measures where each
    token's milliseconds actually went; the Halda objective *predicts*
    them from profile coefficients. This closes the loop per term:

      * **disk** — modeled ``n_layers * layer_bytes / disk_speed`` per
        streamed pass vs the prefetch timeline's total read time per
        token (``prefetch_events``; background reads, so overlap does
        not hide them the way exposed ``disk_wait`` would).
      * **compute** — ``device_coeffs(dev, model).alpha * n_layers``
        vs the mean ``compute`` split of the stall records (needs
        ``model``; skipped otherwise).
      * **comms** — ``dev.t_comm * n_hops`` vs the mean ``comms`` split
        (skipped when ``n_hops`` is 0).

    ``stalls`` is a sequence of ``runtime.telemetry.StallRecord``;
    ``prefetch_events`` a ``PrefetchEvent`` timeline. Terms without
    both a model value and a measurement are omitted rather than
    reported as spuriously drifted.
    """
    stalls = list(stalls)
    tokens = max(len(stalls), 1)
    terms: List[TermDrift] = []

    if prefetch_events:
        modeled_disk = n_layers * streaming_disk_term(dev, layer_bytes)
        measured_disk = sum(e.duration for e in prefetch_events
                            if e.nbytes > 0) / tokens
        terms.append(TermDrift("disk", modeled_disk, measured_disk))

    if model is not None and stalls:
        alpha = device_coeffs(dev, model).alpha
        measured_comp = sum(s.compute_s for s in stalls) / tokens
        terms.append(TermDrift("compute", alpha * n_layers,
                               measured_comp))

    if n_hops > 0 and stalls:
        measured_comms = sum(s.comms_s for s in stalls) / tokens
        terms.append(TermDrift("comms", dev.t_comm * n_hops,
                               measured_comms))

    return DriftReport(terms=tuple(terms), tokens=len(stalls))


def ttft(devices: Sequence[DeviceProfile], model: ModelProfile,
         w: Sequence[int], n: Sequence[int], prompt_len: int = 16) -> float:
    """Time-to-first-token: prefill modelled as one pass whose compute and
    KV-write terms scale with the prompt length while weight/disk terms are
    paid once (mmap'd weights are read once for the whole prompt batch).
    Vectorized over devices like ``token_latency``."""
    W = sum(w)
    if W == 0:
        return math.inf
    L = model.n_layers
    tab = _coeff_table(devices, model)
    codes = classify_cases(devices, model, w, n, max(int(round(L / W)), 1))
    wv = np.asarray(w, dtype=float)
    nv = np.asarray(n, dtype=float)
    l_m = L / W * wv
    l_gpu = L / W * nv
    total = float(np.sum(
        (l_m - l_gpu) * tab.cpu_flops_t * prompt_len
        + l_gpu * tab.gpu_flops_t * prompt_len
        + l_m * model.kv_bytes_per_token_layer * prompt_len / tab.membw
        + np.where(codes != int(Case.M4),
                   (l_m - l_gpu) * model.layer_bytes / tab.disk, 0.0)
        + L / W * tab.xi))
    return total + tab.head_out_flops


def chunked_prefill_ttft(devices: Sequence[DeviceProfile],
                         model: ModelProfile, w: Sequence[int],
                         n: Sequence[int], prompt_len: int = 16, *,
                         chunk: int = 0,
                         decode_step_s: Optional[float] = None) -> float:
    """TTFT under chunked paged admission.

    The prompt runs in ``ceil(prompt_len / chunk)`` page-aligned chunks
    computed straight into the block pool; between chunks the engine
    gives the active decode slots one step, so the admitted request's
    first token waits for the whole prompt's compute (same total FLOPs
    and KV writes as one-shot prefill — ``ttft``'s linear terms are
    length-additive) PLUS, per extra chunk, one re-paid per-pass overhead
    (the ``xi`` window term) and one interleaved decode step:

        TTFT_chunked = TTFT(prompt) + (chunks-1) * (L/W * xi + t_step)

    ``decode_step_s`` overrides the modeled decode step with a measured
    one (the serving benchmark feeds its observed p50 TPOT); the
    interleave part, ``(chunks-1) * t_step``, is what the runtime's
    ``decode/interleave_stall_s`` counter measures from the other side —
    ``chunked_prefill_crosscheck`` turns the pair into a drift term.
    """
    base = ttft(devices, model, w, n, prompt_len)
    if chunk <= 0 or chunk >= prompt_len or not math.isfinite(base):
        return base
    chunks = -(-prompt_len // chunk)
    tab = _coeff_table(devices, model)
    L, W = model.n_layers, sum(w)
    step = decode_step_s if decode_step_s is not None \
        else token_latency(devices, model, w, n)
    return base + (chunks - 1) * (L / W * tab.xi_sum + step)


def chunked_prefill_crosscheck(modeled_step_s: float,
                               measured_stall_s: float,
                               chunks: int) -> TermDrift:
    """Drift term for the chunked-admission interleave overhead.

    ``modeled_step_s`` is the decode step the TTFT term charges per extra
    chunk; ``measured_stall_s`` the runtime's total
    ``decode/interleave_stall_s`` for the admit. Both sides are divided
    by the interleave count so the drift ratio compares per-step costs
    (same convention as the per-token terms in ``telemetry_crosscheck``),
    and the result slots into a :class:`DriftReport` alongside them.
    """
    n = max(chunks - 1, 1)
    return TermDrift("interleave", modeled_step_s,
                     measured_stall_s / n)
