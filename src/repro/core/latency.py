"""Token-latency model (paper Appendix A.3, eqs. 11-21) and case logic.

Everything here is pure analytic modelling; no JAX. These functions are
shared by the Halda scheduler (which linearizes them into ILP coefficients)
and by the benchmarks (which evaluate candidate assignments).

Conventions (decode, single request, steady state):
  w[m] : layer window size on device m          (decision)
  n[m] : GPU layers inside the window on m      (decision)
  k    : rounds per token, k = L / sum(w)
  l_m  = k * w[m]   total layers on device m    (Assumption 1, R = 0)
  l_m^gpu = k * n[m]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .profiles import Case, DeviceProfile, ModelProfile, OS

#: Disk speed below which overloading a device is never worthwhile (paper's
#: s^disk_threshold). Tuned to the Table-2 cluster: the Mac Air's 0.39 GB/s
#: disk lands below, the phones' UFS above.
DISK_SPEED_THRESHOLD = 0.30e9


def _sum_q(flops: Dict[str, float], speed: Dict[str, float]) -> float:
    """sum_q f^q / s^q over quant formats present in the model file."""
    total = 0.0
    for q, f in flops.items():
        s = speed.get(q)
        if s is None or s <= 0.0:
            s = max(speed.values()) if speed else 1e9
        total += f / s
    return total


@dataclasses.dataclass(frozen=True)
class DeviceCoeffs:
    """Per-device linearized latency coefficients (paper A.3)."""

    alpha: float   # per-CPU-layer latency  (compute + kv copy + mem load)
    beta: float    # delta per layer moved to GPU (usually negative)
    xi: float      # per-window overhead (PCIe copies + ring hop)


def device_coeffs(dev: DeviceProfile, model: ModelProfile) -> DeviceCoeffs:
    b_prime = model.b_prime
    alpha = (_sum_q(model.flops_layer, dev.cpu_flops)
             + dev.t_kv_copy_cpu
             + b_prime / dev.cpu_membw)
    if dev.has_gpu and dev.gpu_flops:
        gpu_term = (_sum_q(model.flops_layer, dev.gpu_flops)
                    + dev.t_kv_copy_gpu
                    + b_prime / max(dev.gpu_membw, 1.0))
        beta = gpu_term - alpha
    else:
        beta = 0.0
    xi = (dev.t_ram_vram + dev.t_vram_ram) * (0.0 if dev.uma else 1.0) \
        + dev.t_comm
    return DeviceCoeffs(alpha=alpha, beta=beta, xi=xi)


# ---------------------------------------------------------------------------
# Case assignment (Section 3.2 Cases 1-4)
# ---------------------------------------------------------------------------

def b_cio(dev_index: int, model: ModelProfile) -> float:
    """(b_i/V + b_o) * I[m==head] + c^cpu   (eq. 34)."""
    extra = model.head_extra_bytes() if dev_index == 0 else 0.0
    return extra + model.c_cpu


def classify_device(dev: DeviceProfile, dev_index: int, model: ModelProfile,
                    w_m: int, n_m: int, k: int,
                    forced_m4: bool = False) -> Case:
    """Assign device to M1..M4 given the current decision variables."""
    if forced_m4:
        return Case.M4
    if dev.disk_speed() < DISK_SPEED_THRESHOLD:
        return Case.M4
    l_m = k * w_m
    l_gpu = k * n_m
    kvb = model.kv_bytes_per_token_layer * model.n_kv + model.state_bytes
    head = model.head_extra_bytes() if dev_index == 0 else 0.0
    if dev.os == OS.MACOS and not dev.has_metal:
        need = l_m * model.layer_bytes + head + kvb * l_m + model.c_cpu
        return Case.M1 if need > dev.ram_avail else Case.M4
    if dev.os == OS.MACOS and dev.has_metal:
        need = (l_m * model.layer_bytes + head + kvb * l_m
                + model.c_cpu + model.c_gpu)
        return Case.M2 if need > dev.vram_avail else Case.M4
    # Linux / Android / TPU stage: only the CPU-side (streamed) layers can
    # overload RAM; CUDA/HBM-resident layers are pinned by the driver.
    swap = 0.0
    if dev.os == OS.ANDROID:
        swap = min(dev.bytes_can_swap, dev.swap_avail)
    need = (l_m - l_gpu) * (model.layer_bytes + kvb) + head + model.c_cpu
    return Case.M3 if need > dev.ram_avail + swap else Case.M4


# ---------------------------------------------------------------------------
# Objective coefficient vectors a, b, c and constant kappa (Definition 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ObjectiveData:
    """Vectorized LDA coefficients for a fixed case assignment."""

    a: List[float]          # coefficient of w_m
    b: List[float]          # coefficient of n_m
    c: List[float]          # constant per device (xi)
    kappa: float            # global constant
    cases: List[Case]
    # memory bounds, already divided by (L * b'): constraint (4)-(5) use
    # z * W with W = sum(w).
    z_ram: List[float]      # per-device RAM bound (sign per case)
    z_gpu: List[float]      # per-device VRAM bound


def build_objective(devices: Sequence[DeviceProfile], model: ModelProfile,
                    cases: Sequence[Case]) -> ObjectiveData:
    L = model.n_layers
    b_prime = model.b_prime
    a: List[float] = []
    b: List[float] = []
    c: List[float] = []
    z_ram: List[float] = []
    z_gpu: List[float] = []
    kappa = 0.0

    # Head-device constants (output layer runs on CPU of device 1).
    head = devices[0]
    kappa += _sum_q(model.flops_output, head.cpu_flops)
    kappa += model.head_extra_bytes() / head.cpu_membw
    kappa += (model.input_bytes / model.vocab) / head.disk_speed()
    if cases[0] != Case.M4:
        kappa += model.output_bytes / head.disk_speed()

    for i, (dev, case) in enumerate(zip(devices, cases)):
        co = device_coeffs(dev, model)
        sdisk = dev.disk_speed()
        if case == Case.M1:
            a.append(co.alpha + b_prime / sdisk)
            b.append(0.0)
            kappa += (model.c_cpu - dev.ram_avail) / sdisk
        elif case == Case.M2:
            a.append(co.alpha + model.layer_bytes / sdisk)
            b.append(co.beta)
        elif case == Case.M3:
            swap = (min(dev.bytes_can_swap, dev.swap_avail)
                    if dev.os == OS.ANDROID else 0.0)
            a.append(co.alpha + b_prime / sdisk)
            b.append(co.beta - b_prime / sdisk)
            kappa += (model.c_cpu - dev.ram_avail - swap) / sdisk
        else:  # M4
            a.append(co.alpha)
            b.append(co.beta)
        c.append(co.xi)

        # RAM bound (constraints 28-33), normalized by (L b').
        bc = b_cio(i, model)
        swap = (min(dev.bytes_can_swap, dev.swap_avail)
                if dev.os == OS.ANDROID else 0.0)
        if case == Case.M2:
            bound = (dev.vram_avail - bc - model.c_gpu) / (L * b_prime)
        elif dev.os == OS.MACOS and dev.has_metal:
            bound = (dev.vram_avail - bc - model.c_gpu) / (L * b_prime)
        else:
            bound = (dev.ram_avail + swap - bc) / (L * b_prime)
        z_ram.append(bound)

        # VRAM bound (constraints 35-36).
        if dev.has_cuda:
            g = (dev.vram_avail - model.c_gpu) / (L * b_prime)
        elif dev.has_metal:
            bo = model.output_bytes if i == 0 else 0.0
            g = (dev.vram_avail - model.c_gpu - bo) / (L * b_prime)
        else:
            g = 0.0
        z_gpu.append(max(g, 0.0))

    return ObjectiveData(a=a, b=b, c=c, kappa=kappa, cases=list(cases),
                         z_ram=z_ram, z_gpu=z_gpu)


def token_latency(devices: Sequence[DeviceProfile], model: ModelProfile,
                  w: Sequence[int], n: Sequence[int],
                  cases: Optional[Sequence[Case]] = None) -> float:
    """Analytic token latency T for an assignment (objective (1))."""
    W = sum(w)
    if W == 0:
        return math.inf
    L = model.n_layers
    k = L / W
    if cases is None:
        cases = [classify_device(d, i, model, w[i], n[i], max(int(round(k)), 1))
                 for i, d in enumerate(devices)]
    obj = build_objective(devices, model, cases)
    lin = sum(obj.a[i] * w[i] + obj.b[i] * n[i] + obj.c[i]
              for i in range(len(devices)))
    return L / W * lin + obj.kappa


def ttft(devices: Sequence[DeviceProfile], model: ModelProfile,
         w: Sequence[int], n: Sequence[int], prompt_len: int = 16) -> float:
    """Time-to-first-token: prefill modelled as one pass whose compute and
    KV-write terms scale with the prompt length while weight/disk terms are
    paid once (mmap'd weights are read once for the whole prompt batch)."""
    W = sum(w)
    if W == 0:
        return math.inf
    L = model.n_layers
    cases = [classify_device(d, i, model, w[i], n[i],
                             max(int(round(L / W)), 1))
             for i, d in enumerate(devices)]
    total = 0.0
    for i, dev in enumerate(devices):
        co = device_coeffs(dev, model)
        l_m = L / W * w[i]
        l_gpu = L / W * n[i]
        compute_cpu = _sum_q(model.flops_layer, dev.cpu_flops) * prompt_len
        compute_gpu = (_sum_q(model.flops_layer, dev.gpu_flops) * prompt_len
                       if dev.has_gpu and dev.gpu_flops else 0.0)
        total += (l_m - l_gpu) * compute_cpu + l_gpu * compute_gpu
        total += l_m * model.kv_bytes_per_token_layer * prompt_len \
            / dev.cpu_membw
        # weights traverse the memory hierarchy once:
        if cases[i] != Case.M4:
            total += (l_m - l_gpu) * model.layer_bytes / dev.disk_speed()
        total += L / W * co.xi
    head = devices[0]
    total += _sum_q(model.flops_output, head.cpu_flops)
    return total
