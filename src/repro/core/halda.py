"""Halda: Heterogeneity-Aware Layer-to-Device Allocation (paper Alg. 1).

Solves the LDA problem (Definition 1):

    min_{w,n}  L * (a.w + b.n + e.c) / (e.w) + kappa
    s.t.       1 <= w_m <= L,  0 <= n_m <= w_m,  L = k * sum(w),
               per-case RAM bounds, per-device VRAM bounds.

Strategy (Section 3.3):
  * enumerate k over the divisors of L  -> each k yields a standard ILP;
  * iterate the case assignment M1..M4 to a fixed point;
  * calibration: if a GPU is under-used while another device is overloaded,
    force the slowest-disk overloaded device into M4 and re-solve.

The ILP is solved with ``scipy.optimize.milp`` (HiGHS — the solver the paper
itself uses). A pure-python branch-and-bound fallback keeps the module
dependency-light; tests assert both agree on small instances.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .latency import (DISK_SPEED_THRESHOLD, ObjectiveData, build_objective,
                      classify_device, speculative_estimate, token_latency)
from .profiles import OS, Case, DeviceProfile, ModelProfile, divisors

try:  # HiGHS via scipy
    from scipy.optimize import Bounds, LinearConstraint, milp

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover - exercised via force_fallback tests
    _HAVE_SCIPY = False


#: one ILP candidate: (w, n, k, analytic token latency)
Candidate = Tuple[Tuple[int, ...], Tuple[int, ...], int, float]


@dataclasses.dataclass
class HaldaSolution:
    w: List[int]
    n: List[int]
    k: int
    cases: List[Case]
    latency: float
    iterations: int
    relaxed: bool = False           # memory-consistency constraints dropped
    history: List[Tuple[int, float]] = dataclasses.field(default_factory=list)
    # every distinct (w, n, k) assignment the search evaluated — input to
    # the optional speculative post-pass
    candidates: List[Candidate] = dataclasses.field(default_factory=list)
    # filled by solve(..., spec=SpecPostPass(...))
    spec_report: Optional[List[dict]] = None

    @property
    def window_total(self) -> int:
        return sum(self.w)


@dataclasses.dataclass(frozen=True)
class SpecPostPass:
    """Inputs for the optional speculative post-pass on a Halda solve."""

    gamma: int = 4
    acceptance: float = 0.8
    draft_token_latency: float = 5e-3
    top: int = 8                     # candidates reported (by vanilla TPOT)


def speculative_post_pass(devices: Sequence[DeviceProfile],
                          model: ModelProfile, sol: "HaldaSolution",
                          spec: SpecPostPass) -> List[dict]:
    """Report each candidate assignment's TPOT with and without speculation.

    First step on the ROADMAP item of making Halda speculation-aware: the
    ILP still optimizes the vanilla decode objective, but the post-pass
    prices every candidate it visited under the acceptance-aware model
    (``latency.speculative_estimate``) so callers can see when the
    speculative ordering disagrees with the vanilla one — i.e. when a
    slightly slower vanilla assignment amortizes a gamma+1-token verify
    pass better (more streamed layers -> bigger once-per-pass win).
    """
    cands = list(sol.candidates)
    # the final assignment may differ from every ILP candidate (rebalance)
    cands.append((tuple(sol.w), tuple(sol.n), sol.k, sol.latency))
    # dedupe on the assignment, keep the best vanilla latency per key
    best: Dict[Tuple, Candidate] = {}
    for w, n, k, lat in cands:
        key = (w, n, k)
        if key not in best or lat < best[key][3]:
            best[key] = (w, n, k, lat)
    ordered = sorted(best.values(), key=lambda c: c[3])[:spec.top]
    rows = []
    for w, n, k, obj in ordered:
        # re-price vanilla under auto-classification so the two columns
        # are comparable (the solver's objective value is computed under
        # its assumed case assignment, which can differ)
        t_van = token_latency(devices, model, list(w), list(n))
        est = speculative_estimate(
            devices, model, list(w), list(n), gamma=spec.gamma,
            acceptance=spec.acceptance,
            draft_token_latency=spec.draft_token_latency)
        rows.append({
            "w": list(w), "n": list(n), "k": k,
            "objective": obj,
            "tpot_vanilla": t_van,
            "tpot_spec": est.tpot,
            "spec_speedup": est.speedup,
            "tokens_per_cycle": est.tokens_per_cycle,
            "chosen": list(w) == list(sol.w) and list(n) == list(sol.n)
                      and k == sol.k,
        })
    rows.sort(key=lambda r: r["tpot_vanilla"])
    return rows


# ---------------------------------------------------------------------------
# ILP for a fixed k  (eqs. 6-10)
# ---------------------------------------------------------------------------

def _case_rows(devices, model, obj: ObjectiveData, W: int, relax: bool):
    """Linear inequality rows for the per-case memory constraints.

    Returns (A, lb, ub) rows over x = [w_1..w_M, n_1..n_M].

    Besides the paper's overload-consistency bounds, overloaded devices get
    a *window-fit* upper bound: one round's streamed window must fit the
    reclaimable budget, or prefetch self-evicts ("prefetch-release", §3.1
    — "by setting the layer window size small, we ensure the model layers
    stay within memory limits"). The eq.(15) excess-reload cost model is
    only valid under this bound; without it the solver happily picks k=1
    windows that the real system would double-load.
    """
    M = len(devices)
    L = model.n_layers
    rows, lbs, ubs = [], [], []
    for i, (dev, case) in enumerate(zip(devices, obj.cases)):
        zi = obj.z_ram[i]
        cap = math.floor(zi * L + 1e-9)       # layers that fit the budget
        row_w = np.zeros(2 * M)
        row_w[i] = 1.0
        row_wn = np.zeros(2 * M)
        row_wn[i] = 1.0
        row_wn[M + i] = -1.0
        if case in (Case.M1, Case.M2):
            if relax:
                continue
            # overload consistency: w_m > W * z  ->  w_m >= floor(Wz)+1
            lo = math.floor(W * zi + 1e-9) + 1
            rows.append(row_w); lbs.append(lo); ubs.append(np.inf)
            # window fit (whole window streams on these platforms)
            rows.append(row_w.copy()); lbs.append(-np.inf)
            ubs.append(max(cap, 1))
        elif case == Case.M3:
            if relax:
                continue
            lo = math.floor(W * zi + 1e-9) + 1
            rows.append(row_wn); lbs.append(lo); ubs.append(np.inf)
            # window fit for the CPU-streamed part only
            rows.append(row_wn.copy()); lbs.append(-np.inf)
            ubs.append(max(cap, 1))
        else:  # M4: must NOT overload (hard even under relaxation)
            hi = math.floor(W * zi - 1e-9)
            if dev.os.value == "macos":
                rows.append(row_w)
            else:
                rows.append(row_wn)
            lbs.append(-np.inf); ubs.append(max(hi, 0 if dev.has_gpu else 1))
    return rows, lbs, ubs


def solve_ilp_fixed_k(devices: Sequence[DeviceProfile], model: ModelProfile,
                      obj: ObjectiveData, k: int, *, relax: bool = False,
                      force_fallback: bool = False
                      ) -> Optional[Tuple[List[int], List[int], float]]:
    """Solve the ILP (6-10) for one k. Returns (w, n, objective) or None."""
    L = model.n_layers
    if L % k:
        return None
    W = L // k
    M = len(devices)
    if W < M:  # every device needs >= 1 layer per round
        return None

    cost = np.concatenate([k * np.asarray(obj.a), k * np.asarray(obj.b)])

    lo = np.zeros(2 * M)
    hi = np.zeros(2 * M)
    lo[:M] = 1.0
    hi[:M] = W - (M - 1)
    for i, dev in enumerate(devices):
        cap = math.floor(W * obj.z_gpu[i] + 1e-9)
        hi[M + i] = min(cap, W) if dev.has_gpu else 0.0
    if np.any(lo > hi + 1e-9):
        return None

    rows = [np.concatenate([np.ones(M), np.zeros(M)])]   # sum w == W
    lbs, ubs = [W], [W]
    for i in range(M):                                   # n_m <= w_m
        r = np.zeros(2 * M)
        r[M + i] = 1.0
        r[i] = -1.0
        rows.append(r); lbs.append(-np.inf); ubs.append(0.0)
    cr, clb, cub = _case_rows(devices, model, obj, W, relax)
    rows += cr; lbs += clb; ubs += cub

    A = np.vstack(rows)
    if _HAVE_SCIPY and not force_fallback:
        res = milp(c=cost,
                   constraints=LinearConstraint(A, np.asarray(lbs),
                                                np.asarray(ubs)),
                   integrality=np.ones(2 * M),
                   bounds=Bounds(lo, hi))
        if not res.success or res.x is None:
            return None
        x = np.round(res.x).astype(int)
    else:
        x = _fallback_bnb(cost, A, np.asarray(lbs), np.asarray(ubs), lo, hi, M, W)
        if x is None:
            return None
    w = x[:M].tolist()
    n = x[M:].tolist()
    value = float(cost @ x)
    return w, n, value


def _fallback_bnb(cost, A, lbs, ubs, lo, hi, M, W):
    """Tiny exact solver: enumerate w compositions (bounded), greedy n.

    Only used when scipy is absent or in tests; fine for M <= 6 and the
    divisor-limited W values that occur in practice.
    """
    best = None
    best_val = np.inf
    w_ranges = [range(int(lo[i]), int(hi[i]) + 1) for i in range(M)]

    def feasible(x):
        v = A @ x
        return np.all(v >= lbs - 1e-9) and np.all(v <= ubs + 1e-9)

    for w in itertools.product(*w_ranges):
        if sum(w) != W:
            continue
        # choose n greedily per device: cost coef of n is cost[M+i]; n in
        # [0, min(w_i, hi[M+i])]; constraints couple w,n only per device.
        n = [0] * M
        for i in range(M):
            n_max = int(min(w[i], hi[M + i]))
            n[i] = n_max if cost[M + i] < 0 else 0
        x = np.array(list(w) + n, dtype=float)
        if not feasible(x):
            # try the flipped n choice per device (small search)
            ok = False
            for flips in itertools.product([0, 1], repeat=M):
                n2 = [int(min(w[i], hi[M + i])) if f else 0
                      for i, f in enumerate(flips)]
                x = np.array(list(w) + n2, dtype=float)
                if feasible(x):
                    ok = True
                    break
            if not ok:
                continue
        val = float(cost @ x)
        if val < best_val:
            best_val = val
            best = x.astype(int)
    return best


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def _initial_windows(devices: Sequence[DeviceProfile], L: int) -> List[int]:
    """Line 1: windows proportional to memory budgets, summing to L (k=1)."""
    budgets = np.array([d.memory_budget() for d in devices], dtype=float)
    if budgets.sum() <= 0:
        budgets = np.ones(len(devices))
    w = np.maximum(np.floor(budgets / budgets.sum() * L), 1).astype(int)
    # fix rounding so sum == L
    while w.sum() > L:
        w[np.argmax(w)] -= 1
    while w.sum() < L:
        w[np.argmax(budgets - w / max(L, 1))] += 1
    return w.tolist()


def _gpu_underused_and_overload(devices, model, obj, w, n, W) -> bool:
    """Calibration trigger (Alg. 1 line 13)."""
    gpu_free = False
    for i, dev in enumerate(devices):
        if dev.has_gpu:
            cap = math.floor(W * obj.z_gpu[i] + 1e-9)
            if n[i] < min(cap, w[i]):
                gpu_free = True
    overloaded = any(c in (Case.M1, Case.M2, Case.M3) for c in obj.cases)
    return gpu_free and overloaded


def overload_case(dev: DeviceProfile) -> Case:
    """The (single) overload case a device can be in, by OS (Section 3.2)."""
    if dev.os == OS.MACOS and dev.has_metal:
        return Case.M2
    if dev.os == OS.MACOS:
        return Case.M1
    return Case.M3  # Linux / Android / TPU stage


def solve_exact(devices: Sequence[DeviceProfile], model: ModelProfile, *,
                force_fallback: bool = False,
                max_enum_devices: int = 10) -> Optional[HaldaSolution]:
    """Exact LDA: enumerate consistent case assignments × divisors of L.

    Beyond-paper refinement (recorded in DESIGN.md): Algorithm 1's
    fixed-point iteration can stall in a local optimum when every GPU is
    full (the calibration trigger never fires), e.g. leaving a slow-disk
    macOS device overloaded in M2. Each device has only two possible cases
    — its OS-specific overload case or M4 — so for M <= ``max_enum_devices``
    we can enumerate all 2^M consistent assignments; the ILP's own
    consistency rows guarantee the assumed cases hold at the optimum, which
    makes the search exact for the LDA model under Assumption 1.
    """
    M = len(devices)
    if M > max_enum_devices:
        return None
    L = model.n_layers
    ks = [k for k in divisors(L) if L // k >= M]
    if not ks:
        ks = [1]
    choices = []
    for dev in devices:
        if dev.disk_speed() < DISK_SPEED_THRESHOLD:
            choices.append((Case.M4,))
        else:
            choices.append((overload_case(dev), Case.M4))
    best: Optional[HaldaSolution] = None
    history: List[Tuple[int, float]] = []
    cands: List[Candidate] = []
    for cases in itertools.product(*choices):
        obj = build_objective(devices, model, list(cases))
        for k in ks:
            out = solve_ilp_fixed_k(devices, model, obj, k,
                                    force_fallback=force_fallback)
            if out is None:
                continue
            wk, nk, _ = out
            lat = token_latency(devices, model, wk, nk, cases)
            history.append((k, lat))
            cands.append((tuple(wk), tuple(nk), k, lat))
            if best is None or lat < best.latency:
                best = HaldaSolution(w=wk, n=nk, k=k, cases=list(cases),
                                     latency=lat, iterations=0,
                                     history=history)
    if best is not None:
        best.candidates = cands
    return best


def solve(devices: Sequence[DeviceProfile], model: ModelProfile, *,
          max_iters: int = 32, force_fallback: bool = False,
          paper_faithful: bool = False,
          spec: Optional[SpecPostPass] = None) -> HaldaSolution:
    """Run Halda (Algorithm 1); unless ``paper_faithful``, refine with the
    exact case-enumeration search and return the better of the two.

    ``spec``: optional speculative post-pass — prices every candidate
    assignment with and without speculation (``sol.spec_report``)."""
    sol = _solve_inner(devices, model, max_iters=max_iters,
                       force_fallback=force_fallback,
                       paper_faithful=paper_faithful)
    if spec is not None:
        sol.spec_report = speculative_post_pass(devices, model, sol, spec)
    return sol


def _solve_inner(devices: Sequence[DeviceProfile], model: ModelProfile, *,
                 max_iters: int = 32, force_fallback: bool = False,
                 paper_faithful: bool = False) -> HaldaSolution:
    M = len(devices)
    L = model.n_layers
    if M == 1:
        dev = devices[0]
        w = [L]
        kvb = model.kv_bytes_layer
        per_layer = model.layer_bytes + kvb
        cap = int((dev.gpu_budget() - model.c_gpu) // per_layer) \
            if dev.has_gpu else 0
        n = [max(0, min(L, cap))]
        cases = [classify_device(dev, 0, model, w[0], n[0], 1)]
        lat = token_latency(devices, model, w, n)
        return HaldaSolution(w=w, n=n, k=1, cases=cases, latency=lat,
                             iterations=0,
                             candidates=[(tuple(w), tuple(n), 1, lat)])

    ks = [k for k in divisors(L) if L // k >= M]
    if not ks:
        ks = [1]

    w = _initial_windows(devices, L)
    n = [0] * M
    forced: set = set()
    prev_cases: Optional[List[Case]] = None
    best: Optional[HaldaSolution] = None
    relaxed_mode = False
    history: List[Tuple[int, float]] = []
    cands: List[Candidate] = []

    for it in range(max_iters):
        W = sum(w)
        k_now = max(1, round(L / max(W, 1)))
        cases = [classify_device(d, i, model, w[i], n[i], k_now,
                                 forced_m4=(i in forced))
                 for i, d in enumerate(devices)]
        if cases != prev_cases:
            prev_cases = cases
            continue

        obj = build_objective(devices, model, cases)
        round_best: Optional[Tuple[List[int], List[int], float, int]] = None
        for k in ks:
            out = solve_ilp_fixed_k(devices, model, obj, k,
                                    relax=relaxed_mode,
                                    force_fallback=force_fallback)
            if out is None:
                continue
            wk, nk, _ = out
            lat = token_latency(devices, model, wk, nk, cases)
            history.append((k, lat))
            cands.append((tuple(wk), tuple(nk), k, lat))
            if round_best is None or lat < round_best[2]:
                round_best = (wk, nk, lat, k)

        if round_best is None:
            if not relaxed_mode:
                relaxed_mode = True   # drop overload-consistency rows
                prev_cases = None
                continue
            break

        wk, nk, lat, kk = round_best
        Wk = sum(wk)
        obj_k = build_objective(devices, model, cases)
        if _gpu_underused_and_overload(devices, model, obj_k, wk, nk, Wk):
            candidates = [i for i, c in enumerate(cases)
                          if c in (Case.M1, Case.M2, Case.M3)
                          and i not in forced]
            if candidates:
                slowest = min(candidates,
                              key=lambda i: devices[i].disk_speed())
                forced.add(slowest)
                prev_cases = None
                continue

        if wk == w and nk == n:
            best = HaldaSolution(w=wk, n=nk, k=kk, cases=cases, latency=lat,
                                 iterations=it + 1, relaxed=relaxed_mode,
                                 history=history)
            break
        w, n = wk, nk
        best = HaldaSolution(w=wk, n=nk, k=kk, cases=cases, latency=lat,
                             iterations=it + 1, relaxed=relaxed_mode,
                             history=history)

    if best is None:
        # final fallback: memory-proportional with no GPU layers
        w = _initial_windows(devices, L)
        n = [0] * M
        cases = [classify_device(d, i, model, w[i], n[i], 1)
                 for i, d in enumerate(devices)]
        best = HaldaSolution(w=w, n=n, k=1, cases=cases,
                             latency=token_latency(devices, model, w, n),
                             iterations=max_iters, relaxed=True,
                             history=history)
    if not paper_faithful:
        exact = solve_exact(devices, model, force_fallback=force_fallback)
        if exact is not None:
            cands.extend(exact.candidates)
            if exact.latency < best.latency:
                exact = dataclasses.replace(exact,
                                            iterations=best.iterations)
                best = exact
        best = _rebalance(devices, model, best)
    best.candidates = cands
    return best


def _rebalance(devices: Sequence[DeviceProfile], model: ModelProfile,
               sol: HaldaSolution) -> HaldaSolution:
    """Latency-neutral tie-break: the paper's sum-form objective is
    indifferent to how a tie is split (e.g. [1,1,1,9] vs [3,3,3,3] on a
    homogeneous cluster), but a real pipeline prefers balanced windows
    (the max-form bubble argument). Greedily move layers from the largest
    window to the smallest while analytic latency does not increase."""
    w = list(sol.w)
    n = list(sol.n)
    best_lat = sol.latency
    L = model.n_layers
    for _ in range(L):
        hi = max(range(len(w)), key=lambda i: w[i])
        if w[hi] <= 1:
            break
        moved = False
        # try receivers from smallest window up (a straggler may refuse
        # extra layers — the next-smallest device can still take them)
        for lo in sorted(range(len(w)), key=lambda i: w[i]):
            if lo == hi or w[hi] - w[lo] <= 1:
                continue
            cand_w = list(w)
            cand_n = list(n)
            cand_w[hi] -= 1
            cand_w[lo] += 1
            if cand_n[hi] > cand_w[hi]:      # keep n <= w: move a GPU layer
                cand_n[hi] -= 1
                if devices[lo].has_gpu:
                    cand_n[lo] = min(cand_n[lo] + 1, cand_w[lo])
            lat = token_latency(devices, model, cand_w, cand_n)
            if lat <= best_lat + 1e-12:
                w, n = cand_w, cand_n
                best_lat = min(best_lat, lat)
                moved = True
                break
        if not moved:
            break
    if w == list(sol.w) and n == list(sol.n):
        return sol
    k = L // sum(w) if sum(w) and L % sum(w) == 0 else sol.k
    cases = [classify_device(d, i, model, w[i], n[i], max(k, 1))
             for i, d in enumerate(devices)]
    return dataclasses.replace(sol, w=w, n=n, k=k, cases=cases,
                               latency=best_lat)


def brute_force(devices: Sequence[DeviceProfile], model: ModelProfile,
                max_W: Optional[int] = None) -> HaldaSolution:
    """Exhaustive LDA search (tiny instances only; test oracle)."""
    M = len(devices)
    L = model.n_layers
    best: Optional[HaldaSolution] = None
    for k in divisors(L, exclude_self=False):
        W = L // k
        if W < M or (max_W and W > max_W):
            continue
        for w in itertools.product(range(1, W + 1), repeat=M):
            if sum(w) != W:
                continue
            n_ranges = []
            for i, dev in enumerate(devices):
                if dev.has_gpu:
                    n_ranges.append(range(0, w[i] + 1))
                else:
                    n_ranges.append(range(0, 1))
            for n in itertools.product(*n_ranges):
                cases = [classify_device(d, i, model, w[i], n[i], k)
                         for i, d in enumerate(devices)]
                # respect VRAM capacity
                obj = build_objective(devices, model, cases)
                ok = True
                for i, dev in enumerate(devices):
                    if n[i] > math.floor(W * obj.z_gpu[i] + 1e-9):
                        ok = False
                if not ok:
                    continue
                lat = token_latency(devices, model, list(w), list(n), cases)
                if best is None or lat < best.latency:
                    best = HaldaSolution(w=list(w), n=list(n), k=k,
                                         cases=cases, latency=lat,
                                         iterations=0)
    assert best is not None
    return best
