"""Baseline layer-assignment strategies the paper compares against (§4).

Each strategy returns (w, n, k) in the same decision space as Halda so the
simulator and the analytic latency model can score all systems uniformly.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from .halda import HaldaSolution
from .latency import classify_device, token_latency
from .profiles import DeviceProfile, ModelProfile


def _finish(devices, model, w, n, k) -> HaldaSolution:
    cases = [classify_device(d, i, model, w[i], n[i], k)
             for i, d in enumerate(devices)]
    return HaldaSolution(w=list(w), n=list(n), k=k, cases=cases,
                         latency=token_latency(devices, model, w, n, cases),
                         iterations=0)


def _proportional(weights: Sequence[float], L: int) -> List[int]:
    arr = np.asarray(weights, dtype=float)
    if arr.sum() <= 0:
        arr = np.ones(len(arr))
    w = np.maximum(np.floor(arr / arr.sum() * L), 1).astype(int)
    while w.sum() > L:
        w[int(np.argmax(w))] -= 1
    while w.sum() < L:
        w[int(np.argmax(arr / arr.sum() * L - w))] += 1
    return w.tolist()


def _gpu_layers_capacity(dev: DeviceProfile, model: ModelProfile,
                         w_m: int) -> int:
    if not dev.has_gpu:
        return 0
    per_layer = model.layer_bytes + model.kv_bytes_layer
    cap = int(max(dev.gpu_budget() - model.c_gpu, 0.0) // max(per_layer, 1.0))
    return min(w_m, cap)


def llama_cpp(devices: Sequence[DeviceProfile], model: ModelProfile
              ) -> HaldaSolution:
    """Single strongest device runs everything (on-device baseline).

    Matches the paper's setup: llama.cpp on the most powerful desktop, with
    as many layers as fit on its GPU and the rest on CPU/mmap.
    """
    def power(d: DeviceProfile) -> float:
        g = max(d.gpu_flops.values()) if d.gpu_flops else 0.0
        return max(max(d.cpu_flops.values()), g)

    best = max(range(len(devices)), key=lambda i: power(devices[i]))
    L = model.n_layers
    w = [0] * len(devices)
    n = [0] * len(devices)
    w[best] = L
    n[best] = _gpu_layers_capacity(devices[best], model, L)
    # single-device ring: k = 1 and only one participant
    sub = [devices[best]]
    sol = _finish(sub, model, [L], [n[best]], 1)
    return HaldaSolution(w=w, n=n, k=1, cases=[sol.cases[0]],
                         latency=sol.latency, iterations=0)


def exo(devices: Sequence[DeviceProfile], model: ModelProfile
        ) -> HaldaSolution:
    """exo: layers proportional to *total* device memory, k = 1.

    exo uses the GPU exclusively when present ("CPU / GPU" in Table 1) and
    keeps weights resident (no mmap) — OOM when a shard exceeds memory.
    """
    totals = []
    for d in devices:
        # total memory, not available: the paper notes exo splits by RAM size
        # (approximate total as available * 2 for home devices).
        t = (d.ram_avail * 2.0) + (d.vram_avail if d.has_cuda else 0.0)
        if d.has_metal:
            t = max(t, d.vram_avail * 1.5)
        totals.append(t)
    w = _proportional(totals, model.n_layers)
    n = [w[i] if d.has_gpu else 0 for i, d in enumerate(devices)]
    return _finish(devices, model, w, n, 1)


def dllama(devices: Sequence[DeviceProfile], model: ModelProfile
           ) -> HaldaSolution:
    """dllama: uniform split (tensor parallelism), CPU-only, k = 1.

    TP slices every layer evenly; latency-wise each device processes 1/M of
    every layer and an all-reduce per layer is paid. We model it in the
    layer-window space as a uniform split with an extra per-layer comm term
    folded into xi via the simulator's tp_allreduce flag.
    """
    M = len(devices)
    w = _proportional([1.0] * M, model.n_layers)
    n = [0] * M
    return _finish(devices, model, w, n, 1)


def prima_no_halda(devices: Sequence[DeviceProfile], model: ModelProfile
                   ) -> HaldaSolution:
    """Ablation (§4.2): exo's strategy improved with *available* RAM/VRAM
    and GPU->CPU offload of overloaded layers; k = 1."""
    avail = [d.memory_budget() for d in devices]
    w = _proportional(avail, model.n_layers)
    n = [_gpu_layers_capacity(d, model, w[i]) for i, d in enumerate(devices)]
    return _finish(devices, model, w, n, 1)


STRATEGIES = {
    "llama.cpp": llama_cpp,
    "exo": exo,
    "dllama": dllama,
    "prima(w/o halda)": prima_no_halda,
}
