"""Piped-ring schedule construction (Section 3.1, Figure 1).

Given the Halda decision (w, n, k) over M ring devices, build the concrete
layer->(<device, round, backend>) schedule: device m processes a window of
w_m consecutive layers in each of the k rounds; windows are laid out in ring
order so every layer is covered exactly once per token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class WindowAssignment:
    device: int            # ring position m
    round: int             # 0..k-1
    layer_start: int       # first layer (inclusive)
    layer_end: int         # last layer (exclusive)
    n_resident: int        # layers on GPU / pinned in HBM (paper: n_m)

    @property
    def n_layers(self) -> int:
        return self.layer_end - self.layer_start

    @property
    def n_streamed(self) -> int:
        return self.n_layers - self.n_resident


@dataclasses.dataclass(frozen=True)
class RingSchedule:
    n_layers: int
    w: Tuple[int, ...]
    n: Tuple[int, ...]
    k: int
    windows: Tuple[WindowAssignment, ...]   # in execution (ring) order

    @property
    def n_devices(self) -> int:
        return len(self.w)

    def device_windows(self, m: int) -> List[WindowAssignment]:
        return [win for win in self.windows if win.device == m]

    def layer_owner(self, layer: int) -> WindowAssignment:
        for win in self.windows:
            if win.layer_start <= layer < win.layer_end:
                return win
        raise KeyError(layer)


def build_schedule(w: Sequence[int], n: Sequence[int], L: int) -> RingSchedule:
    """Lay windows around the ring; validates full single coverage.

    Devices with w_m == 0 (possible for baseline strategies like llama.cpp
    on a multi-device profile list) are skipped in the ring.
    """
    active = [m for m in range(len(w)) if w[m] > 0]
    if not active:
        raise ValueError("no active devices")
    W = sum(w)
    if L % W:
        raise ValueError(f"W={W} must divide L={L} (Assumption 1)")
    k = L // W
    windows: List[WindowAssignment] = []
    layer = 0
    for r in range(k):
        for m in active:
            # resident layers are the leading n_m of each window (the split
            # point is arbitrary for correctness; leading keeps the HBM-pinned
            # prefix contiguous for the streaming runtime).
            windows.append(WindowAssignment(
                device=m, round=r,
                layer_start=layer, layer_end=layer + w[m],
                n_resident=min(n[m], w[m])))
            layer += w[m]
    assert layer == L
    return RingSchedule(n_layers=L, w=tuple(w), n=tuple(n), k=k,
                        windows=tuple(windows))


def validate_schedule(s: RingSchedule) -> None:
    """Every layer exactly once; windows contiguous and ring-ordered."""
    covered = [0] * s.n_layers
    prev_end = 0
    for win in s.windows:
        assert win.layer_start == prev_end, "windows must be contiguous"
        prev_end = win.layer_end
        for l in range(win.layer_start, win.layer_end):
            covered[l] += 1
        assert 0 <= win.n_resident <= win.n_layers
    assert prev_end == s.n_layers
    assert all(c == 1 for c in covered), "layer covered more than once"
