"""Event-driven piped-ring timeline simulator (Appendix A.1/A.2, Fig. 3-6).

Simulates the decode loop at window granularity: compute, ring hops,
demand (page-fault) weight loading, and background prefetch — including the
prefetch-release effect when a device's streamed window exceeds its
reclaimable-memory budget.

The simulator is the measurement instrument for the reproduction benchmarks
(Table 3/4/6, Fig 2/8); the analytic model in ``latency.py`` is Halda's
objective. Tests assert the two agree in regimes where the paper's
worst-case assumption (no overlap) makes them comparable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .latency import (_sum_q, classify_device, device_coeffs,
                      expected_tokens_per_cycle)
from .profiles import Case, DeviceProfile, ModelProfile, OS
from .ring import RingSchedule, build_schedule


@dataclasses.dataclass
class SimResult:
    token_latency: float            # steady-state seconds/token
    ttft: float                     # first token completion time
    oom: bool = False
    per_device_busy: Dict[int, float] = dataclasses.field(default_factory=dict)
    per_device_disk: Dict[int, float] = dataclasses.field(default_factory=dict)
    memory_pressure: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def token_latency_ms(self) -> float:
        return self.token_latency * 1e3


@dataclasses.dataclass
class _DevState:
    budget: float          # reclaimable page-cache budget for streamed weights
    stream_bytes_total: float   # total streamed weight bytes on this device
    resident_ok: bool      # streamed set fits budget -> cached after warmup
    warm: bool = False     # whether the full streamed set has been read once
    prefetch_started: float = -1.0   # wall time background prefetch began
    prev_done: float = 0.0
    busy: float = 0.0
    disk: float = 0.0


def _window_compute_time(dev: DeviceProfile, model: ModelProfile,
                         n_cpu: int, n_gpu: int, is_head: bool,
                         seq: int = 1) -> float:
    """Compute + memory-access time for one window (seq tokens batched)."""
    t = 0.0
    if n_cpu:
        t += n_cpu * (_sum_q(model.flops_layer, dev.cpu_flops) * seq
                      + dev.t_kv_copy_cpu * seq
                      + model.b_prime / dev.cpu_membw)
    if n_gpu:
        t += n_gpu * (_sum_q(model.flops_layer, dev.gpu_flops) * seq
                      + dev.t_kv_copy_gpu * seq
                      + model.b_prime / max(dev.gpu_membw, 1.0))
    t += (dev.t_ram_vram + dev.t_vram_ram) * (0.0 if dev.uma else 1.0)
    return t


def _head_output_time(dev: DeviceProfile, model: ModelProfile,
                      seq: int = 1) -> float:
    """lm-head time; ``seq`` positions need logits per verify pass (the
    head weights stream once — only the matmul FLOPs scale)."""
    return (seq * _sum_q(model.flops_output, dev.cpu_flops)
            + model.head_extra_bytes() / dev.cpu_membw)


def simulate_ring(devices: Sequence[DeviceProfile], model: ModelProfile,
                  w: Sequence[int], n: Sequence[int], *,
                  prefetch: bool = True, n_tokens: int = 8,
                  prompt_len: int = 16, resident_weights: bool = False,
                  decode_seq: int = 1) -> SimResult:
    """Simulate piped-ring decode for an assignment.

    ``resident_weights=True`` models systems that keep weights in mem_used
    (exo/dllama): no mmap reclaim (no disk loads) but OOM when the shard
    exceeds device memory, and full memory pressure.

    ``decode_seq``: tokens scored per decode pass (1 = ordinary decode;
    gamma+1 = a speculative verify pass). Compute and KV terms scale with
    it; weight streaming — RAM *and* disk — is per pass, which is the
    whole speculative amortization. The returned ``token_latency`` is then
    seconds per *pass*, not per emitted token (see ``simulate_speculative``).
    """
    sched = build_schedule(w, n, model.n_layers)
    active = sorted({win.device for win in sched.windows})
    states: Dict[int, _DevState] = {}
    pressure: Dict[int, float] = {}
    oom = False

    for m in active:
        dev = devices[m]
        k = sched.k
        n_cpu_layers = k * (w[m] - n[m])
        kv_cpu = n_cpu_layers * model.kv_bytes_layer
        kv_gpu = k * n[m] * model.kv_bytes_layer
        stream = n_cpu_layers * model.layer_bytes
        head_extra = model.head_extra_bytes() if m == active[0] else 0.0
        # mem_total estimate: home devices are >= 8 GiB; mem_available is
        # what's left after the OS/apps (paper's pressure denominator).
        ram_total = max(dev.ram_avail * 2.0, 8.0 * (1 << 30))

        if resident_weights:
            shard = k * w[m] * model.layer_bytes
            gpu_shard = min(shard, dev.gpu_budget())
            cpu_resident = shard - gpu_shard + kv_cpu + model.c_cpu
            if (cpu_resident > dev.ram_avail * 1.5
                    or gpu_shard > dev.gpu_budget() + 1e-9 and not dev.has_gpu):
                oom = True
            pressure[m] = min(cpu_resident / ram_total, 1.0)
            states[m] = _DevState(budget=math.inf, stream_bytes_total=0.0,
                                  resident_ok=True, warm=True)
            continue

        # mmap path: only KV + buffers are non-reclaimable pressure.
        pressure[m] = min((kv_cpu + kv_gpu * (1.0 if dev.uma else 0.0)
                           + model.c_cpu + head_extra) / ram_total, 0.99)
        budget = max(dev.ram_avail - model.c_cpu - head_extra - kv_cpu, 0.0)
        if dev.os == OS.ANDROID:
            budget += min(dev.bytes_can_swap, dev.swap_avail)
        if dev.os == OS.MACOS and dev.has_metal:
            # macOS+Metal (paper case 2): when the *whole* working set
            # exceeds the recommended Metal budget, the OS evicts mmap-ed
            # weights aggressively and every assigned layer reloads —
            # including the "GPU" layers (UMA shared pool).
            total_need = (k * w[m] * model.layer_bytes
                          + (kv_cpu + kv_gpu) + model.c_cpu + model.c_gpu
                          + head_extra)
            if total_need > dev.vram_avail:
                stream = k * w[m] * model.layer_bytes
                budget = max(dev.vram_avail - model.c_cpu - model.c_gpu
                             - (kv_cpu + kv_gpu) - head_extra, 0.0)
        states[m] = _DevState(budget=budget, stream_bytes_total=stream,
                              resident_ok=stream <= budget)

    head = active[0]
    completions: List[float] = []
    t_clock = 0.0

    for tok in range(n_tokens):
        seq = prompt_len if tok == 0 else decode_seq
        arrival = t_clock
        for win in sched.windows:
            m = win.device
            dev = devices[m]
            st = states[m]
            start = max(arrival, st.prev_done)

            # -- disk loading for the streamed part of this window ---------
            metal_full = (dev.os == OS.MACOS and dev.has_metal
                          and not st.resident_ok
                          and st.stream_bytes_total > 0)
            win_stream = win.n_streamed * model.layer_bytes
            if metal_full:
                win_stream = win.n_layers * model.layer_bytes
            stall = 0.0
            if win_stream > 0 and not st.resident_ok:
                # prefetch-release: window bigger than the page-cache budget
                # means background prefetch evicted itself (A.1).
                release = win_stream > st.budget
                per_token_reload = max(
                    st.stream_bytes_total - st.budget, 0.0)
                # paper eq. (15): only the excess over the budget re-loads;
                # distribute over this device's k windows.
                need = per_token_reload / max(sched.k, 1) \
                    if not release else win_stream
                need = min(need, win_stream)
                # background prefetch overlapped since this device's last
                # window (other stages' compute hides it; paper Fig. 6)
                useful = 0.0
                if prefetch and not release and st.prefetch_started >= 0.0:
                    gap = max(start - st.prefetch_started, 0.0)
                    useful = min(dev.disk_speed() * gap, need)
                demand = max(need - useful, 0.0)
                stall = demand / dev.disk_speed()
                st.disk += need / dev.disk_speed()
            elif win_stream > 0 and not st.warm:
                stall = win_stream / dev.disk_speed()  # cold first read
                st.disk += stall

            comp = _window_compute_time(dev, model, win.n_streamed,
                                        win.n_resident, m == head, seq)
            done = start + stall + comp
            st.busy += stall + comp
            st.prev_done = done
            st.prefetch_started = done if (prefetch
                                           and not st.resident_ok) else -1.0
            arrival = done + dev.t_comm

        # output layer back on the head device (prefill emits one logit
        # row; a decode pass emits decode_seq of them)
        head_dev = devices[head]
        arrival = max(arrival, states[head].prev_done)
        out_done = arrival + _head_output_time(
            head_dev, model, 1 if tok == 0 else decode_seq)
        states[head].prev_done = out_done
        completions.append(out_done)
        t_clock = out_done
        for m in active:
            if states[m].stream_bytes_total > 0:
                states[m].warm = True

    if len(completions) >= 3:
        steady = (completions[-1] - completions[1]) / (len(completions) - 2)
    else:
        steady = completions[-1] / max(len(completions), 1)
    busy = {m: states[m].busy for m in active}
    disk = {m: states[m].disk for m in active}
    return SimResult(token_latency=steady, ttft=completions[0], oom=oom,
                     per_device_busy=busy, per_device_disk=disk,
                     memory_pressure=pressure)


@dataclasses.dataclass
class SpecSimResult:
    """Speculative-decoding timeline result (per *emitted* token)."""

    token_latency: float            # expected seconds per emitted token
    tps: float                      # expected emitted tokens/s
    cycle_latency: float            # verify pass + draft steps
    verify_latency: float           # ring pass scoring gamma+1 positions
    draft_latency: float            # gamma+1 draft decodes per cycle
    tokens_per_cycle: float         # E[emitted] at the acceptance rate
    base: SimResult                 # underlying ring simulation (per pass)

    @property
    def token_latency_ms(self) -> float:
        return self.token_latency * 1e3


def simulate_speculative(devices: Sequence[DeviceProfile],
                         model: ModelProfile, w: Sequence[int],
                         n: Sequence[int], *, gamma: int,
                         acceptance: float, draft_token_latency: float,
                         prefetch: bool = True, n_cycles: int = 8,
                         prompt_len: int = 16) -> SpecSimResult:
    """Speculative decode on the ring timeline.

    Each cycle runs gamma+1 draft decodes (resident on the head device —
    ``draft_token_latency`` per step, measured or modelled separately) and
    ONE (gamma+1)-token verify pass through the pipelined ring; the pass
    streams each window's weights once, so its cost is far below gamma+1
    single-token passes on these disk/bandwidth-bound clusters. Emitted
    tokens per cycle follow the acceptance model
    (``expected_tokens_per_cycle``); the effective TPOT divides the cycle
    time by it.
    """
    base = simulate_ring(devices, model, w, n, prefetch=prefetch,
                         n_tokens=n_cycles, prompt_len=prompt_len,
                         decode_seq=gamma + 1)
    e = expected_tokens_per_cycle(acceptance, gamma)
    t_draft = (gamma + 1) * draft_token_latency
    cycle = base.token_latency + t_draft
    return SpecSimResult(token_latency=cycle / e, tps=e / cycle,
                         cycle_latency=cycle,
                         verify_latency=base.token_latency,
                         draft_latency=t_draft, tokens_per_cycle=e,
                         base=base)


def simulate_tp(devices: Sequence[DeviceProfile], model: ModelProfile, *,
                n_tokens: int = 8, prompt_len: int = 16) -> SimResult:
    """dllama-style uniform tensor parallelism: every device computes 1/M of
    every layer, with an all-reduce barrier per layer (CPU backend, resident
    weights, Q40-style)."""
    M = len(devices)
    L = model.n_layers
    pressure: Dict[int, float] = {}
    oom = False
    for m, dev in enumerate(devices):
        shard = L * model.layer_bytes / M + L * model.kv_bytes_layer / M \
            + model.c_cpu
        ram_total = dev.ram_avail * 2.0
        pressure[m] = min(shard / ram_total, 1.0)
        if shard > dev.ram_avail * 1.5:
            oom = True

    completions = []
    t = 0.0
    for tok in range(n_tokens):
        seq = prompt_len if tok == 0 else 1
        for layer in range(L):
            per_dev = [(_sum_q(model.flops_layer, d.cpu_flops) * seq / M
                        + (model.b_prime / M) / d.cpu_membw
                        + d.t_kv_copy_cpu * seq)
                       for d in devices]
            # two all-reduce barriers per layer (attention out + MLP out,
            # Megatron-style TP): slowest device + round-trips
            t += max(per_dev) + 2.0 * 2.0 * max(d.t_comm for d in devices)
        t += _head_output_time(devices[0], model)
        completions.append(t)
    steady = ((completions[-1] - completions[1]) / (len(completions) - 2)
              if len(completions) >= 3 else completions[-1])
    return SimResult(token_latency=steady, ttft=completions[0], oom=oom,
                     memory_pressure=pressure)
