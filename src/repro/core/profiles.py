"""Device and model profiles — the inputs to the LDA problem.

Mirrors the paper's device profiler (Appendix A.3): per-device compute
throughput per quant format, memory-access throughput, disk read speed,
communication latency, OS/memory-management behaviour; and the model
profiler: per-layer FLOPs per quant format, per-layer weight bytes,
KV-cache geometry.

All quantities are SI (bytes, seconds, FLOP/s, bytes/s).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional

GiB = float(1 << 30)
MiB = float(1 << 20)


class OS(str, enum.Enum):
    MACOS = "macos"
    LINUX = "linux"
    ANDROID = "android"
    # TPU adaptation: a pipeline *stage* with explicit host->HBM streaming.
    # Reclaim behaviour is "explicit": the runtime owns eviction, which the
    # latency model treats like Linux sequential reload (Case 3/4 family).
    TPU_STAGE = "tpu_stage"


class Case(enum.IntEnum):
    """The paper's device cases M1..M4 (Section 3.2)."""

    M1 = 1  # macOS, Metal disabled, insufficient RAM, fast disk
    M2 = 2  # macOS, Metal enabled, insufficient RAM, fast disk
    M3 = 3  # Linux/Android (and TPU stage), insufficient RAM, fast disk
    M4 = 4  # sufficient RAM or slow disk -> no overload permitted


#: Quant formats considered by the profiler (paper: Q = {Q4K,...,F32}).
QUANTS = ("q4k", "q5k", "q6k", "q80", "f16", "f32")


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One ring participant.

    On the home cluster this is a physical device; on the TPU production mesh
    it is one pipeline stage (a TP group of chips) whose "disk" is host DRAM
    reached over DMA and whose "VRAM" is the per-stage HBM budget.
    """

    name: str
    os: OS = OS.LINUX
    # --- memory ---------------------------------------------------------
    ram_avail: float = 8 * GiB          # d_m^avail
    vram_avail: float = 0.0             # d_{m,cuda}^avail / d_{m,metal}^avail
    swap_avail: float = 0.0             # d_m^swap_avail (Android)
    bytes_can_swap: float = 0.0         # d_m^bytes_can_swap (Android)
    has_metal: bool = False
    has_cuda: bool = False
    uma: bool = False                   # unified memory (Apple M-series)
    # --- compute: FLOP/s per backend per quant --------------------------
    cpu_flops: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {q: 50e9 for q in QUANTS})
    gpu_flops: Dict[str, float] = dataclasses.field(default_factory=dict)
    # --- memory access --------------------------------------------------
    cpu_membw: float = 20e9             # T_m^cpu (bytes/s into registers)
    gpu_membw: float = 0.0              # T_m^cuda or T_m^metal
    t_kv_copy_cpu: float = 2e-6         # t_m^{kv_cpy,cpu} per layer per token
    t_kv_copy_gpu: float = 0.0
    t_ram_vram: float = 30e-6           # t_m^{ram->vram} per window
    t_vram_ram: float = 30e-6           # t_m^{vram->ram} per window
    # --- disk (or host DRAM for TPU stages) ------------------------------
    disk_seq_bps: float = 2.0e9         # sequential read (Linux mmap)
    disk_rand_bps: float = 1.0e9        # random read (macOS)
    # --- network ---------------------------------------------------------
    t_comm: float = 2e-3                # t_m^comm: one 4e-byte hop to successor

    @property
    def has_gpu(self) -> bool:
        return self.has_cuda or self.has_metal

    def disk_speed(self) -> float:
        """Effective mmap reload throughput for this OS (paper A.3)."""
        if self.os == OS.MACOS:
            return self.disk_rand_bps
        return self.disk_seq_bps

    def gpu_budget(self) -> float:
        """VRAM (CUDA) or recommended Metal working-set budget."""
        return self.vram_avail if self.has_gpu else 0.0

    def memory_budget(self) -> float:
        """Initialization budget used by Halda line 1."""
        if self.os == OS.MACOS and self.has_metal:
            return self.vram_avail  # d_{m,metal}^avail (UMA shared pool)
        if self.os == OS.ANDROID:
            return self.ram_avail + min(self.bytes_can_swap, self.swap_avail)
        return self.ram_avail + (self.vram_avail if self.has_cuda else 0.0)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Model-side inputs to the latency model (paper's model profiler)."""

    name: str
    n_layers: int                        # L
    layer_bytes: float                   # b  (per decoder layer, all quants)
    input_bytes: float                   # b_i (embedding table)
    output_bytes: float                  # b_o (lm head)
    embed_dim: int                       # e
    vocab: int                           # V
    kv_heads: int                        # h_k = h_v
    head_dim: int                        # e_k = e_v
    n_kv: int = 1024                     # tokens resident in KV cache
    # FLOPs per *token* per layer, per quant format present in the file.
    flops_layer: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_output: Dict[str, float] = dataclasses.field(default_factory=dict)
    c_cpu: float = 256 * MiB             # compute buffer (CPU side)
    c_gpu: float = 256 * MiB             # compute buffer (GPU side)
    # Per-layer recurrent-state bytes (SSM/RG-LRU archs); replaces KV bytes
    # for layers that carry O(1) state instead of a KV cache.
    state_bytes: float = 0.0

    @property
    def kv_bytes_per_token_layer(self) -> float:
        """2 * (h_k e_k + h_v e_v) in F16 -> bytes per layer per token."""
        return 2.0 * 2.0 * (self.kv_heads * self.head_dim)

    @property
    def kv_bytes_layer(self) -> float:
        """KV bytes per layer at context n_kv, plus any recurrent state."""
        return self.kv_bytes_per_token_layer * self.n_kv + self.state_bytes

    @property
    def b_prime(self) -> float:
        """b' = b + 2(h_k e_k + h_v e_v) n_kv (weights + KV per layer)."""
        return self.layer_bytes + self.kv_bytes_layer

    def head_extra_bytes(self) -> float:
        """(b_i / V + b_o): embedding row + lm-head bytes on the head device."""
        return self.input_bytes / self.vocab + self.output_bytes


def divisors(n: int, exclude_self: bool = True) -> List[int]:
    """Valid round counts K_L: divisors of L (paper excludes k = L)."""
    out = [d for d in range(1, n + 1) if n % d == 0]
    if exclude_self and len(out) > 1:
        out = [d for d in out if d != n]
    return out


# ---------------------------------------------------------------------------
# Model profile construction from an architecture config (decode FLOPs).
# ---------------------------------------------------------------------------

def profile_from_config(cfg, *, n_kv: int = 1024, quant: str = "q4k",
                        name: Optional[str] = None) -> ModelProfile:
    """Build a ModelProfile from a ``repro.configs`` ModelConfig.

    FLOPs are per decoded token (batch 1): 2 * weight-params matmul FLOPs
    plus attention score/value FLOPs against the n_kv-token cache.
    Weight bytes honour the quant format (q4k ~ 4.5 bits/weight incl scales).
    """
    # q4k uses the Q4_K_M effective rate (~4.85 bits/weight: llama.cpp
    # mixes q4_K and q6_K blocks), matching the paper's 40 GiB for 70B.
    bits = {"q4k": 4.85, "q5k": 5.5, "q6k": 6.5, "q80": 8.5,
            "f16": 16.0, "f32": 32.0}[quant]
    e = cfg.d_model
    # Per-layer weight parameter count (attention + mixer), from the config's
    # own accounting (handles MoE/MLA/SSM variants).
    p_layer = cfg.params_per_layer()
    p_active = cfg.active_params_per_layer()
    layer_bytes = p_layer * bits / 8.0
    input_bytes = cfg.vocab * e * bits / 8.0
    output_bytes = cfg.vocab * e * bits / 8.0
    flops_layer = 2.0 * p_active
    if cfg.kv_heads > 0:
        flops_layer += 4.0 * cfg.n_heads * cfg.head_dim * min(
            n_kv, cfg.attn_window or n_kv)
    flops_out = 2.0 * cfg.vocab * e
    state_bytes = 0.0
    if getattr(cfg, "ssm_state", 0):
        # Mamba-2 state: heads x head_dim x state, fp32.
        state_bytes = 4.0 * cfg.d_inner * cfg.ssm_state
    return ModelProfile(
        name=name or cfg.name,
        n_layers=cfg.n_layers,
        layer_bytes=layer_bytes,
        input_bytes=input_bytes,
        output_bytes=output_bytes,
        embed_dim=e,
        vocab=cfg.vocab,
        kv_heads=max(cfg.kv_heads, 0),
        head_dim=cfg.head_dim if cfg.kv_heads else 0,
        n_kv=min(n_kv, cfg.attn_window or n_kv) if cfg.kv_heads else 0,
        flops_layer={quant: flops_layer},
        flops_output={quant: flops_out},
        state_bytes=state_bytes,
    )


# ---------------------------------------------------------------------------
# Reference clusters
# ---------------------------------------------------------------------------

def paper_table2_cluster() -> List[DeviceProfile]:
    """The paper's Table 2 home cluster, D1-D4 (defaults for Table 3/4)."""
    return [
        DeviceProfile(
            name="D1-MacM1", os=OS.MACOS, has_metal=True, uma=True,
            ram_avail=2.4 * GiB, vram_avail=5.3 * GiB,  # Metal working set
            cpu_flops={q: 150e9 for q in QUANTS},
            gpu_flops={q: 500e9 for q in QUANTS},
            cpu_membw=60e9, gpu_membw=60e9,
            t_kv_copy_cpu=1e-6, t_kv_copy_gpu=1e-6,
            t_ram_vram=0.0, t_vram_ram=0.0,
            disk_seq_bps=0.72e9, disk_rand_bps=0.72e9, t_comm=2e-3),
        DeviceProfile(
            name="D2-Laptop3070", os=OS.LINUX, has_cuda=True,
            ram_avail=4.1 * GiB, vram_avail=8.0 * GiB,
            cpu_flops={q: 200e9 for q in QUANTS},
            gpu_flops={q: 2000e9 for q in QUANTS},
            cpu_membw=40e9, gpu_membw=400e9,
            t_kv_copy_cpu=1e-6, t_kv_copy_gpu=0.5e-6,
            t_ram_vram=20e-6, t_vram_ram=20e-6,
            disk_seq_bps=2.98e9, disk_rand_bps=1.5e9, t_comm=2e-3),
        DeviceProfile(
            name="D3-Desktop2080Ti", os=OS.LINUX, has_cuda=True,
            ram_avail=9.7 * GiB, vram_avail=11.0 * GiB,
            cpu_flops={q: 400e9 for q in QUANTS},
            gpu_flops={q: 2500e9 for q in QUANTS},
            cpu_membw=50e9, gpu_membw=500e9,
            t_kv_copy_cpu=1e-6, t_kv_copy_gpu=0.5e-6,
            t_ram_vram=20e-6, t_vram_ram=20e-6,
            disk_seq_bps=3.17e9, disk_rand_bps=1.6e9, t_comm=2e-3),
        DeviceProfile(
            name="D4-Mate40Pro", os=OS.ANDROID,
            ram_avail=1.9 * GiB, swap_avail=4.0 * GiB,
            bytes_can_swap=2.0 * GiB,
            cpu_flops={q: 80e9 for q in QUANTS},
            cpu_membw=25e9,
            t_kv_copy_cpu=2e-6,
            disk_seq_bps=1.37e9, disk_rand_bps=0.8e9, t_comm=2e-3),
    ]


def paper_table2_extra() -> List[DeviceProfile]:
    """D5 (Honor Pad) and D6 (Mac Air) from Table 2, for A.5 experiments."""
    return [
        DeviceProfile(
            name="D5-HonorPad", os=OS.ANDROID,
            ram_avail=5.1 * GiB, swap_avail=4.0 * GiB,
            bytes_can_swap=2.0 * GiB,
            cpu_flops={q: 100e9 for q in QUANTS},
            cpu_membw=25e9, t_kv_copy_cpu=2e-6,
            disk_seq_bps=2.0e9, disk_rand_bps=1.0e9, t_comm=2e-3),
        DeviceProfile(
            name="D6-MacAir", os=OS.MACOS, has_metal=False,
            ram_avail=6.8 * GiB,
            cpu_flops={q: 60e9 for q in QUANTS},
            cpu_membw=15e9, t_kv_copy_cpu=3e-6,
            disk_seq_bps=0.39e9, disk_rand_bps=0.39e9, t_comm=2e-3),
    ]


def tpu_stage_cluster(n_stages: int, *, hbm_budget: float = 14 * GiB,
                      chips_per_stage: int = 16,
                      peak_flops: float = 197e12,
                      hbm_bw: float = 819e9,
                      dma_bps: float = 40e9,
                      ici_latency: float = 1.5e-6) -> List[DeviceProfile]:
    """Homogeneous TPU pipeline stages (production-mesh adaptation).

    Each stage is ``chips_per_stage`` v5e chips in a TP group. "disk" is the
    host-DRAM DMA path used for streamed (offloaded) layer windows. ``cuda``
    semantics model "HBM-resident layers are pinned" (no reload), matching
    the CUDA-driver-locked VRAM behaviour in the paper.
    """
    stage_flops = peak_flops * chips_per_stage
    return [
        DeviceProfile(
            name=f"stage{i}", os=OS.TPU_STAGE, has_cuda=True,
            ram_avail=hbm_budget * 0.25,     # streaming buffer share of HBM
            vram_avail=hbm_budget * chips_per_stage,
            cpu_flops={q: stage_flops * 0.1 for q in QUANTS},  # streamed path
            gpu_flops={q: stage_flops for q in QUANTS},
            cpu_membw=dma_bps, gpu_membw=hbm_bw * chips_per_stage,
            t_kv_copy_cpu=0.2e-6, t_kv_copy_gpu=0.05e-6,
            t_ram_vram=2e-6, t_vram_ram=2e-6,
            disk_seq_bps=dma_bps, disk_rand_bps=dma_bps,
            t_comm=ici_latency)
        for i in range(n_stages)
    ]
