"""Device-subset selection (paper A.5) and elastic re-solve.

The paper's recipe: start with all candidate devices, run Halda, drop the
devices the solver marks as drags (assigned only the forced minimum of one
layer / below a threshold), re-solve, and keep the best cluster found.
``select_cluster`` automates that loop — the "future updates will automate
this" the paper promises.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from . import halda
from .profiles import DeviceProfile, ModelProfile


@dataclasses.dataclass
class ClusterChoice:
    devices: List[int]                  # indices into the candidate list
    solution: halda.HaldaSolution
    history: List[Tuple[Tuple[int, ...], float]]


def select_cluster(candidates: Sequence[DeviceProfile],
                   model: ModelProfile, *,
                   min_layers: int = 2,
                   max_rounds: int = 8) -> ClusterChoice:
    """Iteratively drop drag devices (w_m < min_layers) and keep the best
    latency seen. The head device (index 0) is never dropped."""
    active = list(range(len(candidates)))
    best: Optional[ClusterChoice] = None
    history: List[Tuple[Tuple[int, ...], float]] = []

    for _ in range(max_rounds):
        devs = [candidates[i] for i in active]
        sol = halda.solve(devs, model)
        history.append((tuple(active), sol.latency))
        if best is None or sol.latency < best.solution.latency:
            best = ClusterChoice(devices=list(active), solution=sol,
                                 history=history)
        drags = [active[m] for m, w in enumerate(sol.w)
                 if w < min_layers and active[m] != 0]
        if not drags or len(active) <= 1:
            break
        # drop the single worst drag per round (paper: remove those with
        # one assigned layer; one-at-a-time keeps the search monotone)
        drop = min(
            (i for i in drags),
            key=lambda i: candidates[i].memory_budget())
        active = [i for i in active if i != drop]

    assert best is not None
    best.history = history
    return best


def fail_and_resolve(devices: Sequence[DeviceProfile],
                     model: ModelProfile, failed: Sequence[int]
                     ) -> halda.HaldaSolution:
    """Elastic path: drop failed devices, re-run Halda on the survivors."""
    survivors = [d for i, d in enumerate(devices) if i not in set(failed)]
    if not survivors:
        raise RuntimeError("no surviving devices")
    return halda.solve(survivors, model)
