from .grouped import (dequantize_q4, dequantize_q2, pack_q4, pack_q2,
                      quantize_q4, quantize_q2, unpack_q4, unpack_q2,
                      QuantizedTensor, quantize_tree, dequantize_leaf,
                      dequantize_tree)

__all__ = ["dequantize_q4", "dequantize_q2", "pack_q4", "pack_q2",
           "quantize_q4", "quantize_q2", "unpack_q4", "unpack_q2",
           "QuantizedTensor", "quantize_tree", "dequantize_leaf",
           "dequantize_tree"]
