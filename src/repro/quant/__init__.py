from .grouped import (dequantize_q4, dequantize_q2, pack_q4, quantize_q4,
                      quantize_q2, unpack_q4, QuantizedTensor,
                      quantize_tree, dequantize_leaf)

__all__ = ["dequantize_q4", "dequantize_q2", "pack_q4", "quantize_q4",
           "quantize_q2", "unpack_q4", "QuantizedTensor", "quantize_tree",
           "dequantize_leaf"]
