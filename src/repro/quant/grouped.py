"""Grouped low-bit weight quantization — the TPU-side analogue of Q4K/IQ1.

The paper runs Q4K (4-bit, grouped scales) weights through llama.cpp's CPU
and CUDA backends; here weights are quantized per-group along the input
(contraction) dimension so a matmul kernel can dequantize tile-by-tile in
VMEM (see ``kernels/q4_matmul.py``).

Formats:
  q4: int4 symmetric, group_size contiguous weights share one f16-ish scale
      (~4.5 bits/weight incl. scale, matching the paper's Q4K accounting).
  q2: int2 symmetric (IQ1-ish demo, ~2.25 bits/weight incl. scale).

int4 values are packed two-per-int8 and int2 values four-per-int8, so
``QuantizedTensor.nbytes`` — which the weight-streaming byte accounting
and the latency model's disk terms consume — is the true footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

DEFAULT_GROUP = 64


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Packed quantized weight + per-group scales.

    ``packed``: int8, shape (..., K/2 [q4] or K/4 [q2], N)-style packing on
    the *contraction* axis (axis=-2 by convention for (K, N) weights).
    """

    packed: jnp.ndarray
    scale: jnp.ndarray           # (..., K/group, N)
    bits: int
    group: int
    shape: Tuple[int, ...]       # original (…, K, N)

    def tree_flatten(self):
        return (self.packed, self.scale), (self.bits, self.group, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        bits, group, shape = aux
        return cls(packed=packed, scale=scale, bits=bits, group=group,
                   shape=shape)

    @property
    def nbytes(self) -> int:
        return self.packed.size * self.packed.dtype.itemsize \
            + self.scale.size * self.scale.dtype.itemsize


# --------------------------------------------------------------------------- #
#  int4
# --------------------------------------------------------------------------- #

def quantize_q4(w: jnp.ndarray, group: int = DEFAULT_GROUP
                ) -> QuantizedTensor:
    """Symmetric int4 grouped quantization along axis -2 (contraction)."""
    *lead, K, N = w.shape
    assert K % group == 0, (K, group)
    wg = w.astype(jnp.float32).reshape(*lead, K // group, group, N)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)       # (..., K/g,1,N)
    scale = jnp.maximum(amax / 7.0, 1e-8)
    q = jnp.clip(jnp.round(wg / scale), -7, 7).astype(jnp.int8)
    q = q.reshape(*lead, K, N)
    packed = pack_q4(q)
    return QuantizedTensor(packed=packed,
                           scale=scale[..., 0, :].astype(jnp.bfloat16),
                           bits=4, group=group, shape=tuple(w.shape))


def pack_q4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 values (as int8 in [-7,7]) two-per-byte along axis -2."""
    *lead, K, N = q.shape
    lo = q[..., 0::2, :] & 0xF
    hi = q[..., 1::2, :] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_q4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_q4: (…, K/2, N) int8 -> (…, K, N) int8 in [-8,7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    *lead, Kh, N = packed.shape
    out = jnp.stack([lo, hi], axis=-2)           # (..., Kh, 2, N)
    return out.reshape(*lead, Kh * 2, N)


def dequantize_q4(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    # derive dims from the packed array itself (a sliced QuantizedTensor —
    # e.g. one scan step of a stacked layer bank — keeps stale .shape aux)
    q = unpack_q4(qt.packed).astype(jnp.float32)
    *lead, K, N = q.shape
    qg = q.reshape(*lead, K // qt.group, qt.group, N)
    w = qg * qt.scale[..., :, None, :].astype(jnp.float32)
    return w.reshape(*lead, K, N).astype(dtype)


# --------------------------------------------------------------------------- #
#  int2 (IQ1-ish demo)
# --------------------------------------------------------------------------- #

def quantize_q2(w: jnp.ndarray, group: int = DEFAULT_GROUP
                ) -> QuantizedTensor:
    *lead, K, N = w.shape
    assert K % group == 0
    assert K % 4 == 0, K                         # 4 values per packed byte
    wg = w.astype(jnp.float32).reshape(*lead, K // group, group, N)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
    scale = jnp.maximum(amax / 1.0, 1e-8)
    q = jnp.clip(jnp.round(wg / scale), -1, 1).astype(jnp.int8)
    packed = pack_q2(q.reshape(*lead, K, N))
    return QuantizedTensor(packed=packed,
                           scale=scale[..., 0, :].astype(jnp.bfloat16),
                           bits=2, group=group, shape=tuple(w.shape))


def pack_q2(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int2 values (as int8 in [-2,1]) four-per-byte along axis -2."""
    *lead, K, N = q.shape
    u = q.astype(jnp.uint8) & 0x3
    out = u[..., 0::4, :]
    for i in range(1, 4):
        out = out | (u[..., i::4, :] << (2 * i))
    return out.astype(jnp.int8)


def unpack_q2(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_q2: (…, K/4, N) int8 -> (…, K, N) int8 in [-2,1]."""
    u = packed.astype(jnp.uint8)
    vals = []
    for i in range(4):
        v = ((u >> (2 * i)) & 0x3).astype(jnp.int8)
        vals.append(jnp.where(v > 1, v - 4, v))
    *lead, Kq, N = packed.shape
    out = jnp.stack(vals, axis=-2)               # (..., Kq, 4, N)
    return out.reshape(*lead, Kq * 4, N)


def dequantize_q2(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    # dims derived from the packed array (see dequantize_q4 on stale .shape)
    q = unpack_q2(qt.packed).astype(jnp.float32)
    *lead, K, N = q.shape
    qg = q.reshape(*lead, K // qt.group, qt.group, N)
    w = qg * qt.scale[..., :, None, :].astype(jnp.float32)
    return w.reshape(*lead, K, N).astype(dtype)


# --------------------------------------------------------------------------- #
#  pytree helpers
# --------------------------------------------------------------------------- #

def _is_weight(path: str, leaf: jnp.ndarray, group: int, *,
               min_ndim: int = 2) -> bool:
    return (leaf.ndim >= min_ndim and leaf.shape[-2] % group == 0
            and leaf.shape[-1] >= 8 and "norm" not in path.lower())


def quantize_tree(params: Dict[str, Any], group: int = DEFAULT_GROUP,
                  bits: int = 4, *, stacked: bool = False) -> Dict[str, Any]:
    """Quantize every eligible matmul weight in a parameter pytree.

    Set ``stacked=True`` for trees whose per-layer leaves carry a leading
    layer axis (``params["blocks"]`` layouts, the param-store input): it
    requires ndim >= 3 so a stacked bias/vector leaf ``(L, D)`` can never
    be mistaken for a weight matrix when L happens to divide the group
    (axis -2 of such a leaf is the *layer* axis — quantizing along it is
    silently wrong and breaks the per-layer store sharding).
    """
    quant = quantize_q4 if bits == 4 else quantize_q2
    min_ndim = 3 if stacked else 2
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if _is_weight(name, leaf, group, min_ndim=min_ndim):
            out.append(quant(leaf, group))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_leaf(leaf, dtype=jnp.float32):
    if isinstance(leaf, QuantizedTensor):
        fn = dequantize_q4 if leaf.bits == 4 else dequantize_q2
        return fn(leaf, dtype)
    return leaf


def dequantize_tree(tree: Any, dtype=jnp.float32) -> Any:
    """Dequantize every QuantizedTensor leaf of a pytree; other leaves
    pass through untouched. This is the single dequantize-at-use hook the
    layer-wise model paths and the ring runtime share, so a quantized
    layer store reproduces the resident-dequantized logits exactly."""
    return jax.tree.map(
        lambda leaf: dequantize_leaf(leaf, dtype)
        if isinstance(leaf, QuantizedTensor) else leaf,
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
