"""Fault tolerance end to end: checkpoint/restart + stage failure ->
Halda re-plan -> ring remap -> continue decoding with identical results.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_failover.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_cache, init_params, prefill
from repro.runtime import elastic, serve
from repro.runtime.checkpoint import CheckpointManager


def decode_on_ring(cfg, params, cache, tok0, mesh, plan, steps):
    """Permute the logical cache for this ring plan and decode."""
    stages = mesh.shape["data"]
    tp = mesh.shape["model"]
    pr = serve.pad_vocab(dict(params), cfg, tp)
    pr["blocks"] = serve.pad_and_permute(params["blocks"], cfg, stages,
                                         plan.k)
    rc = dict(cache)
    rc["layers"] = serve.pad_and_permute(cache["layers"], cfg, stages,
                                         plan.k)
    step = serve.build_ring_serve_step(cfg, mesh, plan)(pr, rc)
    ln = rc["len"]
    tok = tok0
    out = []
    for _ in range(steps):
        logits, rc = step(tok, ln, pr, rc)
        ln = ln + 1
        tok = jnp.argmax(logits[:, 0, :cfg.vocab], -1)[:, None]
        out.append(tok)
    return out


def main():
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, ctx = 8, 64
    prompt = jax.random.randint(key, (B, 8), 0, cfg.vocab)

    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    logits, cache = prefill(params, cfg, prompt, cache)
    tok0 = jnp.argmax(logits[:, -1], -1)[:, None]

    # checkpoint the logical (un-permuted) decode state
    ckpt_dir = tempfile.mkdtemp(prefix="elastic_")
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(0, (cache, tok0))
    print(f"checkpointed post-prefill state -> {ckpt_dir}")

    # ---- healthy ring: 4 stages ----------------------------------------
    st = elastic.initial_state(cfg, 4, k=2)
    print(f"gen-{st.generation}: {len(st.stages)} stages, k={st.plan.k}, "
          f"w={st.plan.w}")
    mesh4 = jax.make_mesh((4, 2), ("data", "model"))
    toks_healthy = decode_on_ring(cfg, params, cache, tok0, mesh4,
                                  st.plan, steps=3)
    print("tokens (healthy)  :",
          jnp.concatenate(toks_healthy, 1)[0].tolist())

    # ---- two stages die -> re-plan on 2 stages, restore, replay ---------
    st = elastic.fail_stages(st, cfg, [2, 3])
    print(f"gen-{st.generation}: {len(st.stages)} stages survive, "
          f"k={st.plan.k}, w={st.plan.w}")
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    _, (cache_r, tok_r) = mgr.restore_latest(
        (jax.tree.map(jnp.zeros_like, cache), tok0))
    toks_failover = decode_on_ring(cfg, params, cache_r, tok_r, mesh2,
                                   st.plan, steps=3)
    print("tokens (failover) :",
          jnp.concatenate(toks_failover, 1)[0].tolist())

    same = all(bool((a == b).all())
               for a, b in zip(toks_healthy, toks_failover))
    print("failover reproduces the pre-failure stream:", same)
    assert same


if __name__ == "__main__":
    main()
