"""Speculative decoding end to end: analytics + a real draft/verify loop.

First prices the paper's 32B scenario (qwen1.5-32b drafted by
qwen1.5-0.5b) on the low-resource slice of the Table-2 cluster, then
runs a *real* (reduced-size) draft/verify loop on CPU through the
ContinuousBatcher and checks the output is byte-identical to vanilla
greedy decode.

    PYTHONPATH=src python examples/speculative_decode.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import halda
from repro.core.latency import speculative_estimate
from repro.core.profiles import (paper_table2_cluster, paper_table2_extra,
                                 profile_from_config)
from repro.core.simulator import simulate_ring, simulate_speculative
from repro.models import decode_step, init_cache, init_params, prefill
from repro.runtime.engine import ContinuousBatcher
from repro.runtime.speculative import SpeculativeDecoder


def analytic():
    # Mac M1 + phone + Mac Air: the disk-bound regime speculation targets
    full, extra = paper_table2_cluster(), paper_table2_extra()
    devices = [full[0], full[3], extra[1]]
    target = profile_from_config(get_config("qwen1.5-32b"))
    draft = profile_from_config(get_config("qwen1.5-0.5b"))
    sol = halda.solve(devices, target)
    vanilla = simulate_ring(devices, target, sol.w, sol.n)
    d_lat = halda.solve([devices[0]], draft).latency
    spec = simulate_speculative(devices, target, sol.w, sol.n, gamma=6,
                                acceptance=0.8, draft_token_latency=d_lat)
    est = speculative_estimate(devices, target, sol.w, sol.n, gamma=6,
                               acceptance=0.8, draft_token_latency=d_lat,
                               cases=sol.cases)
    print(f"vanilla : {vanilla.token_latency_ms:7.0f} ms/token "
          f"({1 / vanilla.token_latency:.2f} tok/s)")
    print(f"spec    : {spec.token_latency_ms:7.0f} ms/token "
          f"({spec.tps:.2f} tok/s) — "
          f"{spec.tps * vanilla.token_latency:.2f}x, "
          f"E[tok/cycle]={spec.tokens_per_cycle:.2f}")
    print(f"analytic: {est.tpot * 1e3:7.0f} ms/token "
          f"(speedup {est.speedup:.2f}x)")


def real_loop():
    t_cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                                n_layers=2)
    d_cfg = dataclasses.replace(t_cfg, d_model=32, d_ff=64, name="draft")
    t_params = init_params(t_cfg, jax.random.PRNGKey(0))
    d_params = init_params(d_cfg, jax.random.PRNGKey(9))
    B, ctx, gamma, n_new = 1, 64, 3, 16
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (6,), 0, t_cfg.vocab))

    # vanilla greedy reference
    c = init_cache(t_cfg, 1, ctx, dtype=jnp.float32)
    lg, c = prefill(t_params, t_cfg, jnp.asarray(prompt)[None], c)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    want = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        lg, c = decode_step(t_params, t_cfg, c, tok)
        tok = jnp.argmax(lg[:, 0], -1)[:, None]
        want.append(int(tok[0, 0]))

    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == B and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new

    def prefill_one(p):
        c1 = init_cache(t_cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(t_params, t_cfg, p, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def d_prefill_one(p):
        c1 = init_cache(d_cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(d_params, d_cfg, p, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    spec = SpeculativeDecoder(
        lambda cc, t: decode_step(d_params, d_cfg, cc, t),
        lambda cc, t: decode_step(t_params, t_cfg, cc, t),
        gamma=gamma,
        draft_cache=init_cache(d_cfg, B, ctx, dtype=jnp.float32),
        draft_prefill_one=d_prefill_one, draft_write_slot=write_slot)
    eng = ContinuousBatcher(
        B, prefill_one, write_slot,
        lambda cc, t: decode_step(t_params, t_cfg, cc, t), spec=spec)

    class Req:
        uid = 0
        max_new_tokens = n_new
    Req.prompt = prompt
    cache = init_cache(t_cfg, B, ctx, dtype=jnp.float32)
    finished, steps = eng.run(cache, [Req()])
    got = finished[0].tokens
    rate = finished[0].acceptance_rate
    print(f"speculative loop: {len(got)} tokens in {steps} engine steps "
          f"(gamma={gamma}, acceptance={rate:.2f})")
    print("byte-identical to vanilla greedy:", got == want)


if __name__ == "__main__":
    print("== analytic (32B on Mac M1 + phone + Mac Air) ==")
    analytic()
    print("\n== real reduced-model draft/verify loop (CPU) ==")
    real_loop()
