"""Heterogeneous scheduling scenarios (paper §4 + A.5):

  * Halda vs the baseline layer-assignment strategies on the Table-2
    cluster across model scales;
  * automated device-subset selection ("is more devices always better?");
  * straggler mitigation: a slow TPU stage gets a smaller window.

    PYTHONPATH=src python examples/heterogeneous_cluster.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_config
from repro.core import baselines, cluster, halda
from repro.core.profiles import (paper_table2_cluster, paper_table2_extra,
                                 profile_from_config, tpu_stage_cluster)
from repro.core.simulator import simulate_ring


def main():
    devices = paper_table2_cluster()

    print("=== Halda vs baselines (simulated ms/token) ===")
    for cid in ("llama3-8b", "llama1-30b", "llama3-70b"):
        mp = profile_from_config(get_config(cid))
        line = [f"{cid:12s}"]
        sol = halda.solve(devices, mp)
        sim = simulate_ring(devices, mp, sol.w, sol.n)
        line.append(f"halda={sim.token_latency_ms:7.0f}ms(k={sol.k})")
        for name, strat in baselines.STRATEGIES.items():
            b = strat(devices, mp)
            active = [i for i, w in enumerate(b.w) if w > 0]
            bs = simulate_ring([devices[i] for i in active], mp,
                               [b.w[i] for i in active],
                               [b.n[i] for i in active])
            line.append(f"{name}={bs.token_latency_ms:7.0f}ms")
        print("  ".join(line))

    print("\n=== device-subset selection (70B) ===")
    all_devs = devices + paper_table2_extra()
    mp = profile_from_config(get_config("llama3-70b"))
    choice = cluster.select_cluster(all_devs, mp)
    names = [all_devs[i].name for i in choice.devices]
    print(f"best cluster: {names} "
          f"({choice.solution.latency * 1e3:.0f} ms analytic)")
    for devs_idx, lat in choice.history:
        print(f"  tried {len(devs_idx)} devices -> {lat * 1e3:.0f} ms")

    print("\n=== straggler mitigation on a TPU pod (4 stages) ===")
    stages = tpu_stage_cluster(4)
    slow = dataclasses.replace(
        stages[2], name="straggler",
        gpu_flops={q: v * 0.25 for q, v in stages[2].gpu_flops.items()})
    sol = halda.solve([stages[0], stages[1], slow, stages[3]], mp)
    print(f"windows: {sol.w} (straggler at index 2 gets the smallest)")


if __name__ == "__main__":
    main()
