"""Quickstart: Halda-scheduled piped-ring inference in 60 lines.

Builds the paper's Table-2 home cluster, solves the layer-to-device
assignment for a 70B-class model, simulates the piped ring, and then runs
a *real* (reduced-size) model through the same schedule on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import halda
from repro.core.profiles import paper_table2_cluster, profile_from_config
from repro.core.ring import build_schedule, validate_schedule
from repro.core.simulator import simulate_ring
from repro.models import decode_step, init_cache, init_params, prefill


def main():
    # --- 1. schedule a 70B model onto the paper's home cluster ----------
    devices = paper_table2_cluster()
    model = profile_from_config(get_config("llama3-70b"))
    sol = halda.solve(devices, model)
    print(f"Halda: w={sol.w} n={sol.n} k={sol.k} "
          f"analytic latency {sol.latency * 1e3:.0f} ms/token")

    sched = build_schedule(sol.w, sol.n, model.n_layers)
    validate_schedule(sched)
    print(f"ring schedule: {len(sched.windows)} windows, "
          f"{sched.k} round(s) per token")

    sim = simulate_ring(devices, model, sol.w, sol.n)
    print(f"simulated: {sim.token_latency_ms:.0f} ms/token, "
          f"TTFT {sim.ttft * 1e3:.0f} ms, "
          f"peak pressure {max(sim.memory_pressure.values()):.1%}")

    # --- 2. run a real (reduced) model end to end ------------------------
    cfg = get_config("qwen2.5-14b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, batch=2, max_len=64, dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    for _ in range(8):
        logits, cache = decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        out.append(tok)
    print("generated ids:", jnp.concatenate(out, 1)[0].tolist())


if __name__ == "__main__":
    main()
