"""End-to-end serving driver: batched requests through prefill + the SPMD
piped-ring decode on a multi-device mesh (deliverable b's serve driver).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/ring_serving.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import ByteTokenizer, RequestGenerator
from repro.models import init_cache, init_params, prefill
from repro.runtime import serve


def main():
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              n_layers=8)   # 2 layers/stage -> k in {1,2}
    stages, tp = 4, 2
    mesh = jax.make_mesh((stages, tp), ("data", "model"))
    B, ctx, new_tokens = 8, 64, 12

    params = init_params(cfg, jax.random.PRNGKey(0))
    gen = RequestGenerator(cfg.vocab, prompt_len=(12, 13), seed=7)
    reqs = gen.generate(B)
    prompts = jnp.asarray(np.stack([r.prompt for r in reqs]))

    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    t0 = time.time()
    logits, cache = prefill(params, cfg, prompts, cache)
    print(f"prefill {B}x{prompts.shape[1]}: {time.time() - t0:.2f}s")

    plan = serve.RingPlan.make(cfg, stages, k=2)
    pr = serve.pad_vocab(dict(params), cfg, tp)
    pr["blocks"] = serve.pad_and_permute(params["blocks"], cfg, stages,
                                         plan.k)
    # int4 weight bank + dequant-in-kernel compute (the §Perf HC2 path)
    pr, skipped = serve.quantize_ring_params(pr, cfg, tp=tp)
    if skipped:
        print(f"warning: {len(skipped)} leaves left bf16: {skipped}")
    cache["layers"] = serve.pad_and_permute(cache["layers"], cfg, stages,
                                            plan.k)
    step = serve.build_ring_serve_step(cfg, mesh, plan)(pr, cache)

    ln = cache["len"]
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for _ in range(new_tokens):
        logits, cache = step(tok, ln, pr, cache)
        ln = ln + 1
        tok = jnp.argmax(logits[:, 0, :cfg.vocab], -1)[:, None]
        outs.append(tok)
    dt = time.time() - t0
    print(f"ring decode (M={stages}, TP={tp}, k={plan.k}, int4 weights): "
          f"{new_tokens} steps in {dt:.2f}s "
          f"({dt / new_tokens * 1e3:.0f} ms/step for {B} seqs)")
    ids = jnp.concatenate(outs, 1)
    print("first sequence ids:", ids[0].tolist())


if __name__ == "__main__":
    main()
