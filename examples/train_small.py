"""End-to-end training driver (deliverable b): train a ~100M-param dense
model for a few hundred steps on the synthetic corpus, with periodic
checkpointing and restart.

    PYTHONPATH=src python examples/train_small.py --steps 200
Resumable:
    PYTHONPATH=src python examples/train_small.py --steps 300 --resume
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--arch") for a in args):
        # ~100M params: 8 layers of d=768 qwen-style dense blocks
        args = ["--arch", "qwen2.5-14b", "--smoke", "--d-model", "768",
                "--n-layers", "8", "--batch", "8", "--seq", "128",
                "--steps", "200", "--ckpt-every", "50"] + args
    raise SystemExit(train_main(args))
