"""Ring schedule construction + the SPMD ring permutation."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from conftest import hypothesis_fallback as _hf
    given, settings, st = _hf.given, _hf.settings, _hf.st

from repro.core.ring import build_schedule, validate_schedule
from repro.runtime.serve import padded_layers, ring_permutation


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.data())
def test_schedule_bijective(m, k, data):
    w = [data.draw(st.integers(1, 4)) for _ in range(m)]
    W = sum(w)
    L = W * k
    n = [data.draw(st.integers(0, wi)) for wi in w]
    s = build_schedule(w, n, L)
    validate_schedule(s)                       # every layer exactly once
    assert s.k == k
    assert len(s.windows) == k * m


def test_schedule_rejects_nondivisible():
    with pytest.raises(ValueError):
        build_schedule([2, 3], [0, 0], 11)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.sampled_from([1, 2, 4, 8, 16]),
       st.sampled_from([1, 2, 4]))
def test_ring_permutation_bijection(L, M, k):
    L_pad = padded_layers(L, M)
    per_stage = L_pad // M
    if per_stage % k:
        return
    perm = ring_permutation(L_pad, M, k)
    assert sorted(perm.tolist()) == list(range(L_pad))
    # stage m's block holds windows {r*M + m}: consecutive rows within a
    # window are consecutive layers
    w = L_pad // (M * k)
    for m in range(M):
        blk = perm[m * k * w:(m + 1) * k * w]
        for r in range(k):
            win = blk[r * w:(r + 1) * w]
            assert list(np.diff(win)) == [1] * (w - 1)
            assert win[0] == (r * M + m) * w


def test_schedule_L_not_divisible_by_window_count():
    """L % sum(w) != 0 violates Assumption 1 for any window split."""
    for w in ([3, 2], [4], [1, 1, 1]):
        L = sum(w) * 2 + 1                     # never divisible
        with pytest.raises(ValueError):
            build_schedule(w, [0] * len(w), L)


def test_schedule_single_device_ring():
    """M=1 degenerates to k rounds of one window covering everything."""
    s = build_schedule([4], [2], 12)
    validate_schedule(s)
    assert s.k == 3
    assert len(s.windows) == 3
    assert all(win.device == 0 for win in s.windows)
    assert all(win.n_resident == 2 for win in s.windows)
    assert s.layer_owner(0).round == 0
    assert s.layer_owner(11).round == 2


def test_schedule_zero_layer_device_skipped():
    """A device with w_m == 0 (llama.cpp-style baselines) leaves the ring;
    coverage and ownership must still be exact."""
    s = build_schedule([0, 3, 3], [0, 1, 0], 12)
    validate_schedule(s)
    assert s.k == 2
    assert s.device_windows(0) == []
    assert {win.device for win in s.windows} == {1, 2}
    # every layer resolves to a non-skipped device
    for layer in range(12):
        assert s.layer_owner(layer).device in (1, 2)
    # n_resident is clamped into the window
    assert all(0 <= win.n_resident <= win.n_layers for win in s.windows)


def test_schedule_all_devices_zero_raises():
    with pytest.raises(ValueError):
        build_schedule([0, 0], [0, 0], 8)


def test_schedule_zero_layer_device_streamed_counts():
    """n_streamed = w - n_resident feeds the streaming runtime's per-window
    disk accounting; a fully-resident window streams nothing."""
    s = build_schedule([2, 2], [2, 0], 8)
    for win in s.windows:
        if win.device == 0:
            assert win.n_streamed == 0
        else:
            assert win.n_streamed == win.n_layers


def test_padded_layers():
    assert padded_layers(32, 16) == 32
    assert padded_layers(62, 16) == 64
    assert padded_layers(38, 16) == 48
    assert padded_layers(4, 16) == 16
