"""Ring schedule construction + the SPMD ring permutation."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from conftest import hypothesis_fallback as _hf
    given, settings, st = _hf.given, _hf.settings, _hf.st

from repro.core.ring import build_schedule, validate_schedule
from repro.runtime.serve import padded_layers, ring_permutation


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.data())
def test_schedule_bijective(m, k, data):
    w = [data.draw(st.integers(1, 4)) for _ in range(m)]
    W = sum(w)
    L = W * k
    n = [data.draw(st.integers(0, wi)) for wi in w]
    s = build_schedule(w, n, L)
    validate_schedule(s)                       # every layer exactly once
    assert s.k == k
    assert len(s.windows) == k * m


def test_schedule_rejects_nondivisible():
    with pytest.raises(ValueError):
        build_schedule([2, 3], [0, 0], 11)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.sampled_from([1, 2, 4, 8, 16]),
       st.sampled_from([1, 2, 4]))
def test_ring_permutation_bijection(L, M, k):
    L_pad = padded_layers(L, M)
    per_stage = L_pad // M
    if per_stage % k:
        return
    perm = ring_permutation(L_pad, M, k)
    assert sorted(perm.tolist()) == list(range(L_pad))
    # stage m's block holds windows {r*M + m}: consecutive rows within a
    # window are consecutive layers
    w = L_pad // (M * k)
    for m in range(M):
        blk = perm[m * k * w:(m + 1) * k * w]
        for r in range(k):
            win = blk[r * w:(r + 1) * w]
            assert list(np.diff(win)) == [1] * (w - 1)
            assert win[0] == (r * M + m) * w


def test_padded_layers():
    assert padded_layers(32, 16) == 32
    assert padded_layers(62, 16) == 64
    assert padded_layers(38, 16) == 48
    assert padded_layers(4, 16) == 16
