"""Event-driven simulator: reproduces the paper's qualitative claims."""
import numpy as np
import pytest

from repro.core.profiles import (GiB, OS, DeviceProfile, ModelProfile,
                                 QUANTS)
from repro.core.simulator import simulate_ring, simulate_tp
from repro.core.latency import _sum_q


def uniform_cluster(n=4, ram_gib=8.0, disk=2e9):
    return [DeviceProfile(name=f"L{i}", os=OS.LINUX, ram_avail=ram_gib * GiB,
                          cpu_flops={q: 200e9 for q in QUANTS},
                          cpu_membw=30e9, disk_seq_bps=disk,
                          disk_rand_bps=disk / 2, t_comm=2e-3)
            for i in range(n)]


def model(n_layers, layer_gib):
    return ModelProfile(
        name="m", n_layers=n_layers, layer_bytes=layer_gib * GiB,
        input_bytes=0.25 * GiB, output_bytes=0.25 * GiB, embed_dim=8192,
        vocab=32000, kv_heads=8, head_dim=128, n_kv=1024,
        flops_layer={"q4k": 2 * layer_gib * GiB / 0.5625},
        flops_output={"q4k": 2 * 8192 * 32000})


def test_fig2_insufficient_memory_prefers_k_gt_1():
    """Paper Fig. 2: with insufficient memory, piped-ring (k>1) roughly
    halves latency or better vs k=1 (prefetch-release regime)."""
    devs = uniform_cluster()
    mp = model(80, 0.48)               # 38 GiB > 32 GiB cluster RAM
    lat = {}
    for k in (1, 2, 4):
        w = [80 // (4 * k)] * 4
        lat[k] = simulate_ring(devs, mp, w, [0] * 4).token_latency
    assert lat[2] < 0.6 * lat[1]
    assert lat[4] < 0.8 * lat[1]


def test_fig2_sufficient_memory_prefers_k_1():
    devs = uniform_cluster()
    mp = model(60, 0.40)               # 24 GiB < 32 GiB: fits
    w1 = simulate_ring(devs, mp, [15] * 4, [0] * 4).token_latency
    w5 = simulate_ring(devs, mp, [3] * 4, [0] * 4).token_latency
    assert w5 >= w1                     # fragmentation overhead only
    assert w5 <= w1 * 1.2               # and it is mild


def test_prefetch_reduces_latency_under_overload():
    devs = uniform_cluster()
    mp = model(80, 0.48)
    w = [10] * 4
    with_pf = simulate_ring(devs, mp, w, [0] * 4, prefetch=True)
    without = simulate_ring(devs, mp, w, [0] * 4, prefetch=False)
    assert with_pf.token_latency <= without.token_latency
    # paper reports 9-17%; accept any strictly positive overlap
    assert with_pf.token_latency < without.token_latency


def test_prefetch_noop_when_memory_sufficient():
    devs = uniform_cluster()
    mp = model(60, 0.4)
    w = [15] * 4
    a = simulate_ring(devs, mp, w, [0] * 4, prefetch=True)
    b = simulate_ring(devs, mp, w, [0] * 4, prefetch=False)
    assert a.token_latency == pytest.approx(b.token_latency, rel=1e-6)


def test_simulator_not_below_compute_lower_bound():
    devs = uniform_cluster()
    mp = model(16, 0.1)
    w = [4] * 4
    res = simulate_ring(devs, mp, w, [0] * 4)
    per_layer = _sum_q(mp.flops_layer, devs[0].cpu_flops)
    lower = mp.n_layers * per_layer     # compute only, zero comm/disk
    assert res.token_latency >= lower * 0.99


def test_resident_weights_oom_and_pressure():
    devs = uniform_cluster(ram_gib=2.0)
    mp = model(80, 0.48)                # 38 GiB into 8 GiB: hopeless
    res = simulate_ring(devs, mp, [20] * 4, [0] * 4, resident_weights=True)
    assert res.oom
    assert max(res.memory_pressure.values()) > 0.5
    # mmap path on the same cluster: low pressure, no OOM
    res2 = simulate_ring(devs, mp, [20] * 4, [0] * 4)
    assert not res2.oom
    assert max(res2.memory_pressure.values()) < 0.3


def test_tp_slower_than_ring_on_wifi():
    """dllama-style TP pays two all-reduces every layer over slow Wi-Fi
    links (RTT ~8 ms); the ring pays M hops per round in total."""
    devs = [DeviceProfile(name=f"L{i}", os=OS.LINUX, ram_avail=8 * GiB,
                          cpu_flops={q: 200e9 for q in QUANTS},
                          cpu_membw=30e9, disk_seq_bps=2e9,
                          disk_rand_bps=1e9, t_comm=8e-3)
            for i in range(4)]
    mp = model(32, 0.2)
    ring = simulate_ring(devs, mp, [8] * 4, [0] * 4)
    tp = simulate_tp(devs, mp)
    assert tp.token_latency > ring.token_latency


# --------------------------------------------------------------------------- #
#  speculative decoding analytics (acceptance-aware TPOT/TPS)
# --------------------------------------------------------------------------- #

def test_verify_pass_cheaper_than_T_single_passes():
    """A (gamma+1)-token verify pass streams weights once, so it must cost
    far less than gamma+1 single-token passes in the disk-bound regime —
    the amortization speculative decoding banks on. Both the analytic
    model and the simulator must agree on the direction."""
    from repro.core.latency import token_latency
    devs = uniform_cluster()
    mp = model(80, 0.48)               # overloads the cluster: disk-bound
    w, n = [20] * 4, [0] * 4
    T = 5
    t1 = token_latency(devs, mp, w, n)
    tT = token_latency(devs, mp, w, n, seq=T)
    assert t1 < tT < 0.5 * T * t1
    s1 = simulate_ring(devs, mp, w, n).token_latency
    sT = simulate_ring(devs, mp, w, n, decode_seq=T).token_latency
    assert s1 < sT < 0.5 * T * s1


def test_token_latency_seq1_unchanged_by_seq_arg():
    from repro.core.latency import token_latency
    devs = uniform_cluster()
    mp = model(80, 0.48)
    w, n = [20] * 4, [0] * 4
    assert token_latency(devs, mp, w, n) == \
        token_latency(devs, mp, w, n, seq=1)


def test_speculative_estimate_and_simulator_speedup():
    """At acceptance 0.75+ the spec TPS model must beat vanilla decode,
    and degrade gracefully to ~vanilla at acceptance 0."""
    from repro.core.latency import speculative_estimate, token_latency
    from repro.core.simulator import simulate_speculative
    devs = uniform_cluster()
    mp = model(80, 0.48)
    w, n = [20] * 4, [0] * 4
    t_vanilla = token_latency(devs, mp, w, n)
    draft = 0.01 * t_vanilla
    est = speculative_estimate(devs, mp, w, n, gamma=4, acceptance=0.8,
                               draft_token_latency=draft)
    assert est.speedup > 1.5
    assert abs(est.tps * est.tpot - 1.0) < 1e-9
    est0 = speculative_estimate(devs, mp, w, n, gamma=4, acceptance=0.0,
                                draft_token_latency=draft)
    assert est0.speedup < 1.0          # pure overhead when nothing accepted
    # monotone in acceptance
    prev = 0.0
    for a in (0.25, 0.5, 0.75, 0.9):
        e = speculative_estimate(devs, mp, w, n, gamma=4, acceptance=a,
                                 draft_token_latency=draft)
        assert e.tps > prev
        prev = e.tps
    # simulator-side: same direction
    sim = simulate_speculative(devs, mp, w, n, gamma=4, acceptance=0.8,
                               draft_token_latency=draft)
    vanilla = simulate_ring(devs, mp, w, n).token_latency
    assert sim.token_latency < vanilla
    assert sim.tokens_per_cycle > 3.0


def test_classify_cases_matches_scalar():
    from repro.core.latency import classify_cases, classify_device
    devs = uniform_cluster(4, ram_gib=4.0) + uniform_cluster(2, ram_gib=16.0)
    mp = model(80, 0.48)
    rng = np.random.default_rng(1)
    for _ in range(50):
        w = rng.integers(1, 30, len(devs)).tolist()
        n = [0] * len(devs)
        k = max(int(round(mp.n_layers / sum(w))), 1)
        want = [int(classify_device(d, i, mp, w[i], n[i], k))
                for i, d in enumerate(devs)]
        got = classify_cases(devs, mp, w, n, k).tolist()
        assert want == got
