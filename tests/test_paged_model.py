"""Paged KV-cache model paths + engine integration.

The contract under test: paging changes where KV lives (block pool +
per-slot tables), never what attention computes — greedy decode through
the paged paths must be *byte-identical* to the dense cache, including
the T > 1 speculative verify/rollback path, prefix-shared admits, CoW
divergence and offload round trips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, decode_step_paged, init_cache,
                          init_params, prefill, rollback_cache)
from repro.runtime.engine import make_dense_engine
from repro.runtime.kvcache import PagedKVCache, make_paged_engine
from repro.runtime.speculative import SpeculativeDecoder

KEY = jax.random.PRNGKey(0)


def _small(arch, n_layers=2):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers)


def _admit_direct(kv, cache, cfg, params, prompts, ctx, max_new=20):
    """Prefill each sequence separately and install it into the pages."""
    firsts = []
    for b in range(prompts.shape[0]):
        c1 = init_cache(cfg, 1, ctx, dtype=jnp.float32)
        lg, c1 = prefill(params, cfg, prompts[b:b + 1], c1)
        kv.plan_admit(cache, b, [int(t) for t in np.asarray(prompts[b])],
                      max_new)
        cache = kv.install(cache, b, c1["layers"], prompts.shape[1])
        firsts.append(int(jnp.argmax(lg[0, -1])))
    return cache, firsts


class _Req:
    def __init__(self, uid, prompt, max_new):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new


def _write_slot(B):
    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == B and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new
    return write_slot


def _dense_engine(cfg, params, B, ctx):
    return make_dense_engine(params, cfg, B, ctx)


# --------------------------------------------------------------------------- #
#  byte-identical decode: dense vs paged (dense attention + MLA)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ["qwen2.5-14b", "minicpm3-4b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_paged_greedy_decode_byte_identical(arch):
    cfg = _small(arch)
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 5), 0,
                                 cfg.vocab)

    c = init_cache(cfg, B, ctx, dtype=jnp.float32)
    lg, c = prefill(params, cfg, prompts, c)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    dense = [np.asarray(tok[:, 0]).tolist()]
    for _ in range(6):
        lg, c = decode_step(params, cfg, c, tok)
        tok = jnp.argmax(lg[:, 0], -1)[:, None]
        dense.append(np.asarray(tok[:, 0]).tolist())

    kv = PagedKVCache(cfg, batch=B, ctx=ctx, n_pages=32, page_tokens=8)
    try:
        cache, firsts = _admit_direct(kv, kv.init_cache(), cfg, params,
                                      prompts, ctx)
        tok = jnp.asarray(firsts)[:, None]
        paged = [np.asarray(tok[:, 0]).tolist()]
        for _ in range(6):
            cache = kv.begin_step(cache, [0, 1], 1)
            lg, cache = decode_step_paged(params, cfg, cache, tok)
            kv.advance(0), kv.advance(1)
            tok = jnp.argmax(lg[:, 0], -1)[:, None]
            paged.append(np.asarray(tok[:, 0]).tolist())
        assert dense == paged
        kv.pool.check()
    finally:
        kv.close()


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "minicpm3-4b"])
def test_paged_multi_token_verify_matches_dense(arch):
    """T > 1 verify logits identical to dense, spanning page boundaries."""
    cfg = _small(arch)
    params = init_params(cfg, KEY)
    B, ctx, T = 2, 64, 5
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                                 cfg.vocab)
    c = init_cache(cfg, B, ctx, dtype=jnp.float32)
    _, c = prefill(params, cfg, prompts, c)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    lg_d, c_d = decode_step(params, cfg, c, toks)

    kv = PagedKVCache(cfg, batch=B, ctx=ctx, n_pages=32, page_tokens=8)
    try:
        cache, _ = _admit_direct(kv, kv.init_cache(), cfg, params,
                                 prompts, ctx)
        cache = kv.begin_step(cache, [0, 1], T)    # 6 + 5 crosses a page
        lg_p, c_p = decode_step_paged(params, cfg, cache, toks)
        assert jnp.array_equal(lg_d, lg_p)
        np.testing.assert_array_equal(np.asarray(c_p["len"]),
                                      np.asarray(c_d["len"]))
        kv.pool.check()
    finally:
        kv.close()


def test_paged_rollback_then_decode_matches_prefix():
    """Paged rollback = reset len + free pages past the accepted length;
    decoding afterwards must equal the dense rolled-back cache."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx, T, keep = 2, 64, 4, 2
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                                 cfg.vocab)
    c0 = init_cache(cfg, B, ctx, dtype=jnp.float32)
    _, c0 = prefill(params, cfg, prompts, c0)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    _, c_spec = decode_step(params, cfg, c0, toks)
    c_rb = rollback_cache(c_spec, c0["len"] + keep)
    probe = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, cfg.vocab)
    lg_ref, _ = decode_step(params, cfg, c_rb, probe)

    kv = PagedKVCache(cfg, batch=B, ctx=ctx, n_pages=32, page_tokens=8)
    try:
        cache, _ = _admit_direct(kv, kv.init_cache(), cfg, params,
                                 prompts, ctx)
        cache = kv.begin_step(cache, [0, 1], T)
        _, cache = decode_step_paged(params, cfg, cache, toks)
        cache = rollback_cache(cache, jnp.asarray([6 + keep, 6 + keep]))
        for b in range(B):
            kv.trim_to(b, 6 + keep)
        cache = kv.begin_step(cache, [0, 1], 1)
        lg_p, _ = decode_step_paged(params, cfg, cache, probe)
        assert jnp.array_equal(lg_ref, lg_p)
        kv.pool.check()
    finally:
        kv.close()


# --------------------------------------------------------------------------- #
#  engine integration
# --------------------------------------------------------------------------- #

def test_paged_engine_parity_more_requests_than_slots():
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(3)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 14))),
                 5) for i in range(7)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        kv.pool.check()
        assert kv.pool.n_active == 0          # every slot released
    finally:
        kv.close()


def test_paged_engine_prefix_share_and_cow():
    """Identical prompts admitted together share every prompt page once
    and diverge via copy-on-write — with identical output streams."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, 19)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8)
    try:
        fin, _ = eng.run(kv.init_cache(),
                         [_Req(0, prompt, 5), _Req(1, prompt.copy(), 5)])
        by = {f.uid: f.tokens for f in fin}
        assert by[0] == by[1]
        st = kv.stats()
        assert st.prefix_hits == 3            # 2 full + 1 partial page
        assert st.cow_copies >= 1             # divergence page cloned
        kv.pool.check()
    finally:
        kv.close()


def test_paged_engine_offload_roundtrip_parity():
    """Churn past the pool size: cold prefix pages offload to host; a
    later identical prompt fetches them back and still matches the dense
    reference byte for byte."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(5)
    p0 = rng.integers(0, cfg.vocab, 16)
    reqs = [_Req(0, p0, 4)] + \
        [_Req(i, rng.integers(0, cfg.vocab, 16), 4) for i in range(1, 6)] \
        + [_Req(6, p0.copy(), 4)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=10,
                                page_tokens=8)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        st = kv.stats()
        assert st.evictions > 0 and st.fetched_bytes > 0
        assert len(st.fetch_events) >= 1
        kv.pool.check()
    finally:
        kv.close()


def test_paged_engine_speculative_byte_identical():
    """Paged target + speculative decoding == dense vanilla greedy, with
    rollback returning rejected-draft pages to the pool every cycle."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    dcfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                               n_layers=1, vocab=cfg.vocab)
    dparams = init_params(dcfg, jax.random.PRNGKey(7))
    B, ctx = 2, 64
    rng = np.random.default_rng(6)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 10))),
                 7) for i in range(4)]

    fin_v, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)

    def d_prefill_one(prompt):
        c1 = init_cache(dcfg, 1, ctx, dtype=jnp.float32)
        lg, c1 = prefill(dparams, dcfg, prompt, c1)
        return int(jnp.argmax(lg[0, -1])), c1

    spec = SpeculativeDecoder(
        lambda c, t: decode_step(dparams, dcfg, c, t), None, gamma=3,
        draft_cache=init_cache(dcfg, B, ctx, dtype=jnp.float32),
        draft_prefill_one=d_prefill_one, draft_write_slot=_write_slot(B))
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=48,
                                page_tokens=8, spec=spec)
    spec.verify = eng.decode
    try:
        fin_s, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_v} == \
            {f.uid: f.tokens for f in fin_s}
        kv.pool.check()
        assert kv.pool.n_active == 0
    finally:
        kv.close()


def test_paged_engine_defers_admit_under_transient_pressure():
    """A pool that can only hold one request at a time serializes the
    workload instead of crashing: admits wait for finishes to free
    pages, and every request is still served with correct tokens."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(9)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, 14), 4) for i in range(3)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    # 14-token prompt + 4 new = 2 prompt pages + boundary growth; 5
    # usable pages fit one request comfortably, never two
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=6,
                                page_tokens=8, offload=False)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        kv.pool.check()
    finally:
        kv.close()


# --------------------------------------------------------------------------- #
#  chunked admission (page-sized prefill chunks interleaved with decode)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ["qwen2.5-14b", "phi3.5-moe-42b-a6.6b"])
def test_chunked_admission_byte_identical(arch):
    """Prompts admitted in page-sized chunks (written straight into the
    block pool, interleaved with decode steps) must stream the exact
    bytes of the dense engine — first token included."""
    cfg = _small(arch)
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(3)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 30))),
                 5) for i in range(5)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8, prefill_chunk=8)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        kv.pool.check()
        assert kv.pool.n_active == 0
    finally:
        kv.close()


def test_chunked_admission_prefix_share_and_cow():
    """Pages written by a chunked admit are content-addressed like any
    other: an identical prompt reuses them across chunk boundaries
    (2 full + 1 partial page hit -> the whole prompt is a prefix hit,
    which exercises the write-free logits replay) and diverges via
    copy-on-write at the first generated token."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, 19)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8, prefill_chunk=8)
    try:
        fin, _ = eng.run(kv.init_cache(),
                         [_Req(0, prompt, 5), _Req(1, prompt.copy(), 5)])
        by = {f.uid: f.tokens for f in fin}
        assert by[0] == by[1]
        st = kv.stats()
        assert st.prefix_hits == 3            # same sharing as unchunked
        assert st.cow_copies >= 1             # divergence page cloned
        kv.pool.check()
    finally:
        kv.close()


def test_chunked_admission_partial_prefix_resumes_mid_prompt():
    """A shared 16-token prefix skips its pages and chunking resumes at
    the divergence offset — streams still match the dense engine."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(8)
    head = rng.integers(0, cfg.vocab, 16)
    reqs = [_Req(0, np.concatenate([head, rng.integers(0, cfg.vocab, 7)]),
                 5),
            _Req(1, np.concatenate([head, rng.integers(0, cfg.vocab, 9)]),
                 5)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8, prefill_chunk=8)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        assert kv.stats().prefix_hits >= 2    # the two full head pages
        kv.pool.check()
    finally:
        kv.close()


def test_chunked_admission_int8_pages_match_dense_int8():
    """int8 KV pages under chunked admission: the page round-trip
    quantizes per (token, kv-head) exactly like the dense int8 cache, so
    chunked greedy streams stay byte-identical to dense int8."""
    cfg = dataclasses.replace(_small("qwen2.5-14b"), kv_dtype="int8")
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    rng = np.random.default_rng(3)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 30))),
                 5) for i in range(5)]

    fin_d, _ = _dense_engine(cfg, params, B, ctx).run(
        init_cache(cfg, B, ctx, dtype=jnp.float32), reqs)
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=32,
                                page_tokens=8, prefill_chunk=8)
    try:
        fin_p, _ = eng.run(kv.init_cache(), reqs)
        assert {f.uid: f.tokens for f in fin_d} == \
            {f.uid: f.tokens for f in fin_p}
        kv.pool.check()
    finally:
        kv.close()


def test_paged_engine_rejects_only_on_exhaustion():
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=4,
                                page_tokens=8)
    try:
        from repro.runtime.kvcache import PoolExhausted

        with pytest.raises(PoolExhausted, match="exhausted"):
            eng.run(kv.init_cache(),
                    [_Req(0, np.arange(30) % cfg.vocab, 4)])
    finally:
        kv.close()
