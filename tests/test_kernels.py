"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode, flash_verify
from repro.kernels.q4_matmul import q4_matmul
from repro.kernels.ssd_scan import ssd_scan
from repro.quant import quantize_q4

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 256, 256, 128, 128, 128),
    (256, 512, 512, 128, 256, 256),
    (64, 128, 384, 64, 128, 64),
    (256, 1024, 128, 256, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q4_matmul_sweep(M, K, N, bm, bn, bk, dtype):
    x = jax.random.normal(KEY, (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    qt = quantize_q4(w)
    out = q4_matmul(x, qt.packed, qt.scale, block_m=bm, block_n=bn,
                    block_k=bk, interpret=True)
    want = ref.q4_matmul_ref(x, qt.packed, qt.scale)
    tol = 1e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("B,H,hkv,D,S,bs", [
    (2, 8, 2, 64, 512, 128),
    (1, 4, 4, 128, 1024, 256),   # MHA
    (3, 8, 1, 64, 256, 256),     # MQA
    (2, 16, 2, 32, 512, 512),
])
@pytest.mark.parametrize("window", [None, 128])
def test_flash_decode_sweep(B, H, hkv, D, S, bs, window):
    q = jax.random.normal(KEY, (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, size=B), jnp.int32)
    out = flash_decode(q, k, v, kv_len, window=window, block_s=bs,
                       interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T", [1, 2, 4, 8])
@pytest.mark.parametrize("B,H,hkv,D,S,bs", [
    (2, 8, 2, 64, 512, 128),
    (1, 4, 4, 128, 512, 256),    # MHA
    (3, 8, 1, 64, 256, 256),     # MQA
])
def test_flash_verify_sweep(T, B, H, hkv, D, S, bs):
    """Multi-query verify kernel vs the reference attention path, T draft
    positions with causal masking among the drafts (1e-3 acceptance bar)."""
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray(
        np.random.default_rng(T).integers(T, S + 1, size=B), jnp.int32)
    out = flash_verify(q, k, v, kv_len, block_s=bs, interpret=True)
    want = ref.flash_verify_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("T", [2, 4])
def test_flash_verify_window(T):
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray([S, S // 2], jnp.int32)
    out = flash_verify(q, k, v, kv_len, window=64, block_s=128,
                       interpret=True)
    want = ref.flash_verify_ref(q, k, v, kv_len, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_flash_verify_T1_matches_flash_decode():
    """T = 1 must reduce to ordinary decode attention."""
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray([S, S // 3], jnp.int32)
    out = flash_verify(q, k, v, kv_len, block_s=128, interpret=True)
    want = flash_decode(q[:, 0], k, v, kv_len, block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D), dtype)
    kv_len = jnp.full((B,), S, jnp.int32)
    out = flash_decode(q, k, v, kv_len, block_s=256, interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,nh,P,N,chunk", [
    (2, 256, 4, 32, 64, 64),
    (1, 128, 2, 64, 128, 128),
    (2, 512, 8, 16, 32, 128),
    (1, 192, 3, 32, 64, 64),     # S not a multiple of a power of two
])
def test_ssd_scan_sweep(B, S, nh, P, N, chunk):
    if S % chunk:
        pytest.skip("kernel requires S % chunk == 0")
    x = jax.random.normal(KEY, (B, S, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4),
                                           (B, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (nh,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(6), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(7), (B, S, N)) * 0.3
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_jnp_vs_sequential():
    """The model-layer chunked scan (used in training) against the O(S)
    recurrence."""
    B, S, nh, P, N = 2, 200, 4, 16, 32
    x = jax.random.normal(KEY, (B, S, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4),
                                           (B, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (nh,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(6), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(7), (B, S, N)) * 0.3
    y_c, h_c = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=64)
    y_r, h_r = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
