"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode, flash_verify
from repro.kernels.paged_decode import (paged_decode, paged_decode_quant,
                                        paged_verify, paged_verify_quant)
from repro.kernels.paged_prefill import paged_prefill
from repro.kernels.q4_matmul import q4_matmul
from repro.kernels.ssd_scan import ssd_scan
from repro.quant import quantize_q4

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 256, 256, 128, 128, 128),
    (256, 512, 512, 128, 256, 256),
    (64, 128, 384, 64, 128, 64),
    (256, 1024, 128, 256, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q4_matmul_sweep(M, K, N, bm, bn, bk, dtype):
    x = jax.random.normal(KEY, (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    qt = quantize_q4(w)
    out = q4_matmul(x, qt.packed, qt.scale, block_m=bm, block_n=bn,
                    block_k=bk, interpret=True)
    want = ref.q4_matmul_ref(x, qt.packed, qt.scale)
    tol = 1e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("B,H,hkv,D,S,bs", [
    (2, 8, 2, 64, 512, 128),
    (1, 4, 4, 128, 1024, 256),   # MHA
    (3, 8, 1, 64, 256, 256),     # MQA
    (2, 16, 2, 32, 512, 512),
])
@pytest.mark.parametrize("window", [None, 128])
def test_flash_decode_sweep(B, H, hkv, D, S, bs, window):
    q = jax.random.normal(KEY, (B, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, size=B), jnp.int32)
    out = flash_decode(q, k, v, kv_len, window=window, block_s=bs,
                       interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T", [1, 2, 4, 8])
@pytest.mark.parametrize("B,H,hkv,D,S,bs", [
    (2, 8, 2, 64, 512, 128),
    (1, 4, 4, 128, 512, 256),    # MHA
    (3, 8, 1, 64, 256, 256),     # MQA
])
def test_flash_verify_sweep(T, B, H, hkv, D, S, bs):
    """Multi-query verify kernel vs the reference attention path, T draft
    positions with causal masking among the drafts (1e-3 acceptance bar)."""
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray(
        np.random.default_rng(T).integers(T, S + 1, size=B), jnp.int32)
    out = flash_verify(q, k, v, kv_len, block_s=bs, interpret=True)
    want = ref.flash_verify_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("T", [2, 4])
def test_flash_verify_window(T):
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray([S, S // 2], jnp.int32)
    out = flash_verify(q, k, v, kv_len, window=64, block_s=128,
                       interpret=True)
    want = ref.flash_verify_ref(q, k, v, kv_len, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_flash_verify_T1_matches_flash_decode():
    """T = 1 must reduce to ordinary decode attention."""
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    kv_len = jnp.asarray([S, S // 3], jnp.int32)
    out = flash_verify(q, k, v, kv_len, block_s=128, interpret=True)
    want = flash_decode(q[:, 0], k, v, kv_len, block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T", [1, 3, 4])
@pytest.mark.parametrize("B,H,hkv,D,P,bs,nb", [
    (2, 8, 2, 64, 16, 16, 4),
    (1, 4, 4, 128, 8, 32, 3),    # MHA
    (3, 8, 1, 64, 32, 8, 6),     # MQA, small pages
])
def test_paged_verify_sweep(T, B, H, hkv, D, P, bs, nb):
    """Paged verify kernel (block-table gather through scalar prefetch)
    vs the gather-then-verify oracle; tables are random permutations so
    physical != logical page order."""
    q = jax.random.normal(KEY, (B, T, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    rng = np.random.default_rng(T)
    table = jnp.asarray(rng.permutation(P)[:B * nb].reshape(B, nb)
                        if P >= B * nb else
                        rng.integers(0, P, (B, nb)), jnp.int32)
    kv_len = jnp.asarray(rng.integers(T, nb * bs + 1, size=B), jnp.int32)
    out = paged_verify(q, kp, vp, table, kv_len, interpret=True)
    want = ref.paged_verify_ref(q, kp, vp, table, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_paged_verify_window_and_T1_decode():
    B, T, H, hkv, D, P, bs, nb = 2, 2, 8, 2, 64, 16, 16, 4
    q = jax.random.normal(KEY, (B, T, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    table = jnp.asarray(
        np.random.default_rng(0).permutation(P)[:B * nb].reshape(B, nb),
        jnp.int32)
    kv_len = jnp.asarray([nb * bs, 17], jnp.int32)
    out = paged_verify(q, kp, vp, table, kv_len, window=16,
                       interpret=True)
    want = ref.paged_verify_ref(q, kp, vp, table, kv_len, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    # T = 1 wrapper reduces to paged decode attention
    out1 = paged_decode(q[:, 0], kp, vp, table, kv_len, interpret=True)
    want1 = ref.paged_decode_ref(q[:, 0], kp, vp, table, kv_len)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(want1),
                               rtol=1e-5, atol=1e-5)


def test_paged_verify_contiguous_table_matches_flash_verify():
    """With an identity block table the paged kernel must reproduce the
    contiguous flash_verify on the same bytes."""
    B, T, H, hkv, D, bs, nb = 2, 4, 8, 2, 64, 64, 4
    S = bs * nb
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D))
    q = jax.random.normal(KEY, (B, T, H, D))
    kv_len = jnp.asarray([S, S // 2], jnp.int32)
    # pages: batch-major split of the contiguous caches
    kp = k.reshape(B * nb, bs, hkv, D)
    vp = v.reshape(B * nb, bs, hkv, D)
    table = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    out = paged_verify(q, kp, vp, table, kv_len, interpret=True)
    want = flash_verify(q, k, v, kv_len, block_s=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _int8_pages(pages):
    """Per-(position, kv-head) int8 quantization of float pages —
    ``layers.quantize_kv`` convention (scale = amax/127 over D)."""
    scale = jnp.max(jnp.abs(pages), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(pages / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


@pytest.mark.parametrize("S", [1, 4, 8])
@pytest.mark.parametrize("B,H,hkv,D,P,bs,nb", [
    (2, 8, 2, 64, 16, 16, 4),
    (1, 4, 4, 128, 8, 32, 3),    # MHA
    (3, 8, 1, 64, 32, 8, 6),     # MQA, small pages
])
def test_paged_prefill_sweep(S, B, H, hkv, D, P, bs, nb):
    """Chunked-prefill flash kernel vs the gather oracle: S chunk rows
    sit at absolute positions kv_len - S + t, tables are permuted, and
    kv_len sweeps partial pages so dead table entries must be skipped."""
    q = jax.random.normal(KEY, (B, S, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    rng = np.random.default_rng(S)
    table = jnp.asarray(rng.permutation(P)[:B * nb].reshape(B, nb)
                        if P >= B * nb else
                        rng.integers(0, P, (B, nb)), jnp.int32)
    kv_len = jnp.asarray(rng.integers(S, nb * bs + 1, size=B), jnp.int32)
    out = paged_prefill(q, kp, vp, table, kv_len, interpret=True)
    want = ref.paged_prefill_ref(q, kp, vp, table, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_paged_prefill_windowed_dead_page_guard():
    """Sliding-window regression: the dead-page guard must keep pages
    the *first* chunk row's window still reaches (its window starts at
    kv_len - S - window, up to S - 1 positions before the last row's) —
    cutting at kv_len - window silently zeros those contributions."""
    B, S, H, hkv, D, P, bs, nb = 1, 4, 4, 2, 64, 8, 8, 4
    q = jax.random.normal(KEY, (B, S, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    table = jnp.asarray([[3, 1, 5, 0]], jnp.int32)
    # kv_len 24, window 8: row 0 (abs pos 20) attends 13..20 — page 1
    # (positions 8..15) ends exactly at kv_len - window, so a guard
    # keyed on the last row drops it
    kv_len = jnp.asarray([24], jnp.int32)
    out = paged_prefill(q, kp, vp, table, kv_len, window=8,
                        interpret=True)
    want = ref.paged_prefill_ref(q, kp, vp, table, kv_len, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_prefill_S1_matches_paged_decode():
    """A one-token chunk is exactly paged decode attention."""
    B, H, hkv, D, P, bs, nb = 2, 8, 2, 64, 16, 16, 4
    q = jax.random.normal(KEY, (B, 1, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    table = jnp.asarray(
        np.random.default_rng(0).permutation(P)[:B * nb].reshape(B, nb),
        jnp.int32)
    kv_len = jnp.asarray([nb * bs, 21], jnp.int32)
    out = paged_prefill(q, kp, vp, table, kv_len, interpret=True)
    want = paged_decode(q[:, 0], kp, vp, table, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T", [1, 3])
@pytest.mark.parametrize("B,H,hkv,D,P,bs,nb", [
    (2, 8, 2, 64, 16, 16, 4),
    (1, 4, 4, 128, 8, 32, 3),    # MHA
    (3, 8, 1, 64, 32, 8, 6),     # MQA
])
def test_paged_verify_quant_sweep(T, B, H, hkv, D, P, bs, nb):
    """int8-KV paged verify with in-kernel dequant vs the
    dequantize-then-attend oracle on the same quantized bytes."""
    q = jax.random.normal(KEY, (B, T, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    kq, ks = _int8_pages(kp)
    vq, vs = _int8_pages(vp)
    rng = np.random.default_rng(T)
    table = jnp.asarray(rng.permutation(P)[:B * nb].reshape(B, nb)
                        if P >= B * nb else
                        rng.integers(0, P, (B, nb)), jnp.int32)
    kv_len = jnp.asarray(rng.integers(T, nb * bs + 1, size=B), jnp.int32)
    out = paged_verify_quant(q, kq, vq, ks, vs, table, kv_len,
                             interpret=True)
    want = ref.paged_verify_quant_ref(q, kq, vq, ks, vs, table, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_paged_decode_quant_window_and_oracle():
    B, H, hkv, D, P, bs, nb = 2, 8, 2, 64, 16, 16, 4
    q = jax.random.normal(KEY, (B, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(2), (P, bs, hkv, D))
    vp = jax.random.normal(jax.random.PRNGKey(3), (P, bs, hkv, D))
    kq, ks = _int8_pages(kp)
    vq, vs = _int8_pages(vp)
    table = jnp.asarray(
        np.random.default_rng(0).permutation(P)[:B * nb].reshape(B, nb),
        jnp.int32)
    kv_len = jnp.asarray([nb * bs, 17], jnp.int32)
    for window in (None, 16):
        out = paged_decode_quant(q, kq, vq, ks, vs, table, kv_len,
                                 window=window, interpret=True)
        want = ref.paged_decode_quant_ref(q, kq, vq, ks, vs, table,
                                          kv_len, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)


def test_paged_verify_quant_exact_scales_recover_float():
    """With unit scales the int8 kernel must equal the float kernel on
    integer-valued pages — the dequant path adds no extra error."""
    B, T, H, hkv, D, P, bs, nb = 1, 2, 4, 2, 64, 8, 16, 3
    q = jax.random.normal(KEY, (B, T, H, D))
    rng = np.random.default_rng(1)
    kq = jnp.asarray(rng.integers(-127, 128, (P, bs, hkv, D)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (P, bs, hkv, D)), jnp.int8)
    ones = jnp.ones((P, bs, hkv), jnp.float32)
    table = jnp.asarray(rng.permutation(P)[:B * nb].reshape(B, nb),
                        jnp.int32)
    kv_len = jnp.asarray([nb * bs - 5], jnp.int32)
    out = paged_verify_quant(q, kq, vq, ones, ones, table, kv_len,
                             interpret=True)
    want = paged_verify(q, kq.astype(jnp.float32),
                        vq.astype(jnp.float32), table, kv_len,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    B, H, hkv, D, S = 2, 8, 2, 64, 512
    q = jax.random.normal(KEY, (B, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, hkv, D), dtype)
    kv_len = jnp.full((B,), S, jnp.int32)
    out = flash_decode(q, k, v, kv_len, block_s=256, interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,nh,P,N,chunk", [
    (2, 256, 4, 32, 64, 64),
    (1, 128, 2, 64, 128, 128),
    (2, 512, 8, 16, 32, 128),
    (1, 192, 3, 32, 64, 64),     # S not a multiple of a power of two
])
def test_ssd_scan_sweep(B, S, nh, P, N, chunk):
    if S % chunk:
        pytest.skip("kernel requires S % chunk == 0")
    x = jax.random.normal(KEY, (B, S, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4),
                                           (B, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (nh,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(6), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(7), (B, S, N)) * 0.3
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_jnp_vs_sequential():
    """The model-layer chunked scan (used in training) against the O(S)
    recurrence."""
    B, S, nh, P, N = 2, 200, 4, 16, 32
    x = jax.random.normal(KEY, (B, S, nh, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4),
                                           (B, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (nh,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(6), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(7), (B, S, N)) * 0.3
    y_c, h_c = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=64)
    y_r, h_r = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
