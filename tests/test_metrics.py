"""Serving metrics: histogram correctness, registry exposure, request
lifecycle through the engine, classified sheds, and trace-eviction
surfacing."""
import dataclasses
import json
import logging
import math
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_cache, init_params
from repro.runtime.engine import make_dense_engine
from repro.runtime.kvcache import make_paged_engine
from repro.runtime.metrics import (LogHistogram, MetricsRegistry,
                                   RequestTracker,
                                   validate_metrics_snapshot)
from repro.runtime.telemetry import Tracer, validate_chrome_trace

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen2.5-14b", n_layers=2, **over):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers, **over)


class _Req:
    def __init__(self, uid, prompt, max_new, session=None):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new
        self.session = session


# --------------------------------------------------------------------------- #
#  LogHistogram: quantile accuracy, merging, concurrency
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dist", ["uniform", "lognormal", "pointmass"])
def test_histogram_quantiles_within_bucket_error(dist):
    """p50/p90/p99 of the log-bucketed histogram agree with exact numpy
    quantiles (same inverted-CDF definition) within one bucket of
    relative error — the histogram's accuracy contract."""
    rng = np.random.default_rng(hash(dist) % 2**32)
    n = 5000
    if dist == "uniform":
        xs = rng.uniform(0.001, 10.0, n)
    elif dist == "lognormal":
        xs = rng.lognormal(0.0, 2.0, n)
    else:
        xs = np.full(n, 3.7)
    h = LogHistogram()
    for x in xs:
        h.observe(x)
    assert h.count == n
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.total == pytest.approx(xs.sum(), rel=1e-9)
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.quantile(xs, q, method="inverted_cdf"))
        ratio = est / exact
        assert 1.0 / h.growth <= ratio <= h.growth, \
            f"{dist} p{q}: {est} vs exact {exact} (x{ratio:.4f})"


def test_histogram_extremes_exact_and_empty_nan():
    h = LogHistogram()
    assert math.isnan(h.quantile(0.5))
    for v in (2.0, 8.0, 32.0):
        h.observe(v)
    assert h.quantile(0.0) == 2.0          # clamped to exact min
    assert h.quantile(1.0) == 32.0         # clamped to exact max


def test_histogram_zero_and_negative_share_zero_bucket():
    h = LogHistogram()
    for v in (0.0, -1.5, 4.0):
        h.observe(v)
    assert h.zero_count == 2
    assert h.count == 3
    assert h.quantile(0.5) == 0.0          # zero-bucket, inside [min,max]
    assert h.min == -1.5 and h.max == 4.0


def test_histogram_merge_associative():
    rng = np.random.default_rng(3)
    parts = []
    for _ in range(3):
        h = LogHistogram()
        for x in rng.lognormal(0.0, 1.0, 400):
            h.observe(x)
        parts.append(h)

    def merged(order):
        acc = LogHistogram()
        for i in order:
            acc.merge(parts[i])
        return acc

    a = merged([0, 1, 2])
    b = merged([2, 0, 1])
    sa, sb = a.state(), b.state()
    # bucket/count merging is exactly associative; only the float sum
    # accumulates rounding
    assert sa.pop("sum") == pytest.approx(sb.pop("sum"), rel=1e-12)
    assert sa == sb
    assert a.count == sum(p.count for p in parts)
    for q in (0.5, 0.9, 0.99):
        assert a.quantile(q) == b.quantile(q)
    with pytest.raises(ValueError, match="growth"):
        a.merge(LogHistogram(growth=2.0))


def test_histogram_concurrent_observe():
    h = LogHistogram()
    per_thread, n_threads = 5000, 4
    xs = np.random.default_rng(9).lognormal(0.0, 1.0, per_thread)

    def work():
        for x in xs:
            h.observe(x)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == per_thread * n_threads
    assert h.zero_count + sum(h.buckets.values()) == h.count
    assert h.total == pytest.approx(xs.sum() * n_threads, rel=1e-6)


# --------------------------------------------------------------------------- #
#  Registry: counters/gauges/labels, snapshot, prometheus, validation
# --------------------------------------------------------------------------- #

def test_registry_counters_labels_and_monotonicity():
    reg = MetricsRegistry()
    reg.inc("requests/rejected", reason="shed_capacity")
    reg.inc("requests/rejected", 2, reason="deferred_ttl_expired")
    reg.inc("requests/finished", 3)
    snap = reg.snapshot()
    assert snap["counters"]["requests/rejected{reason=shed_capacity}"] == 1
    assert snap["counters"][
        "requests/rejected{reason=deferred_ttl_expired}"] == 2
    assert snap["counters"]["requests/finished"] == 3
    with pytest.raises(ValueError, match="negative"):
        reg.counter("requests/finished").inc(-1)


def test_registry_gauge_sources_sampled():
    reg = MetricsRegistry()
    state = {"v": 1.0}
    reg.add_source("test", lambda: {"pool/occupancy": state["v"]})
    reg.sample()
    assert reg.gauge("pool/occupancy").value == 1.0
    state["v"] = 0.25
    snap = reg.snapshot()                  # snapshot() re-samples
    assert snap["gauges"]["pool/occupancy"] == 0.25


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.inc("requests/finished", 2)
    reg.set_gauge("slots/active", 3)
    for v in (0.1, 0.2, 0.4):
        reg.observe("request/ttft_s", v)
    text = reg.prometheus_text()
    assert "# TYPE repro_requests_finished_total counter" in text
    assert "repro_requests_finished_total 2" in text
    assert "# TYPE repro_slots_active gauge" in text
    assert "# TYPE repro_request_ttft_s summary" in text
    assert 'repro_request_ttft_s{quantile="0.5"}' in text
    assert "repro_request_ttft_s_count 3" in text
    assert "repro_request_ttft_s_sum" in text


def test_validate_metrics_snapshot_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.inc("requests/finished", 4)
    for v in np.random.default_rng(0).uniform(0.01, 2.0, 100):
        reg.observe("request/ttft_s", v)
    path = reg.export_json(str(tmp_path / "m.json"))
    info = validate_metrics_snapshot(path, require=["request/ttft_s"])
    assert info["histograms"] == 1
    assert info["quantiles"]["request/ttft_s"]["p50"] > 0

    with pytest.raises(ValueError, match="required metric"):
        validate_metrics_snapshot(path, require=["no/such/metric"])

    doc = json.loads(open(path).read())
    doc["counters"]["requests/finished"] = -1
    with pytest.raises(ValueError, match="non-monotonic"):
        validate_metrics_snapshot(doc)

    doc = json.loads(open(path).read())
    doc["histograms"]["request/ttft_s"]["count"] += 5
    with pytest.raises(ValueError, match="bucket sum"):
        validate_metrics_snapshot(doc)

    with pytest.raises(ValueError, match="schema"):
        validate_metrics_snapshot({"schema": "bogus"})


def test_request_log_bounded_with_eviction_counter():
    from repro.runtime.metrics import RequestTrace

    reg = MetricsRegistry(request_log_size=4)
    for i in range(7):
        reg.record_request(RequestTrace(uid=i, submit_t=float(i)))
    assert len(reg.request_log) == 4
    assert reg.request_log_evicted == 3
    assert reg.snapshot()["request_log"] == {"logged": 4, "evicted": 3}


def test_tracker_chunked_prefill_metrics():
    """Chunked-admission instrumentation: per-request chunk counts land
    in the ``request/prefill_chunks`` histogram, interleave stalls
    accumulate as a counter, and the worst inter-token gap is recorded
    per finished request (the stat the chunked-admit TPOT gate reads)."""
    import time

    reg = MetricsRegistry()
    tr = RequestTracker(reg)
    tr.submit(1)
    tr.admitted(1)
    tr.prefill_chunks(1, 4)
    tr.interleave_stall(0.25)
    tr.interleave_stall(0.5)
    tr.token(1)
    time.sleep(0.02)
    tr.token(1)
    tr.token(1)
    tr.finished(1)
    snap = reg.snapshot()
    assert snap["counters"]["decode/interleave_stall_s"] == \
        pytest.approx(0.75)
    assert reg.histogram("request/prefill_chunks").quantile(0.5) >= 4
    trace = list(reg.request_log)[-1]
    assert trace.max_gap_s >= 0.02           # the slept gap was captured
    assert reg.histogram("request/max_gap_s").count == 1


def test_tracker_reject_classification_counts():
    reg = MetricsRegistry()
    tr = RequestTracker(reg)
    tr.submit(1)
    tr.rejected(1, "shed_capacity", "pool too small")
    tr.submit(2)
    tr.rejected(2, "deferred_ttl_expired", "starved")
    snap = reg.snapshot()
    assert snap["counters"]["requests/rejected{reason=shed_capacity}"] == 1
    assert snap["counters"][
        "requests/rejected{reason=deferred_ttl_expired}"] == 1
    outcomes = [t.outcome for t in reg.request_log]
    assert outcomes == ["shed", "shed"]


# --------------------------------------------------------------------------- #
#  Engine lifecycle: dense + paged, arrivals, sheds, restores
# --------------------------------------------------------------------------- #

def test_dense_engine_records_request_lifecycle():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    reg = MetricsRegistry()
    eng = make_dense_engine(params, cfg, 2, 64, metrics=reg)
    rng = np.random.default_rng(1)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, 6), 4) for i in range(3)]
    fin, steps = eng.run(init_cache(cfg, 2, 64, dtype=jnp.float32), reqs)
    assert len(fin) == 3
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["requests/submitted"] == 3
    assert c["requests/admitted"] == 3
    assert c["requests/finished"] == 3
    assert c["tokens/generated"] == sum(len(f.tokens) for f in fin) == 12
    h = snap["histograms"]
    assert h["request/ttft_s"]["count"] == 3
    assert h["request/queue_wait_s"]["count"] == 3
    assert h["request/tpot_s"]["count"] == 3
    assert h["decode/step_s"]["count"] == steps
    assert snap["gauges"]["slots/active"] == 0.0
    traces = list(reg.request_log)
    assert sorted(t.uid for t in traces) == [0, 1, 2]
    assert all(t.outcome == "finished" for t in traces)
    assert all(t.ttft_s > 0 and t.e2e_s >= t.ttft_s for t in traces)
    assert all(t.n_tokens == 4 for t in traces)
    validate_metrics_snapshot(snap, require=["request/ttft_s",
                                             "requests/finished"])


def test_paged_engine_classified_shed_counters():
    """The two PoolExhausted shed paths land as distinctly-labeled
    counters and classified codes on RejectedRequest."""
    cfg = _cfg()
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(5)

    reg = MetricsRegistry()
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=6,
                                page_tokens=8, offload=False, metrics=reg)
    try:
        fin, _ = eng.run(kv.init_cache(),
                         [_Req(0, rng.integers(0, cfg.vocab, 8), 8),
                          _Req(1, rng.integers(0, cfg.vocab, 30), 4)])
    finally:
        kv.close()
    assert [f.uid for f in fin] == [0]
    assert eng.rejected[0].code == "shed_capacity"
    assert "pool too small for request 1" in eng.rejected[0].reason
    c = reg.snapshot()["counters"]
    assert c["requests/rejected{reason=shed_capacity}"] == 1
    assert c["requests/finished"] == 1

    reg2 = MetricsRegistry()
    eng2, kv2 = make_paged_engine(params, cfg, 2, 64, n_pages=6,
                                  page_tokens=8, offload=False,
                                  metrics=reg2)
    try:
        fin2, _ = eng2.run(kv2.init_cache(),
                           [_Req(0, rng.integers(0, cfg.vocab, 8), 12),
                            _Req(1, rng.integers(0, cfg.vocab, 8), 8)],
                           admit_patience=5)
    finally:
        kv2.close()
    assert [f.uid for f in fin2] == [0]
    assert eng2.rejected[0].code == "deferred_ttl_expired"
    c2 = reg2.snapshot()["counters"]
    assert c2["requests/rejected{reason=deferred_ttl_expired}"] == 1
    shed_traces = [t for t in reg2.request_log if t.outcome == "shed"]
    assert [t.uid for t in shed_traces] == [1]


def test_engine_respect_arrivals_replays_queue_wait():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    reg = MetricsRegistry()
    eng = make_dense_engine(params, cfg, 2, 64, metrics=reg)
    rng = np.random.default_rng(2)

    class _ArrReq(_Req):
        def __init__(self, uid, prompt, max_new, arrival_s):
            super().__init__(uid, prompt, max_new)
            self.arrival_s = arrival_s

    reqs = [_ArrReq(0, rng.integers(0, cfg.vocab, 6), 3, 0.0),
            _ArrReq(1, rng.integers(0, cfg.vocab, 6), 3, 0.05)]
    fin, _ = eng.run(init_cache(cfg, 2, 64, dtype=jnp.float32), reqs,
                     respect_arrivals=True)
    assert sorted(f.uid for f in fin) == [0, 1]
    traces = {t.uid: t for t in reg.request_log}
    # request 1's submit is pinned to its arrival instant, 50 ms after
    # request 0's
    assert traces[1].submit_t - traces[0].submit_t \
        >= 0.05 - 1e-3
    assert all(t.queue_wait_s >= 0 for t in traces.values())


def test_paged_engine_gauges_and_session_restore_counter(tmp_path):
    cfg = _cfg()
    params = init_params(cfg, KEY)
    reg = MetricsRegistry()
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=18,
                                page_tokens=8, offload=False, metrics=reg,
                                disk_dir=str(tmp_path), park_idle_s=1e9)
    try:
        cache = kv.init_cache()
        prompt = np.arange(8) % cfg.vocab
        eng.run(cache, [_Req(10, prompt, 3, session="s1")])
        assert kv.is_parked("s1")
        eng.run(cache, [_Req(11, prompt, 3, session="s1")])
    finally:
        kv.close()
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["requests/finished"] == 2
    assert c["requests/restored"] == 1
    restored = [t for t in reg.request_log if t.restored]
    assert [t.uid for t in restored] == [11]
    assert restored[0].ttft_s > 0      # first token of turn 2 still timed
    g = snap["gauges"]
    for key in ("kv/pages_free", "kv/prefix_hit_rate", "slots/free",
                "mem/device/used_bytes", "mem/host/peak_bytes"):
        assert key in g, f"missing gauge {key}"
    assert snap["histograms"]["request/prefill_s"]["count"] == 2


# --------------------------------------------------------------------------- #
#  Tracer ring-eviction surfacing (satellite: truncated-trace warning)
# --------------------------------------------------------------------------- #

def test_chrome_trace_carries_eviction_metadata(tmp_path, caplog):
    tr = Tracer(capacity=4)
    for i in range(12):
        with tr.span(f"s{i}", track="decode"):
            pass
    assert tr.evicted > 0
    doc = tr.chrome_trace()
    assert doc["metadata"]["evicted"] == tr.evicted
    assert doc["metadata"]["complete"] is False
    path = str(tmp_path / "t.json")
    with caplog.at_level(logging.WARNING, "repro.runtime.telemetry"):
        tr.export_chrome_trace(path)
    assert any("truncated" in r.message for r in caplog.records)
    info = validate_chrome_trace(path)
    assert info["evicted"] == tr.evicted


def test_chrome_trace_complete_when_nothing_evicted(tmp_path, caplog):
    tr = Tracer(capacity=64)
    with tr.span("only", track="decode"):
        pass
    path = str(tmp_path / "t.json")
    with caplog.at_level(logging.WARNING, "repro.runtime.telemetry"):
        tr.export_chrome_trace(path)
    assert not caplog.records
    info = validate_chrome_trace(path)
    assert info["evicted"] == 0
