"""Per-arch smoke tests (deliverable f): every assigned architecture, at a
reduced same-family config, runs one forward and one train step on CPU with
shape assertions and no NaNs; plus prefill+decode vs teacher-forced forward
consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)
from repro.runtime.optim import AdamW
from repro.runtime.train import lm_loss, make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16, extra=0):
    toks = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab)
    emb = None
    if cfg.frontend:
        emb = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return toks, emb


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    toks, emb = _inputs(cfg)
    logits = forward(params, cfg, toks, embeds=emb)
    S_tot = toks.shape[1] + (cfg.n_frontend_tokens
                             if cfg.frontend and cfg.family != "audio" else 0)
    assert logits.shape == (2, S_tot, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    toks, emb = _inputs(cfg)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if emb is not None:
        batch["embeds"] = emb
    step = make_train_step(cfg, AdamW(lr=1e-3), grad_dtype=None,
                           remat=False, has_embeds=emb is not None)
    opt = AdamW(lr=1e-3).init(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(metrics["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    B, S, extra = 2, 12, 3
    toks, emb = _inputs(cfg, B, S, extra)
    full = forward(params, cfg, toks, embeds=emb)
    off = cfg.n_frontend_tokens if (cfg.frontend
                                    and cfg.family != "audio") else 0
    cache = init_cache(cfg, B, 48, dtype=jnp.float32)
    lg, cache = prefill(params, cfg, toks[:, :S], cache, embeds=emb)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, off + S - 1])))]
    for t in range(S, S + extra):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, off + t]))))
    rel = max(errs) / float(jnp.max(jnp.abs(full)))
    tol = 2e-2 if cfg.kv_dtype == "int8" else 2e-4
    assert rel < tol, (arch, rel)


def test_loss_decreases_dense():
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    opt_def = AdamW(lr=3e-3, warmup_steps=5)
    opt = opt_def.init(params)
    step = make_train_step(cfg, opt_def, grad_dtype=None, remat=False)
    step = jax.jit(step)
    toks = jax.random.randint(KEY, (4, 33), 0, 64)   # learnable: tiny vocab
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_swa_rolling_buffer_consistency():
    """SWA decode with a full rolling buffer matches a fresh full-context
    prefill truncated to the window."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              n_layers=2, attn_window=8)
    params = init_params(cfg, KEY)
    B, S = 1, 20
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full = forward(params, cfg, toks)       # SWA causal over all positions
    cache = init_cache(cfg, B, 64, dtype=jnp.float32)
    lg, cache = prefill(params, cfg, toks[:, :S], cache)
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, S - 1])))
    lg2, cache = decode_step(params, cfg, cache, toks[:, S:S + 1])
    err2 = float(jnp.max(jnp.abs(lg2[:, 0] - full[:, S])))
    scale = float(jnp.max(jnp.abs(full)))
    assert max(err, err2) / scale < 2e-5


def test_grad_accumulation_equivalence():
    cfg = dataclasses.replace(get_config("minitron-8b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    opt_def = AdamW(lr=1e-3)
    toks = jax.random.randint(KEY, (8, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    full = make_train_step(cfg, opt_def, grad_dtype=None, remat=False)
    micro = make_train_step(cfg, opt_def, grad_dtype=None, remat=False,
                            microbatch=2)
    p1, _, m1 = full(params, opt_def.init(params), batch)
    p2, _, m2 = micro(params, opt_def.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))
    assert diff < 5e-5, diff
