"""SPMD piped-ring serving on an 8-device CPU mesh: partition invariance
(the ring must produce byte-identical-to-tolerance logits vs the plain
single-device decode for every (w, k) split), plus the multi-pod replica
path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.runtime import serve

KEY = jax.random.PRNGKey(0)

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 CPU devices (conftest sets flag)")


def _reference(cfg, params, toks, B, Smax, steps):
    cache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    out = []
    for t in range(steps):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        out.append(lg)
    return out


def _ring(cfg, params, toks, B, Smax, steps, mesh, n_stages, tp, k):
    plan = serve.RingPlan.make(cfg, n_stages, k=k)
    pr = serve.pad_vocab(dict(params), cfg, tp)
    pr["blocks"] = serve.pad_and_permute(params["blocks"], cfg, n_stages, k)
    cache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    cache["layers"] = serve.pad_and_permute(cache["layers"], cfg,
                                            n_stages, k)
    step = serve.build_ring_serve_step(cfg, mesh, plan)(pr, cache)
    ln = jnp.zeros((B,), jnp.int32)
    out = []
    for t in range(steps):
        logits, cache = step(toks[:, t:t + 1], ln, pr, cache)
        ln = ln + 1
        out.append(logits[:, :, :cfg.vocab])
    return out


def _run(arch, *, n_layers=8, k=1, B=8, Smax=32, steps=3, tol=2e-4,
         mesh_shape=(4, 2), axis_names=("data", "model"), **cfg_over):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              n_layers=n_layers, **cfg_over)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, steps + 1), 0, cfg.vocab)
    refs = _reference(cfg, params, toks, B, Smax, steps)
    mesh = jax.make_mesh(mesh_shape, axis_names)
    n_stages = dict(zip(axis_names, mesh_shape))["data"]
    tp = dict(zip(axis_names, mesh_shape))["model"]
    outs = _ring(cfg, params, toks, B, Smax, steps, mesh, n_stages, tp, k)
    scale = float(jnp.max(jnp.abs(refs[-1])))
    for t, (a, b) in enumerate(zip(outs, refs)):
        rel = float(jnp.max(jnp.abs(a - b))) / scale
        assert rel < tol, (arch, k, t, rel)


@needs_8_devices
@pytest.mark.parametrize("k", [1, 2])
def test_ring_dense(k):
    _run("qwen2.5-14b", k=k)


@needs_8_devices
@pytest.mark.parametrize("k", [1, 2])
def test_ring_moe(k):
    _run("phi3.5-moe-42b-a6.6b", k=k)


@needs_8_devices
def test_ring_swa_rolling():
    _run("mixtral-8x7b", k=2, Smax=32)     # window == Smax: rolling buffer


@needs_8_devices
def test_ring_mla_absorbed():
    _run("minicpm3-4b", k=2)


@needs_8_devices
def test_ring_ssm():
    _run("mamba2-780m", k=2, tol=1e-5)


@needs_8_devices
def test_ring_int8_kv():
    _run("qwen1.5-32b", k=2, tol=2e-2)


@needs_8_devices
def test_ring_mrope():
    _run("qwen2-vl-2b", k=2)


@needs_8_devices
def test_ring_layer_padding():
    _run("minitron-8b", n_layers=6, k=1)   # L=6 on 4 stages -> 2 pad layers


@needs_8_devices
def test_ring_multi_pod_replicas():
    """(pod=2, data=2, model=2): each pod runs its own ring over its half
    of the batch; logits must still match the reference."""
    _run("qwen2.5-14b", n_layers=8, k=2, B=8, mesh_shape=(2, 2, 2),
         axis_names=("pod", "data", "model"))


@needs_8_devices
@pytest.mark.parametrize("arch", ["qwen2.5-14b", "minicpm3-4b"])
def test_ring_verify_multi_token(arch):
    """T=4 speculative verify through the ring == 4 sequential reference
    decode steps (per-position logit parity), then rollback + T=1 decode
    matches the never-rejected prefix."""
    cfg = dataclasses.replace(get_config(arch).reduced(), n_layers=8)
    params = init_params(cfg, KEY)
    B, Smax, T = 8, 32, 4
    toks = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab)

    # reference: sequential single-token decode
    cache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    refs = []
    for t in range(T):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        refs.append(lg[:, 0])
    ref = jnp.stack(refs, 1)                             # (B, T, V)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = serve.RingPlan.make(cfg, 4, k=1)
    pr = serve.pad_vocab(dict(params), cfg, 2)
    pr["blocks"] = serve.pad_and_permute(params["blocks"], cfg, 4, 1)
    rcache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    rcache["layers"] = serve.pad_and_permute(rcache["layers"], cfg, 4, 1)
    vstep = serve.build_ring_serve_step(cfg, mesh, plan,
                                        n_tokens=T)(pr, rcache)
    ln = jnp.zeros((B,), jnp.int32)
    logits, rcache = vstep(toks[:, :T], ln, pr, rcache)
    logits = logits[:, :, :cfg.vocab]
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(logits - ref))) / scale < 2e-4

    # rollback: keep 2 of the 4 positions, then decode token 2 again with
    # a T=1 ring step — must match the sequential reference at that point.
    keep = 2
    c_ref = init_cache(cfg, B, Smax, dtype=jnp.float32)
    for t in range(keep):
        _, c_ref = decode_step(params, cfg, c_ref, toks[:, t:t + 1])
    lg_ref, _ = decode_step(params, cfg, c_ref, toks[:, keep:keep + 1])
    step1 = serve.build_ring_serve_step(cfg, mesh, plan)(pr, rcache)
    lg_rb, _ = step1(toks[:, keep:keep + 1], jnp.full((B,), keep,
                                                      jnp.int32),
                     pr, rcache)
    rel = float(jnp.max(jnp.abs(lg_rb[:, :, :cfg.vocab] - lg_ref))) / float(
        jnp.max(jnp.abs(lg_ref)))
    assert rel < 2e-4


@needs_8_devices
def test_gspmd_decode_matches_reference():
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              n_layers=6)
    params = init_params(cfg, KEY)
    B, Smax, steps = 8, 32, 3
    toks = jax.random.randint(KEY, (B, steps + 1), 0, cfg.vocab)
    refs = _reference(cfg, params, toks, B, Smax, steps)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    step = serve.gspmd_decode_step(cfg, mesh, params, cache)
    for t in range(steps):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        rel = float(jnp.max(jnp.abs(lg - refs[t]))) / float(
            jnp.max(jnp.abs(refs[t])))
        assert rel < 2e-4, (t, rel)
