"""Chaos suite: deterministic fault injection against the streaming
runtime — transient faults must retry to byte-identical output, permanent
faults must fail fast with classified errors, stalls must become
timeouts, and pool pressure must shed requests instead of starving."""
import dataclasses
import os
import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step_layerwise, init_cache, init_params, \
    prefill
from repro.runtime.engine import make_dense_engine
from repro.runtime.faults import (FaultInjector, FaultSpec, FaultyStore,
                                  InjectedFault)
from repro.runtime.iopolicy import (IOPolicy, FatalIOError, ShortReadError,
                                    StallTimeout, StageFailure,
                                    WorkerHealth, find_cause)
from repro.runtime.kvcache import BlockOffloader, PagedKVCache, \
    make_paged_engine
from repro.runtime.paramstore import ParamStore, save_param_store
from repro.runtime.streaming import LayerPrefetcher, StreamingParamSource

KEY = jax.random.PRNGKey(0)

#: fast knobs so retry/backoff/deadline paths run in milliseconds
FAST = IOPolicy(max_retries=3, backoff_base_s=0.002, backoff_max_s=0.01,
                op_deadline_s=5.0, get_timeout_s=10.0)


def _cfg(arch="qwen2.5-14b", n_layers=3, **over):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers, **over)


@pytest.fixture()
def store_dir():
    d = tempfile.mkdtemp(prefix="test_faults_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


class _Req:
    def __init__(self, uid, prompt, max_new):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new


# --------------------------------------------------------------------------- #
#  IOPolicy unit behavior
# --------------------------------------------------------------------------- #

def test_policy_classify():
    p = IOPolicy()
    assert p.classify(OSError("eio")) == "transient"
    assert p.classify(ShortReadError("short")) == "transient"
    assert p.classify(InjectedFault("x")) == "transient"
    assert p.classify(ValueError("shape")) == "fatal"
    assert p.classify(StageFailure("dead")) == "fatal"
    assert p.classify(FatalIOError("gone")) == "fatal"


def test_policy_retries_transient_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("flaky disk")
        return "ok"

    h = WorkerHealth(name="t")
    assert FAST.run("layer_read[0]", flaky, health=h) == "ok"
    assert calls["n"] == 3
    assert h.retries == 2 and h.failures == 2
    assert h.consecutive_failures == 0       # progress reset


def test_policy_fatal_error_no_retry():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("corrupt shape")

    with pytest.raises(FatalIOError, match="fatal error"):
        FAST.run("layer_read[0]", bad)
    assert calls["n"] == 1                   # no retry on fatal


def test_policy_retries_exhausted_is_classified():
    with pytest.raises(FatalIOError, match="retries exhausted") as ei:
        FAST.run("op", lambda: (_ for _ in ()).throw(OSError("eio")))
    assert ei.value.attempts == FAST.max_retries + 1
    assert isinstance(ei.value.__cause__, OSError)


def test_policy_deadline_becomes_stall_timeout():
    p = IOPolicy(max_retries=10_000, backoff_base_s=0.02,
                 backoff_max_s=0.02, op_deadline_s=0.1)
    t0 = time.monotonic()
    with pytest.raises(StallTimeout, match="deadline"):
        p.run("op", lambda: (_ for _ in ()).throw(OSError("eio")))
    assert time.monotonic() - t0 < 2.0       # fails fast, not 10k retries


def test_policy_reopen_called_between_attempts():
    reopens = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("eio")
        return calls["n"]

    FAST.run("op", flaky, reopen=lambda: reopens.append(1))
    assert reopens == [1]


def test_policy_propagates_control_flow():
    with pytest.raises(KeyboardInterrupt):
        FAST.run("op", lambda: (_ for _ in ()).throw(KeyboardInterrupt()))


# --------------------------------------------------------------------------- #
#  injector determinism
# --------------------------------------------------------------------------- #

def test_injector_schedule_window_exact():
    inj = FaultInjector([FaultSpec(op="layer_read", after=2, times=2)])
    fired = []
    for i in range(6):
        try:
            inj.check("layer_read", key=i)
        except InjectedFault:
            fired.append(i)
    assert fired == [2, 3]                   # window [after, after+times)
    assert inj.counts() == [(6, 2)]
    assert inj.exhausted()


def test_injector_key_scoping():
    inj = FaultInjector([FaultSpec(op="layer_read", key=1, times=-1)])
    inj.check("layer_read", key=0)           # other key: clean
    inj.check("kv_h2d", key=1)               # other op: clean
    with pytest.raises(InjectedFault):
        inj.check("layer_read", key=1)


def test_injector_seeded_prob_deterministic():
    def pattern(seed):
        inj = FaultInjector(
            [FaultSpec(op="layer_read", prob=0.5, times=-1)], seed=seed)
        out = []
        for i in range(64):
            try:
                inj.check("layer_read", key=i)
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = pattern(7), pattern(7)
    assert a == b                            # same seed -> same firings
    assert 0 < sum(a) < 64                   # actually probabilistic
    assert pattern(8) != a                   # seed participates


def test_injector_stage_failure_mode():
    inj = FaultInjector([FaultSpec(op="layer_read",
                                   mode="stage_failure", stage=2)])
    with pytest.raises(StageFailure) as ei:
        inj.check("layer_read", key=5)
    assert ei.value.stage == 2


# --------------------------------------------------------------------------- #
#  mid-stream truncation (satellite: classified error naming layer/file)
# --------------------------------------------------------------------------- #

def _truncate(path, frac=0.5):
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(int(size * frac))
    return size


def test_truncated_layer_is_classified_short_read(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    with ParamStore(store_dir) as store:
        store.layer(0)                       # manifest + layer 0 fine
        path = os.path.join(store_dir, "layer_00001.bin")
        _truncate(path)
        with pytest.raises(ShortReadError) as ei:
            store.layer(1)
        assert ei.value.layer == 1
        assert "layer_00001.bin" in str(ei.value)
        assert ei.value.got < ei.value.expected


def test_truncated_to_zero_is_classified(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    with ParamStore(store_dir) as store:
        _truncate(os.path.join(store_dir, "layer_00002.bin"), 0.0)
        with pytest.raises(ShortReadError, match="layer_00002.bin"):
            store.layer(2)


def test_reopen_recovers_restored_file(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    path = os.path.join(store_dir, "layer_00001.bin")
    with open(path, "rb") as f:
        original = f.read()
    with ParamStore(store_dir) as store:
        ref = jax.tree.map(lambda a: np.array(a, copy=True),
                           store.layer(1))
        store.reopen(1)
        _truncate(path)
        with pytest.raises(ShortReadError):
            store.layer(1)
        with open(path, "wb") as f:          # writer finishes the flush
            f.write(original)
        with pytest.raises(ShortReadError):
            store.layer(1)                   # stale mapping still short
        store.reopen(1)                      # the IOPolicy retry hook
        back = store.layer(1)
        flags = jax.tree.map(
            lambda x, y: bool(np.array_equal(np.asarray(x),
                                             np.asarray(y))), ref, back)
        assert all(jax.tree.leaves(flags))


def test_prefetcher_truncation_fails_classified_not_shape_crash(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    _truncate(os.path.join(store_dir, "layer_00001.bin"))
    store = ParamStore(store_dir)
    pf = LayerPrefetcher(store, window=2, policy=FAST)
    try:
        pf.get(0)                            # healthy layer still serves
        with pytest.raises(RuntimeError, match="prefetch of layer 1") \
                as ei:
            pf.get(1)
        short = find_cause(ei.value, ShortReadError)
        assert short is not None and short.layer == 1
        assert "layer_00001.bin" in str(short)
    finally:
        pf.close()
        store.close()


# --------------------------------------------------------------------------- #
#  transient faults during streamed decode: retry to identical tokens
# --------------------------------------------------------------------------- #

def _stream_decode(cfg, params, store, prompts, n_tokens, *, policy=None):
    src = StreamingParamSource(store, window=2, policy=policy)
    try:
        cache = init_cache(cfg, prompts.shape[0], 32, dtype=jnp.float32)
        logits, cache = prefill(params, cfg, prompts, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out = [np.asarray(tok[:, 0])]
        for _ in range(n_tokens - 1):
            logits, cache = decode_step_layerwise(src, cfg, cache, tok)
            tok = jnp.argmax(logits[:, 0], -1)[:, None]
            out.append(np.asarray(tok[:, 0]))
        return np.stack(out, 1), src.stats()
    finally:
        src.close()


def test_transient_disk_faults_recover_byte_identical(store_dir):
    cfg = _cfg()
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    prompts = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, (2, 5)))

    clean, _ = _stream_decode(cfg, params, ParamStore(store_dir),
                              prompts, 6)
    inj = FaultInjector([FaultSpec(op="layer_read", after=4, times=3)])
    faulty_store = FaultyStore(ParamStore(store_dir), inj)
    chaos, stats = _stream_decode(cfg, params, faulty_store, prompts, 6,
                                  policy=FAST)
    assert np.array_equal(clean, chaos)      # byte-identical recovery
    assert len(inj.fired) == 3               # the faults really fired
    assert stats.retries >= 3                # visible in PrefetchStats


def test_permanent_fault_fails_fast_classified(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    inj = FaultInjector([FaultSpec(op="layer_read", times=-1)])
    store = FaultyStore(ParamStore(store_dir), inj)
    pf = LayerPrefetcher(store, window=2, policy=FAST)
    try:
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="prefetch of layer") as ei:
            pf.get(0)
        assert time.monotonic() - t0 < 5.0   # fail fast, no hang
        fatal = find_cause(ei.value, FatalIOError)
        assert fatal is not None and fatal.attempts == FAST.max_retries + 1
    finally:
        pf.close()
        store.close()


def test_stalled_worker_becomes_get_timeout(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    inj = FaultInjector([FaultSpec(op="layer_read", mode="stall",
                                   delay_s=0.6, times=-1)])
    store = FaultyStore(ParamStore(store_dir), inj)
    pf = LayerPrefetcher(store, window=1,
                         policy=dataclasses.replace(FAST,
                                                    get_timeout_s=0.25))
    try:
        with pytest.raises(StallTimeout, match="not staged within"):
            pf.get(0)
        # worker still wedged inside the stall: close() must report it
        assert pf.close(timeout=0.05) is False
        assert pf.health.stalled
    finally:
        # the injected stall ends and the worker exits; close is
        # idempotent and eventually observes the join
        deadline = time.monotonic() + 10.0
        while not pf.close(timeout=0.2) and time.monotonic() < deadline:
            pass
        assert pf.close(timeout=0.2) is True
        store.close()


def test_interrupt_is_not_latched_as_io_error(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    inj = FaultInjector([FaultSpec(op="layer_read",
                                   error_type=KeyboardInterrupt)])
    store = FaultyStore(ParamStore(store_dir), inj)
    hook, threading.excepthook = threading.excepthook, lambda a: None
    pf = LayerPrefetcher(store, window=1, policy=FAST)
    try:
        with pytest.raises(RuntimeError, match="worker interrupted"):
            pf.get(0)
        assert pf._error is None             # never latched as I/O error
    finally:
        threading.excepthook = hook
        pf.close()
        store.close()


def test_prefetcher_close_idempotent(store_dir):
    cfg = _cfg()
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    store = ParamStore(store_dir)
    pf = LayerPrefetcher(store, window=2, policy=FAST)
    pf.get(0)
    assert pf.close() is True
    assert pf.close() is True                # double-stop: no-op
    store.close()


# --------------------------------------------------------------------------- #
#  BlockOffloader H2D/D2H faults
# --------------------------------------------------------------------------- #

def _page_tree():
    return {"k": np.arange(8, dtype=np.float32).reshape(2, 4),
            "v": np.ones((2, 4), np.float32)}


def test_offloader_transient_h2d_retries():
    inj = FaultInjector([FaultSpec(op="kv_h2d", times=2)])
    off = BlockOffloader(policy=FAST, injector=inj)
    try:
        off.offload(("h",), _page_tree())
        off.schedule(("h",))
        out = off.get(("h",))
        assert np.array_equal(np.asarray(out["k"]), _page_tree()["k"])
        assert off.health.retries >= 2
        assert off.fetched_bytes > 0
    finally:
        off.close()


def test_offloader_transient_d2h_retries():
    inj = FaultInjector([FaultSpec(op="kv_d2h", times=1)])
    off = BlockOffloader(policy=FAST, injector=inj)
    try:
        off.offload(("h",), _page_tree())    # retried under the policy
        assert off.health.retries >= 1
        assert off.holds(("h",))
    finally:
        off.close()


def test_offloader_permanent_fault_fails_fast():
    inj = FaultInjector([FaultSpec(op="kv_h2d", times=-1)])
    off = BlockOffloader(policy=FAST, injector=inj)
    try:
        off.offload(("h",), _page_tree())
        off.schedule(("h",))
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="offload fetch") as ei:
            off.get(("h",))
        assert time.monotonic() - t0 < 5.0
        assert find_cause(ei.value, FatalIOError) is not None
    finally:
        assert off.close() is True
        assert off.close() is True           # idempotent


# --------------------------------------------------------------------------- #
#  engine shedding (bounded deferral TTL + pool-too-small)
# --------------------------------------------------------------------------- #

def test_can_ever_admit():
    kv = PagedKVCache(_cfg(n_layers=2), batch=2, ctx=64, n_pages=6,
                      page_tokens=8, offload=False)
    assert kv.can_ever_admit(8, 8)           # 3 pages vs 5 usable
    assert not kv.can_ever_admit(30, 4)      # 6 pages: never fits
    assert not kv.can_ever_admit(60, 60)     # exceeds ctx
    kv.close()


def test_engine_sheds_request_pool_can_never_hold():
    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=6,
                                page_tokens=8, offload=False)
    rng = np.random.default_rng(5)
    reqs = [_Req(0, rng.integers(0, cfg.vocab, 8), 8),     # fits
            _Req(1, rng.integers(0, cfg.vocab, 30), 4)]    # never fits
    try:
        fin, _ = eng.run(kv.init_cache(), reqs)
        assert [f.uid for f in fin] == [0]
        assert len(fin[0].tokens) == 8
        assert [r.uid for r in eng.rejected] == [1]
        assert "pool too small for request 1" in eng.rejected[0].reason
        kv.pool.check()
    finally:
        kv.close()


def test_engine_admit_ttl_sheds_starved_request():
    """A request that *could* fit an empty pool but is starved by a
    long-running occupant is shed after admit_patience refused steps —
    bounded deferral, not an unbounded spin."""
    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=9,
                                page_tokens=8, offload=False)
    rng = np.random.default_rng(6)
    reqs = [_Req(0, rng.integers(0, cfg.vocab, 8), 40),    # hog: 7 pages
            _Req(1, rng.integers(0, cfg.vocab, 8), 8)]     # needs 3 more
    try:
        fin, _ = eng.run(kv.init_cache(), reqs, admit_patience=5)
        assert [f.uid for f in fin] == [0]
        assert len(fin[0].tokens) == 40      # the hog still completes
        assert [r.uid for r in eng.rejected] == [1]
        assert "pool too small for request 1" in eng.rejected[0].reason
        assert "deferred 5 consecutive steps" in eng.rejected[0].reason
        kv.pool.check()
    finally:
        kv.close()


def test_engine_still_raises_when_nothing_can_free(store_dir):
    """The raise-when-idle contract is preserved: a lone oversized
    request with no active slots propagates PoolExhausted."""
    from repro.runtime.kvcache import PoolExhausted

    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=4,
                                page_tokens=8, offload=False)
    try:
        with pytest.raises(PoolExhausted, match="exhausted"):
            eng.run(kv.init_cache(),
                    [_Req(0, np.arange(30) % cfg.vocab, 4)])
    finally:
        kv.close()


def test_dense_engine_unaffected_by_shedding_path():
    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    eng = make_dense_engine(params, cfg, 2, 64)
    rng = np.random.default_rng(7)
    reqs = [_Req(i, rng.integers(0, cfg.vocab, 6), 4) for i in range(3)]
    fin, _ = eng.run(init_cache(cfg, 2, 64, dtype=jnp.float32), reqs)
    assert sorted(f.uid for f in fin) == [0, 1, 2]
    assert eng.rejected == []
