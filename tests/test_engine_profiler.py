"""Continuous-batching engine + device profiler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import halda
from repro.core.profiler import (measure_disk, measure_flops,
                                 measure_membw, profile_local_device_noopt)
from repro.core.profiles import profile_from_config
from repro.data import RequestGenerator
from repro.models import decode_step, init_cache, init_params, prefill
from repro.runtime.engine import ContinuousBatcher

KEY = jax.random.PRNGKey(0)


def _make_engine(cfg, params, B, ctx):
    def prefill_one(prompt):
        c1 = init_cache(cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(params, cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == B and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new

    def decode(cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return ContinuousBatcher(B, prefill_one, write_slot, decode, ctx=ctx)


def test_engine_serves_more_requests_than_slots():
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 4, 64
    eng = _make_engine(cfg, params, B, ctx)
    reqs = RequestGenerator(cfg.vocab, prompt_len=(4, 9), max_new=6,
                            seed=3).generate(10)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, steps = eng.run(cache, reqs)
    assert len(finished) == 10                       # all served
    assert {f.uid for f in finished} == set(range(10))
    for f in finished:
        assert 1 <= len(f.tokens) <= 64
    assert steps < 200


def test_engine_matches_unbatched_decode():
    """A request served through the slot engine produces the same greedy
    tokens as a dedicated single-sequence decode."""
    cfg = dataclasses.replace(get_config("minitron-8b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    prompt = np.asarray(
        jax.random.randint(KEY, (5,), 0, cfg.vocab))
    n_new = 5

    # reference: single-sequence decode
    c1 = init_cache(cfg, 1, ctx, dtype=jnp.float32)
    lg, c1 = prefill(params, cfg, jnp.asarray(prompt)[None], c1)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    want = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        lg, c1 = decode_step(params, cfg, c1, tok)
        tok = jnp.argmax(lg[:, 0], -1)[:, None]
        want.append(int(tok[0, 0]))

    eng = _make_engine(cfg, params, B, ctx)

    class Req:
        uid = 7
        max_new_tokens = n_new
    Req.prompt = prompt
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, _ = eng.run(cache, [Req()])
    assert finished[0].tokens == want


def test_engine_single_slot_batch():
    """B=1: requests serialize through the single slot, outputs intact."""
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 1, 64
    eng = _make_engine(cfg, params, B, ctx)
    reqs = RequestGenerator(cfg.vocab, prompt_len=(4, 9), max_new=4,
                            seed=5).generate(3)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, steps = eng.run(cache, reqs)
    assert len(finished) == 3
    assert {f.uid for f in finished} == {0, 1, 2}
    for f in finished:
        assert 1 <= len(f.tokens) <= 4


def test_engine_slot_reuse_after_early_finish():
    """A request hitting EOS frees its slot immediately; the next pending
    request lands in that slot and still decodes correctly."""
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 2, 64
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i), (5,),
                                             0, cfg.vocab))
               for i in range(4)]
    # pick the EOS id so request 0 finishes on its very first decode step
    c1 = init_cache(cfg, 1, ctx, dtype=jnp.float32)
    lg, c1 = prefill(params, cfg, jnp.asarray(prompts[0])[None], c1)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    lg, _ = decode_step(params, cfg, c1, tok)
    eos = int(jnp.argmax(lg[0, 0]))

    eng = _make_engine(cfg, params, B, ctx)
    eng.eos_id = eos

    class Req:
        def __init__(self, uid, prompt, max_new):
            self.uid = uid
            self.prompt = prompt
            self.max_new_tokens = max_new

    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    reqs = [Req(i, p, 8) for i, p in enumerate(prompts)]
    finished, _ = eng.run(cache, reqs)
    assert len(finished) == 4
    by_uid = {f.uid: f for f in finished}
    assert by_uid[0].tokens[-1] == eos or len(by_uid[0].tokens) == 8
    # every request was served despite only two slots
    assert all(len(f.tokens) >= 1 for f in finished)


def test_engine_rejects_request_exceeding_context_budget():
    """A request whose generation would overrun the cache context is
    rejected at admit with a clear error instead of silently clipping
    into the clamped last cache slot."""
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 2, 16
    eng = _make_engine(cfg, params, B, ctx)
    prompt = np.asarray(jax.random.randint(KEY, (10,), 0, cfg.vocab))

    class Req:
        uid = 0
        max_new_tokens = 32          # 10 + 32 >> ctx=16
    Req.prompt = prompt
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    with pytest.raises(ValueError, match="exceeds the cache context"):
        eng.run(cache, [Req()])
    # the engine stays usable: nothing was admitted, no slot leaked
    assert eng.free_slots() == [0, 1]
    fitting = Req()
    fitting.max_new_tokens = 4
    finished, _ = eng.run(cache, [fitting])
    assert len(finished) == 1 and len(finished[0].tokens) == 4


def test_engine_without_ctx_keeps_legacy_clipping():
    """Engines built without ``ctx`` (rolling-SWA caches have no hard
    limit) keep the pre-validation behaviour: clamped writes, token
    budget still honoured."""
    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, KEY)
    B, ctx = 2, 16
    eng = _make_engine(cfg, params, B, ctx)
    eng.ctx = None
    prompt = np.asarray(jax.random.randint(KEY, (10,), 0, cfg.vocab))

    class Req:
        uid = 0
        max_new_tokens = 32
    Req.prompt = prompt
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, steps = eng.run(cache, [Req()])
    assert len(finished) == 1
    assert len(finished[0].tokens) == 32
    assert eng.free_slots() == [0, 1]


def test_profiler_produces_usable_profile():
    prof = profile_local_device_noopt("ci")
    assert prof.cpu_flops["q4k"] > 1e8           # >0.1 GFLOP/s, surely
    assert prof.cpu_membw > 1e7
    assert prof.disk_seq_bps > 1e6
    assert prof.t_kv_copy_cpu < 1.0
    # the profile must drive the scheduler end to end
    mp = profile_from_config(get_config("llama3-8b"))
    sol = halda.solve([prof], mp)
    assert sol.w == [mp.n_layers]


def test_measurements_monotone_sanity():
    f1 = measure_flops(128)
    assert f1 > 0
    bw = measure_membw(1 << 20)
    assert bw > 0
    d = measure_disk(1 << 20)
    assert d > 0
