"""Elastic failover (stage loss -> re-plan) and device-subset selection
(paper A.5): more devices is not always better; drags get dropped."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core import cluster, halda
from repro.core.profiles import (GiB, OS, DeviceProfile, ModelProfile,
                                 QUANTS, paper_table2_cluster,
                                 paper_table2_extra, tpu_stage_cluster)
from repro.runtime import elastic
from repro.runtime.serve import RingPlan


def model_70b():
    return ModelProfile(
        name="llama70b", n_layers=80, layer_bytes=0.48 * GiB,
        input_bytes=0.27 * GiB, output_bytes=0.27 * GiB, embed_dim=8192,
        vocab=128256, kv_heads=8, head_dim=128, n_kv=1024,
        flops_layer={"q4k": 2 * 0.85e9},
        flops_output={"q4k": 2 * 8192 * 128256})


def test_fail_stages_replans():
    cfg = get_config("mixtral-8x7b")
    st = elastic.initial_state(cfg, 16, k=1)
    assert st.plan.L_pad == 32 and st.plan.w == 2
    st2 = elastic.fail_stages(st, cfg, [3])
    assert len(st2.stages) == 15 and 3 not in st2.stages
    assert st2.generation == 1
    # plan still covers every layer
    assert st2.plan.L_pad >= cfg.n_layers
    assert st2.plan.w * st2.plan.k * len(st2.stages) == st2.plan.L_pad


def test_fail_all_raises():
    cfg = get_config("mixtral-8x7b")
    st = elastic.initial_state(cfg, 4)
    with pytest.raises(RuntimeError):
        elastic.fail_stages(st, cfg, [0, 1, 2, 3])


def test_resolve_heterogeneous_survivors():
    devs = paper_table2_cluster()
    sol = elastic.resolve_heterogeneous(devs[:3], model_70b())
    assert sum(sol.w) * sol.k == 80
    sched = elastic.remap_schedule(sol, 80)
    assert sched.n_layers == 80


def test_a5_more_devices_not_always_better():
    """Adding the slow-disk Mac Air (D6) should not improve the cluster;
    select_cluster must not pick a strictly worse superset."""
    devs = paper_table2_cluster() + paper_table2_extra()
    mp = model_70b()
    all6 = halda.solve(devs, mp)
    choice = cluster.select_cluster(devs, mp)
    assert choice.solution.latency <= all6.latency + 1e-9
    assert len(choice.history) >= 1


def test_select_cluster_keeps_head():
    devs = paper_table2_cluster()
    mp = model_70b()
    choice = cluster.select_cluster(devs, mp)
    assert 0 in choice.devices


def test_fail_and_resolve_drops_failed():
    devs = paper_table2_cluster()
    mp = model_70b()
    sol = cluster.fail_and_resolve(devs, mp, failed=[1])
    assert len(sol.w) == 3


def test_tpu_stage_cluster_uniform():
    devs = tpu_stage_cluster(16)
    mp = model_70b()
    sol = halda.solve(devs, mp)
    assert len(set(sol.w)) == 1          # homogeneous stages, equal windows


def test_straggler_gets_smaller_window():
    """Heterogeneous throughput -> Halda shrinks the slow stage's window
    (straggler mitigation via the scheduler)."""
    devs = tpu_stage_cluster(4)
    slow = dataclasses.replace(
        devs[2], name="slow",
        gpu_flops={q: v * 0.25 for q, v in devs[2].gpu_flops.items()})
    devs = [devs[0], devs[1], slow, devs[3]]
    sol = halda.solve(devs, model_70b())
    assert sol.w[2] <= min(sol.w[0], sol.w[1], sol.w[3])
