import os
import sys

# A small 8-device CPU mesh for the distributed (shard_map ring) tests.
# This must be set before jax is first imported anywhere in the test
# process. The 512-device flag stays dry-run-only (launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
