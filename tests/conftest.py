import os
import sys

# A small 8-device CPU mesh for the distributed (shard_map ring) tests.
# This must be set before jax is first imported anywhere in the test
# process. The 512-device flag stays dry-run-only (launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


class hypothesis_fallback:
    """Stand-ins so property-test modules still import (and their plain
    tests run) when ``hypothesis`` is not installed; the ``@given`` tests
    themselves skip."""

    @staticmethod
    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    @staticmethod
    def settings(*_a, **_k):
        return lambda fn: fn

    class st:
        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def data(*_a, **_k):
            return None

        @staticmethod
        def sampled_from(*_a, **_k):
            return None
