"""Grouped quantization: error bounds, packing invertibility, tree pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from conftest import hypothesis_fallback as _hf
    given, settings, st = _hf.given, _hf.settings, _hf.st

from repro.quant import (dequantize_q2, dequantize_q4, pack_q2, pack_q4,
                         quantize_q2, quantize_q4, quantize_tree, unpack_q2,
                         unpack_q4, dequantize_leaf, QuantizedTensor)

KEY = jax.random.PRNGKey(0)


def test_pack_unpack_roundtrip():
    q = jnp.asarray(np.random.default_rng(0).integers(-7, 8, (128, 64)),
                    jnp.int8)
    assert (unpack_q4(pack_q4(q)) == q).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([64, 128, 256]),
       st.sampled_from([32, 64]))
def test_q4_error_bound(seed, K, group):
    """|w - deq(q(w))| <= amax/14 per group (+ bf16 scale slack)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, 32))
    qt = quantize_q4(w, group=group)
    wd = dequantize_q4(qt)
    wg = np.asarray(w).reshape(K // group, group, 32)
    amax = np.abs(wg).max(axis=1)
    bound = amax / 14 + amax * 8e-3 + 1e-6       # bf16 scale rounding slack
    err = np.abs(np.asarray(w - wd)).reshape(K // group, group, 32).max(1)
    assert (err <= bound).all()


def test_q4_memory_footprint():
    w = jax.random.normal(KEY, (512, 256))
    qt = quantize_q4(w, group=64)
    # 4 bits + bf16 scale per 64 weights = 4.25 bits -> ratio vs f32
    assert qt.nbytes / (w.size * 4) < 0.14


def test_pack_q2_roundtrip():
    q = jnp.asarray(np.random.default_rng(1).integers(-1, 2, (128, 32)),
                    jnp.int8)
    assert (unpack_q2(pack_q2(q)) == q).all()


def test_q2_memory_footprint():
    """q2 packs 4 values/byte: ~2.25 bits/weight incl. the bf16 group
    scale — the footprint the streaming byte accounting and the latency
    model's disk term consume, so one-value-per-int8 storage (a 4x
    overstatement of compression) must never come back."""
    w = jax.random.normal(KEY, (512, 256))
    qt = quantize_q2(w, group=64)
    assert qt.packed.shape == (512 // 4, 256)
    # 2 bits + bf16 scale per 64 weights = 2.25 bits -> ratio vs f32
    assert qt.nbytes / (w.size * 4) < 0.09
    # and q2 must now beat q4's footprint, not quadruple it
    assert qt.nbytes < quantize_q4(w, group=64).nbytes


def test_q2_error_bound():
    w = jax.random.normal(KEY, (256, 64))
    qt = quantize_q2(w)
    wd = dequantize_q2(qt)
    wg = np.asarray(w).reshape(4, 64, 64)
    bound = np.abs(wg).max(1) / 2 + np.abs(wg).max(1) * 8e-3 + 1e-6
    # int2 in {-1,0,1} with scale=amax: max err is amax/2 at the midpoints
    err = np.abs(np.asarray(w - wd)).reshape(4, 64, 64).max(1)
    assert (err <= bound + 1e-5).all()


def test_quantize_tree_skips_norms():
    params = {"norm": jnp.ones((64,)), "w": jax.random.normal(KEY, (64, 64)),
              "blocks": {"attn_norm": jnp.ones((8, 64)),
                         "wq": jax.random.normal(KEY, (8, 64, 64))}}
    qp = quantize_tree(params)
    assert isinstance(qp["w"], QuantizedTensor)
    assert isinstance(qp["blocks"]["wq"], QuantizedTensor)
    assert not isinstance(qp["norm"], QuantizedTensor)
    assert not isinstance(qp["blocks"]["attn_norm"], QuantizedTensor)
    # dequantize-leaf roundtrip keeps shape
    wd = dequantize_leaf(qp["blocks"]["wq"])
    assert wd.shape == (8, 64, 64)


def test_quantized_matmul_model_quality():
    """End gate: y = x @ W vs quantized path. Symmetric int4 RTN noise for
    gaussian weights is amax/(7·√12) ≈ 0.11σ (group-64 amax ≈ 2.7σ);
    llama.cpp's Q4K improves on this with affine super-blocks, our grouped
    format matches plain RTN theory."""
    x = jax.random.normal(KEY, (32, 512)) / 22.6
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) / 22.6
    qt = quantize_q4(w)
    y = x @ w
    yq = x @ dequantize_q4(qt)
    rel = float(jnp.linalg.norm(y - yq) / jnp.linalg.norm(y))
    assert rel < 0.13, rel


def test_qmm_fused_dispatch_matches_dequant_matmul():
    """layers.qmm: fused-kernel dispatch (eligible shapes) and the
    dequantize fallback must agree, and plain weights pass through."""
    from repro.models.layers import q4_fused_eligible, qmm

    x = jax.random.normal(KEY, (2, 3, 128))           # M = 6 (fused)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
    qt = quantize_q4(w)
    assert q4_fused_eligible(qt)
    out = qmm(x, qt)
    want = x @ dequantize_q4(qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # plain-array passthrough
    np.testing.assert_allclose(np.asarray(qmm(x, w)), np.asarray(x @ w),
                               rtol=1e-6, atol=1e-6)
    # M = 384 does not divide the kernel's row tile -> dequant fallback,
    # same numbers
    x_big = jax.random.normal(jax.random.PRNGKey(2), (384, 128))
    np.testing.assert_allclose(np.asarray(qmm(x_big, qt)),
                               np.asarray(x_big @ dequantize_q4(qt)),
                               rtol=1e-5, atol=1e-5)
    # q2 and 3-D (stacked expert) tensors are never fused-eligible
    from repro.quant import quantize_q2
    assert not q4_fused_eligible(quantize_q2(w))
    w3 = jax.random.normal(jax.random.PRNGKey(3), (4, 128, 64))
    assert not q4_fused_eligible(quantize_q4(w3))
