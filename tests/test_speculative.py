"""Speculative decoding: multi-token verify correctness, greedy
exact-match vs vanilla decode, rollback, acceptance bookkeeping, and the
ContinuousBatcher integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, init_cache, init_params, prefill,
                          rollback_cache)
from repro.runtime.engine import ContinuousBatcher
from repro.runtime.speculative import (SpeculativeDecoder,
                                       expected_tokens_per_cycle)

KEY = jax.random.PRNGKey(0)


def _small(arch, n_layers=2):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers)


def _greedy_reference(cfg, params, prompt, n_new, ctx=64):
    c = init_cache(cfg, 1, ctx, dtype=jnp.float32)
    lg, c = prefill(params, cfg, jnp.asarray(prompt)[None], c)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        lg, c = decode_step(params, cfg, c, tok)
        tok = jnp.argmax(lg[:, 0], -1)[:, None]
        out.append(int(tok[0, 0]))
    return out


# --------------------------------------------------------------------------- #
#  multi-token decode_step == sequential decode_step
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ["qwen2.5-14b", "minicpm3-4b",
                                  "qwen1.5-32b", "phi3.5-moe-42b-a6.6b"])
def test_multi_token_decode_matches_sequential(arch):
    cfg = _small(arch)
    params = init_params(cfg, KEY)
    B, ctx, T = 2, 64, 4
    prompt = jax.random.randint(KEY, (B, 5), 0, cfg.vocab)
    c = init_cache(cfg, B, ctx, dtype=jnp.float32)
    _, c = prefill(params, cfg, prompt, c)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    c_seq = c
    refs = []
    for t in range(T):
        lg, c_seq = decode_step(params, cfg, c_seq, toks[:, t:t + 1])
        refs.append(lg[:, 0])
    ref = jnp.stack(refs, 1)
    out, c_v = decode_step(params, cfg, c, toks)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(out - ref))) / scale < 1e-5
    np.testing.assert_array_equal(np.asarray(c_v["len"]),
                                  np.asarray(c_seq["len"]))


def test_rollback_then_decode_matches_prefix():
    """After rejecting draft positions, decoding from the rolled-back cache
    must equal decoding from a cache that never saw the rejects."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx, T, keep = 2, 64, 4, 2
    prompt = jax.random.randint(KEY, (B, 5), 0, cfg.vocab)
    c0 = init_cache(cfg, B, ctx, dtype=jnp.float32)
    _, c0 = prefill(params, cfg, prompt, c0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)

    _, c_spec = decode_step(params, cfg, c0, toks)          # writes T
    c_rb = rollback_cache(c_spec, c0["len"] + keep)

    c_ref = c0
    for t in range(keep):
        _, c_ref = decode_step(params, cfg, c_ref, toks[:, t:t + 1])

    probe = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    lg_rb, _ = decode_step(params, cfg, c_rb, probe)
    lg_ref, _ = decode_step(params, cfg, c_ref, probe)
    scale = float(jnp.max(jnp.abs(lg_ref)))
    assert float(jnp.max(jnp.abs(lg_rb - lg_ref))) / scale < 1e-5


# --------------------------------------------------------------------------- #
#  SpeculativeDecoder: exact-match + acceptance bookkeeping
# --------------------------------------------------------------------------- #

def _spec_engine(t_cfg, t_params, d_cfg, d_params, B, ctx, gamma,
                 eos_id=None):
    def prefill_one(prompt):
        c1 = init_cache(t_cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(t_params, t_cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def d_prefill_one(prompt):
        c1 = init_cache(d_cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(d_params, d_cfg, prompt, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def make_write_slot(B_):
        def write_slot(cache, slot_cache, slot, length):
            def wr(dst, src):
                if dst.ndim >= 2 and dst.shape[1] == B_ \
                        and src.shape[1] == 1:
                    return dst.at[:, slot].set(src[:, 0])
                return dst
            new = jax.tree.map(wr, cache, slot_cache)
            new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
            return new
        return write_slot

    spec = SpeculativeDecoder(
        lambda c, t: decode_step(d_params, d_cfg, c, t),
        lambda c, t: decode_step(t_params, t_cfg, c, t),
        gamma=gamma,
        draft_cache=init_cache(d_cfg, B, ctx, dtype=jnp.float32),
        draft_prefill_one=d_prefill_one,
        draft_write_slot=make_write_slot(B))

    eng = ContinuousBatcher(
        B, prefill_one, make_write_slot(B),
        lambda c, t: decode_step(t_params, t_cfg, c, t),
        eos_id=eos_id, spec=spec)
    return eng


class _Req:
    def __init__(self, uid, prompt, max_new):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new


def test_speculative_exact_match_distinct_draft():
    """Greedy speculative output == vanilla greedy target output, with an
    *independent* draft model (imperfect acceptance)."""
    gamma = 2
    t_cfg = _small("qwen2.5-14b")
    d_cfg = dataclasses.replace(t_cfg, d_model=32, d_ff=64, name="draft")
    t_params = init_params(t_cfg, KEY)
    d_params = init_params(d_cfg, jax.random.PRNGKey(9))
    B, ctx, n_new = 2, 64, 10
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i), (5,),
                                             0, t_cfg.vocab))
               for i in range(3)]
    want = [_greedy_reference(t_cfg, t_params, p, n_new, ctx)
            for p in prompts]

    eng = _spec_engine(t_cfg, t_params, d_cfg, d_params, B, ctx, gamma)
    cache = init_cache(t_cfg, B, ctx, dtype=jnp.float32)
    reqs = [_Req(i, p, n_new) for i, p in enumerate(prompts)]
    finished, _ = eng.run(cache, reqs)
    assert len(finished) == 3
    got = {f.uid: f.tokens for f in finished}
    for i in range(3):
        assert got[i] == want[i], i
    # an independent random draft should not be perfect
    total_prop = sum(f.proposed for f in finished)
    assert total_prop > 0


def test_speculative_self_draft_accepts_everything():
    """Draft == target => every draft token is accepted, and each cycle
    emits gamma+1 tokens."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx, gamma, n_new = 1, 64, 3, 9
    prompt = np.asarray(jax.random.randint(KEY, (5,), 0, cfg.vocab))
    want = _greedy_reference(cfg, params, prompt, n_new, ctx)

    eng = _spec_engine(cfg, params, cfg, params, B, ctx, gamma)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, steps = eng.run(cache, [_Req(0, prompt, n_new)])
    assert finished[0].tokens == want
    assert finished[0].accepted == finished[0].proposed  # all accepted
    assert finished[0].acceptance_rate == 1.0
    # 8 tokens decoded after the prefill token, gamma+1=4 per cycle
    assert eng.spec.cycles == 2


def test_speculative_budget_truncation():
    """A cycle that overshoots the request budget must truncate: the slot
    frees with exactly max_new tokens."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx, gamma, n_new = 1, 64, 3, 3   # cycle emits up to 4, budget 3
    prompt = np.asarray(jax.random.randint(KEY, (5,), 0, cfg.vocab))
    want = _greedy_reference(cfg, params, prompt, n_new, ctx)
    eng = _spec_engine(cfg, params, cfg, params, B, ctx, gamma)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, _ = eng.run(cache, [_Req(0, prompt, n_new)])
    assert finished[0].tokens == want
    assert len(finished[0].tokens) == n_new


def test_speculative_slot_reuse_after_early_finish():
    """B=2 slots, 4 requests; a request finishing mid-stream frees its slot
    for the next pending request, draft cache included."""
    t_cfg = _small("qwen2.5-14b")
    d_cfg = dataclasses.replace(t_cfg, d_model=32, d_ff=64, name="draft")
    t_params = init_params(t_cfg, KEY)
    d_params = init_params(d_cfg, jax.random.PRNGKey(9))
    B, ctx = 2, 64
    lens = [3, 9, 6, 4]
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i), (4,),
                                             0, t_cfg.vocab))
               for i in range(4)]
    want = [_greedy_reference(t_cfg, t_params, p, n, ctx)
            for p, n in zip(prompts, lens)]
    eng = _spec_engine(t_cfg, t_params, d_cfg, d_params, B, ctx, gamma=2)
    cache = init_cache(t_cfg, B, ctx, dtype=jnp.float32)
    reqs = [_Req(i, p, n) for i, (p, n) in enumerate(zip(prompts, lens))]
    finished, _ = eng.run(cache, reqs)
    assert len(finished) == 4
    got = {f.uid: f.tokens for f in finished}
    for i in range(4):
        assert got[i] == want[i], i


def test_speculative_padded_vocab_logits():
    """With vocab-padded logits (the ring verify step pads to a multiple
    of tp), the decoder must slice before argmax — a zero pad column
    would otherwise win whenever every real logit is negative."""
    cfg = _small("qwen2.5-14b")
    params = init_params(cfg, KEY)
    B, ctx, gamma, n_new, pad = 1, 64, 2, 8, 32
    prompt = np.asarray(jax.random.randint(KEY, (5,), 0, cfg.vocab))
    want = _greedy_reference(cfg, params, prompt, n_new, ctx)

    def padded(fn):
        def wrapped(c, t):
            lg, c = fn(c, t)
            return jnp.pad(lg, ((0, 0), (0, 0), (0, pad))), c
        return wrapped

    def prefill_one(p):
        c1 = init_cache(cfg, 1, ctx, dtype=jnp.float32)
        logits, c1 = prefill(params, cfg, p, c1)
        return int(jnp.argmax(logits[0, -1])), c1

    def write_slot(cache, slot_cache, slot, length):
        def wr(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == B and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        new = jax.tree.map(wr, cache, slot_cache)
        new["len"] = cache["len"].at[slot].set(slot_cache["len"][0])
        return new

    base = lambda c, t: decode_step(params, cfg, c, t)   # noqa: E731
    spec = SpeculativeDecoder(
        padded(base), padded(base), gamma=gamma, vocab=cfg.vocab,
        draft_cache=init_cache(cfg, B, ctx, dtype=jnp.float32),
        draft_prefill_one=prefill_one, draft_write_slot=write_slot)
    eng = ContinuousBatcher(B, prefill_one, write_slot, base, spec=spec)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    finished, _ = eng.run(cache, [_Req(0, prompt, n_new)])
    assert finished[0].tokens == want
    assert finished[0].acceptance_rate == 1.0    # self-draft


def test_expected_tokens_per_cycle():
    assert expected_tokens_per_cycle(0.0, 4) == 1.0
    assert expected_tokens_per_cycle(1.0, 4) == 5.0
    e = expected_tokens_per_cycle(0.75, 4)
    assert 3.0 < e < 3.1                      # (1 - .75^5) / .25 ~ 3.051
    # monotone in both arguments
    assert expected_tokens_per_cycle(0.8, 4) > e
    assert expected_tokens_per_cycle(0.75, 6) > e
