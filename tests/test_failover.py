"""Elastic ring failover: an injected stage failure mid-decode must
trigger an elastic re-solve, rebuild on the survivors, and resume from
the last emitted token — post-recovery tokens bit-identical to a clean
run on the survivor mesh fed the same history."""
import dataclasses
import shutil
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.profiles import paper_table2_cluster
from repro.models import init_params
from repro.runtime import elastic
from repro.runtime.failover import ElasticRingServer, FailoverEvent
from repro.runtime.faults import FaultInjector, FaultSpec, FaultyStore
from repro.runtime.iopolicy import IOPolicy
from repro.runtime.paramstore import ParamStore, save_param_store

from test_elastic_cluster import model_70b

KEY = jax.random.PRNGKey(0)

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 CPU devices (conftest sets flag)")

FAST = IOPolicy(max_retries=2, backoff_base_s=0.002, backoff_max_s=0.01,
                op_deadline_s=10.0, get_timeout_s=30.0)

B, S, MAX_NEW, N_STAGES, TP = 8, 4, 6, 4, 2


def _cfg():
    return dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                               n_layers=8)


class _Counting:
    """ParamStore proxy that counts layer reads (to find a mid-decode
    call index for the fault schedule)."""

    def __init__(self, store):
        self.store = store
        self.reads = 0

    def layer(self, i):
        self.reads += 1
        return self.store.layer(i)

    def __getattr__(self, name):
        return getattr(self.store, name)


@pytest.fixture(scope="module")
def ring_env():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    d = tempfile.mkdtemp(prefix="test_failover_")
    save_param_store(params, cfg, d)
    prompts = np.asarray(jax.random.randint(KEY, (B, S), 0, cfg.vocab),
                         np.int32)
    # probe: a short clean run on the full 4-stage ring measures how many
    # layer reads precede "two tokens emitted" — the chaos schedules fire
    # at that call index, i.e. somewhere mid-decode
    counting = _Counting(ParamStore(d))
    srv = ElasticRingServer(cfg, counting, params, batch=B, ctx=32,
                            n_stages=N_STAGES, tp=TP, policy=FAST)
    try:
        probe = srv.generate(prompts, 2)
    finally:
        srv.close()
        counting.close()
    env = dict(cfg=cfg, params=params, dir=d, prompts=prompts,
               probe=probe, reads_2=counting.reads)
    yield env
    shutil.rmtree(d, ignore_errors=True)


def _reference(env, n_stages, k, history_tokens, n_new):
    """Clean run on an ``n_stages`` ring fed prompt+history as prompt."""
    ref = ElasticRingServer(env["cfg"], ParamStore(env["dir"]),
                            env["params"], batch=B, ctx=32,
                            n_stages=n_stages, tp=TP, k=k, policy=FAST)
    try:
        pr = np.concatenate([env["prompts"], history_tokens], axis=1) \
            if history_tokens.shape[1] else env["prompts"]
        return ref.generate(pr, n_new)
    finally:
        ref.close()
        ref.store.close()


@needs_8_devices
def test_stage_failure_triggers_elastic_failover(ring_env):
    env = ring_env
    inj = FaultInjector([FaultSpec(op="layer_read", mode="stage_failure",
                                   stage=1, after=env["reads_2"],
                                   times=1)])
    store = FaultyStore(ParamStore(env["dir"]), inj)
    srv = ElasticRingServer(
        env["cfg"], store, env["params"], batch=B, ctx=32,
        n_stages=N_STAGES, tp=TP, policy=FAST,
        device_profiles=paper_table2_cluster(),
        model_profile=model_70b())
    try:
        toks = srv.generate(env["prompts"], MAX_NEW)
    finally:
        srv.close()
        store.close()

    assert toks.shape == (B, MAX_NEW)
    assert len(inj.fired) == 1               # the stage really died once
    assert len(srv.events) == 1
    ev = srv.events[0]
    assert isinstance(ev, FailoverEvent)
    assert ev.failed_stage == 1
    assert ev.n_stages_before == N_STAGES
    # batch 8 % 3 != 0: graceful degradation drops a healthy stage too
    assert ev.n_stages_after == 2
    assert ev.tokens_lost == 0
    assert 1 <= ev.token_index < MAX_NEW
    assert ev.replayed_tokens == S + ev.token_index
    assert ev.recovery_s > 0
    assert ev.halda is not None and ev.halda["k"] >= 1   # re-solve ran
    assert ev.plan["n_stages"] == 2

    # pre-failure tokens match the healthy 4-stage run
    n_pre = min(ev.token_index, env["probe"].shape[1])
    assert np.array_equal(toks[:, :n_pre], env["probe"][:, :n_pre])
    # post-recovery tokens are bit-identical to a clean run on the
    # survivor mesh fed the same history (resume, not restart)
    ref = _reference(env, ev.plan["n_stages"], ev.plan["k"],
                     toks[:, :ev.token_index], MAX_NEW - ev.token_index)
    assert np.array_equal(toks[:, ev.token_index:], ref)


@needs_8_devices
def test_unattributed_failure_rebuilds_same_stages(ring_env):
    env = ring_env
    # a fatal non-stage error (poisoned read) is not attributed to a
    # stage: the server rebuilds the same 4-stage ring and resumes
    inj = FaultInjector([FaultSpec(op="layer_read", mode="error",
                                   error_type=ValueError,
                                   after=env["reads_2"], times=1)])
    store = FaultyStore(ParamStore(env["dir"]), inj)
    srv = ElasticRingServer(env["cfg"], store, env["params"], batch=B,
                            ctx=32, n_stages=N_STAGES, tp=TP, policy=FAST)
    try:
        toks = srv.generate(env["prompts"], MAX_NEW)
    finally:
        srv.close()
        store.close()

    assert len(srv.events) == 1
    ev = srv.events[0]
    assert ev.failed_stage is None
    assert ev.n_stages_after == N_STAGES
    assert ev.tokens_lost == 0
    ref = _reference(env, N_STAGES, ev.plan["k"],
                     toks[:, :ev.token_index], MAX_NEW - ev.token_index)
    assert np.array_equal(toks[:, ev.token_index:], ref)


@needs_8_devices
def test_failover_budget_exhausted_reraises(ring_env):
    env = ring_env
    inj = FaultInjector([FaultSpec(op="layer_read", times=-1)])
    store = FaultyStore(ParamStore(env["dir"]), inj)
    srv = ElasticRingServer(env["cfg"], store, env["params"], batch=B,
                            ctx=32, n_stages=N_STAGES, tp=TP, policy=FAST,
                            max_failovers=1)
    try:
        with pytest.raises(Exception):
            srv.generate(env["prompts"], MAX_NEW)
    finally:
        srv.close()
        store.close()


def test_feasible_shrinks_survivors_to_batch_divisor():
    cfg = _cfg()
    params = init_params(cfg, KEY)
    srv = ElasticRingServer(cfg, object(), params, batch=8, ctx=32,
                            n_stages=4, tp=2)
    st = elastic.fail_stages(srv.state, cfg, [1])   # 3 survivors: 8 % 3
    st = srv._feasible(st)
    assert len(st.stages) == 2 and srv.batch % len(st.stages) == 0


def test_feasible_raises_when_no_ring_fits():
    # tp wider than the machine: even a 1-stage ring needs tp devices
    cfg = _cfg()
    params = init_params(cfg, KEY)
    srv = ElasticRingServer(cfg, object(), params, batch=8, ctx=32,
                            n_stages=4, tp=2 * jax.device_count())
    with pytest.raises(RuntimeError, match="no feasible ring"):
        srv._feasible(srv.state)


def test_recovery_s_property():
    ev = FailoverEvent(token_index=3, failed_stage=1, generation=1,
                       n_stages_before=4, n_stages_after=2,
                       plan={"n_stages": 2, "k": 2, "w": 2, "L_pad": 8},
                       halda=None, detect_s=0.1, resolve_s=0.2,
                       rebuild_s=0.3, replay_s=0.4, tokens_lost=0,
                       replayed_tokens=6)
    assert ev.recovery_s == pytest.approx(1.0)
