"""Quantized (v2) layer store: packed int4 persists through the manifest,
round-trips to zero-copy QuantizedTensor views, streams through the
prefetch window with packed-byte accounting, and reproduces the
resident-dequantized logits exactly. Plus the store-hardening sweep:
v1 backward compatibility, corrupt/truncated manifests, and the
``willneed`` bounds/error-propagation fix."""
import dataclasses
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.latency import quantized_layer_bytes
from repro.models import (decode_step, decode_step_layerwise, init_cache,
                          init_params, prefill, prefill_layerwise)
from repro.quant import QuantizedTensor, dequantize_tree, quantize_tree
from repro.runtime.paramstore import (MANIFEST, ParamStore, save_param_store)
from repro.runtime.streaming import StreamingParamSource

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen2.5-14b", n_layers=4, **over):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers, **over)


@pytest.fixture()
def store_dir():
    d = tempfile.mkdtemp(prefix="test_qstore_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _trees_exact(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree.leaves(flags))


def _quantized(params):
    qp = dict(params)
    qp["blocks"] = quantize_tree(params["blocks"], bits=4, stacked=True)
    return qp


# --------------------------------------------------------------------------- #
#  v2 round-trip
# --------------------------------------------------------------------------- #

def test_quantized_store_roundtrip_exact(store_dir):
    """save(quantize_tree(params)) -> layer(i) -> dequant must equal the
    resident quantize+dequant exactly (same packed codes, same scales)."""
    cfg = _cfg()
    qp = _quantized(init_params(cfg, KEY))
    save_param_store(qp, cfg, store_dir)
    with ParamStore(store_dir) as store:
        assert store.version == 2
        assert store.quant_format == "q4"
        assert store.n_layers == cfg.n_layers
        for i in range(cfg.n_layers):
            got = store.layer(i)
            want = jax.tree.map(lambda a: a[i], qp["blocks"])
            # packed codes + scales round-trip bit-exactly...
            leaf = got["attn"]["wq"]
            ref = want["attn"]["wq"]
            assert isinstance(leaf, QuantizedTensor)
            assert leaf.bits == ref.bits and leaf.group == ref.group
            assert np.array_equal(np.asarray(leaf.packed),
                                  np.asarray(ref.packed))
            assert np.array_equal(np.asarray(leaf.scale),
                                  np.asarray(ref.scale))
            # ...so dequantization is exactly the resident computation
            assert _trees_exact(dequantize_tree(got), dequantize_tree(want))


def test_quantized_store_packed_footprint(store_dir):
    """The store's layer files hold the packed bytes: well under a bf16
    store of the same blocks, and near the analytic reduced-b estimate."""
    cfg = _cfg()
    params = init_params(cfg, KEY)
    save_param_store(_quantized(params), cfg, store_dir)
    bdir = tempfile.mkdtemp(prefix="test_qstore_bf16_")
    try:
        bf16 = dict(params)
        bf16["blocks"] = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                                      params["blocks"])
        save_param_store(bf16, cfg, bdir)
        with ParamStore(store_dir) as qs, ParamStore(bdir) as bs:
            ratio = qs.layer_nbytes / bs.layer_nbytes
            assert ratio <= 0.35, ratio
            # analytic reduced b (norms/biases stream f32 here, so the
            # store sits a little above the pure-weight estimate)
            est = quantized_layer_bytes(bs.layer_nbytes)
            assert est <= qs.layer_nbytes <= 1.5 * est
    finally:
        shutil.rmtree(bdir, ignore_errors=True)


def test_quantized_store_head_leaves(store_dir):
    """QuantizedTensor head leaves (e.g. a quantized unembed) persist and
    reassemble like block leaves."""
    from repro.quant import quantize_q4

    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    qp = _quantized(params)
    if "unembed" not in qp:
        qp["unembed"] = jax.random.normal(KEY, (cfg.d_model, cfg.vocab))
    qp["unembed"] = quantize_q4(qp["unembed"])
    save_param_store(qp, cfg, store_dir)
    with ParamStore(store_dir) as store:
        head = store.head()
        assert isinstance(head["unembed"], QuantizedTensor)
        assert _trees_exact(dequantize_tree(head["unembed"]),
                            dequantize_tree(qp["unembed"]))


def test_quantized_store_64_layers_skips_stacked_biases(store_dir):
    """n_layers divisible by the group must not turn (L, D) bias leaves
    into cross-layer 'weights': stacked=True quantization only touches
    ndim>=3 matmul leaves, so the per-layer store sharding survives at
    the paper's 30-70B layer counts (e.g. 64-layer qwen1.5-32b)."""
    cfg = _cfg("qwen1.5-32b", n_layers=64)
    params = init_params(cfg, KEY)
    qp = dict(params)
    qp["blocks"] = quantize_tree(params["blocks"], bits=4, stacked=True)
    assert isinstance(qp["blocks"]["attn"]["wq"], QuantizedTensor)
    assert not isinstance(qp["blocks"]["attn"]["bq"], QuantizedTensor)
    save_param_store(qp, cfg, store_dir)          # used to raise: axis != L
    with ParamStore(store_dir) as store:
        assert store.n_layers == 64
        got = store.layer(63)
        want = jax.tree.map(lambda a: a[63], qp["blocks"])
        assert _trees_exact(dequantize_tree(got), dequantize_tree(want))


def test_quantized_store_ssm(store_dir):
    cfg = _cfg("mamba2-780m", n_layers=2)
    qp = _quantized(init_params(cfg, KEY))
    save_param_store(qp, cfg, store_dir)
    with ParamStore(store_dir) as store:
        got = dequantize_tree(store.layer(1))
        want = dequantize_tree(jax.tree.map(lambda a: a[1], qp["blocks"]))
        assert _trees_exact(got, want)


# --------------------------------------------------------------------------- #
#  streamed decode: packed bytes through the window, exact parity
# --------------------------------------------------------------------------- #

def test_streamed_q4_matches_resident_dequantized(store_dir):
    """Streaming the packed store must reproduce the resident-dequantized
    tokens exactly, while staging ~4x fewer bytes per layer."""
    cfg = _cfg(n_layers=4)
    params = init_params(cfg, KEY)
    qp = _quantized(params)
    dp = dict(params)
    dp["blocks"] = dequantize_tree(qp["blocks"], jnp.float32)
    save_param_store(qp, cfg, store_dir)
    raw_layer = sum(a.nbytes for a in
                    jax.tree.leaves(params["blocks"])) // cfg.n_layers

    B, S, steps = 2, 8, 3
    toks = jax.random.randint(KEY, (B, S + steps), 0, cfg.vocab)
    cache_r = init_cache(cfg, B, 32, dtype=jnp.float32)
    lg_r, cache_r = prefill(dp, cfg, toks[:, :S], cache_r)

    src = StreamingParamSource(ParamStore(store_dir), window=2)
    try:
        cache_s = init_cache(cfg, B, 32, dtype=jnp.float32)
        lg_s, cache_s = prefill_layerwise(src, cfg, toks[:, :S], cache_s)
        assert _trees_exact(jnp.argmax(lg_r[:, -1], -1),
                            jnp.argmax(lg_s[:, -1], -1))
        for t in range(S, S + steps):
            lg_r, cache_r = decode_step(dp, cfg, cache_r, toks[:, t:t + 1])
            lg_s, cache_s = decode_step_layerwise(src, cfg, cache_s,
                                                  toks[:, t:t + 1])
            assert _trees_exact(jnp.argmax(lg_r[:, 0], -1),
                                jnp.argmax(lg_s[:, 0], -1))
        st = src.stats()
        # byte accounting sees the packed leaves, not the dequant width
        assert st.bytes_per_layer == src.store.layer_nbytes
        assert st.bytes_per_layer < 0.35 * raw_layer / 2  # vs bf16 = raw/2
        assert st.peak_resident_bytes <= 2 * src.store.layer_nbytes
    finally:
        src.close()


# --------------------------------------------------------------------------- #
#  quantized store through the streamed SPMD ring
# --------------------------------------------------------------------------- #

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 CPU devices (conftest sets flag)")


@needs_8_devices
def test_ring_stream_quantized_store(store_dir):
    from repro.runtime import serve
    from repro.runtime.streaming import StreamingRingDriver

    cfg = _cfg(n_layers=8)
    params = init_params(cfg, KEY)
    pq, skipped = serve.quantize_ring_params(dict(params), cfg, tp=2)
    assert skipped == []
    pd = dict(pq)
    pd["blocks"] = serve.dequant_ring_reference(pq["blocks"])

    B, Smax, steps = 8, 32, 3
    toks = jax.random.randint(KEY, (B, steps), 0, cfg.vocab)
    cache_r = init_cache(cfg, B, Smax, dtype=jnp.float32)
    refs = []
    for t in range(steps):
        lg, cache_r = decode_step(pd, cfg, cache_r, toks[:, t:t + 1])
        refs.append(lg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = serve.RingPlan.make(cfg, 4, k=2)
    head = {k: v for k, v in serve.pad_vocab(dict(params), cfg, 2).items()
            if k != "blocks"}
    cache_s = init_cache(cfg, B, Smax, dtype=jnp.float32)
    cache_s["layers"] = serve.pad_and_permute(cache_s["layers"], cfg, 4, 2)

    save_param_store(pq, cfg, store_dir)
    drv = StreamingRingDriver(cfg, mesh, plan, ParamStore(store_dir),
                              head_params=head, cache_like=cache_s)
    ln = jnp.zeros((B,), jnp.int32)
    scale = float(jnp.max(jnp.abs(refs[-1])))
    for t in range(steps):
        logits, cache_s = drv.step(toks[:, t:t + 1], ln, cache_s)
        ln = ln + 1
        rel = float(jnp.max(jnp.abs(
            logits[:, :, :cfg.vocab] - refs[t]))) / scale
        assert rel < 2e-4, (t, rel)
    assert drv.stats().total_bytes_read > 0
    drv.close()


# --------------------------------------------------------------------------- #
#  manifest compatibility + error paths
# --------------------------------------------------------------------------- #

def test_v1_manifest_backward_compat(store_dir):
    """Unquantized saves stay version 1 and load byte-identically — a v2
    reader must accept stores written before quantized leaves existed."""
    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    mpath = os.path.join(store_dir, MANIFEST)
    with open(mpath) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert all("part" not in d and "quant" not in d for d in m["leaves"])
    # a genuinely old manifest has no version key at all -> implied v1
    del m["version"]
    with open(mpath, "w") as f:
        json.dump(m, f)
    with ParamStore(store_dir) as store:
        assert store.version == 1
        assert store.quant_format is None
        want = jax.tree.map(lambda a: a[0], params["blocks"])
        assert _trees_exact(store.layer(0), want)


def test_corrupt_manifest_raises(store_dir):
    cfg = _cfg(n_layers=2)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    mpath = os.path.join(store_dir, MANIFEST)

    with open(mpath) as f:
        good = f.read()

    # truncated mid-JSON
    with open(mpath, "w") as f:
        f.write(good[:len(good) // 2])
    with pytest.raises(ValueError, match="corrupt param-store manifest"):
        ParamStore(store_dir)

    # future / unknown version
    m = json.loads(good)
    m["version"] = 99
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="unsupported param-store"):
        ParamStore(store_dir)

    # valid JSON but missing required keys
    m = json.loads(good)
    del m["leaves"]
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="missing"):
        ParamStore(store_dir)


def test_quantized_manifest_missing_subleaf_raises(store_dir):
    """A v2 manifest whose scale sub-leaf vanished is corruption, not a
    silently-bf16 layer."""
    cfg = _cfg(n_layers=2)
    save_param_store(_quantized(init_params(cfg, KEY)), cfg, store_dir)
    mpath = os.path.join(store_dir, MANIFEST)
    with open(mpath) as f:
        m = json.load(f)
    m["leaves"] = [d for d in m["leaves"] if d.get("part") != "scale"]
    with open(mpath, "w") as f:
        json.dump(m, f)
    store = ParamStore(store_dir)
    try:
        with pytest.raises(ValueError, match="missing its scale"):
            store.layer(0)
    finally:
        store.close()


def test_quantized_manifest_null_quant_record_raises(store_dir):
    """quant: null on a packed/scale sub-leaf is corruption too — it must
    raise the same descriptive ValueError, not leak a KeyError."""
    cfg = _cfg(n_layers=2)
    save_param_store(_quantized(init_params(cfg, KEY)), cfg, store_dir)
    mpath = os.path.join(store_dir, MANIFEST)
    with open(mpath) as f:
        m = json.load(f)
    for d in m["leaves"]:
        if d.get("part"):
            d["quant"] = None
    with open(mpath, "w") as f:
        json.dump(m, f)
    store = ParamStore(store_dir)
    try:
        with pytest.raises(ValueError, match="quant record is missing"):
            store.layer(0)
    finally:
        store.close()


# --------------------------------------------------------------------------- #
#  willneed: bounds + error propagation (the prefetch-hint bugfix)
# --------------------------------------------------------------------------- #

def test_willneed_out_of_range_raises(store_dir):
    cfg = _cfg(n_layers=2)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    with ParamStore(store_dir) as store:
        store.willneed(0)                    # in range: fine
        store.willneed(cfg.n_layers - 1)
        with pytest.raises(IndexError):
            store.willneed(cfg.n_layers)     # past the stack
        with pytest.raises(IndexError):
            store.willneed(-1)


def test_willneed_missing_layer_file_propagates(store_dir):
    """A vanished layer_*.bin is store corruption — willneed must surface
    the OSError instead of swallowing it as a failed madvise hint."""
    cfg = _cfg(n_layers=2)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    os.remove(os.path.join(store_dir, "layer_00001.bin"))
    with ParamStore(store_dir) as store:
        store.willneed(0)                    # intact layer still fine
        with pytest.raises(OSError):
            store.willneed(1)


def test_streamed_q4_mla_matches_resident_dequantized(store_dir):
    """Regression: MLA consumes its o-proj outside ``layers.qmm``'s
    original call sites — a quantized store streamed through the
    layer-wise MLA path must still decode (packed ``wo`` routed through
    the fused dispatch) and match the resident-dequantized tokens."""
    cfg = _cfg("minicpm3-4b", n_layers=2)
    params = init_params(cfg, KEY)
    qp = _quantized(params)
    dp = dict(params)
    dp["blocks"] = dequantize_tree(qp["blocks"], jnp.float32)
    save_param_store(qp, cfg, store_dir)

    B, S, steps = 2, 6, 3
    toks = jax.random.randint(KEY, (B, S + steps), 0, cfg.vocab)
    cache_r = init_cache(cfg, B, 32, dtype=jnp.float32)
    lg_r, cache_r = prefill(dp, cfg, toks[:, :S], cache_r)

    src = StreamingParamSource(ParamStore(store_dir), window=2)
    try:
        cache_s = init_cache(cfg, B, 32, dtype=jnp.float32)
        lg_s, cache_s = prefill_layerwise(src, cfg, toks[:, :S], cache_s)
        assert _trees_exact(jnp.argmax(lg_r[:, -1], -1),
                            jnp.argmax(lg_s[:, -1], -1))
        for t in range(S, S + steps):
            lg_r, cache_r = decode_step(dp, cfg, cache_r, toks[:, t:t + 1])
            lg_s, cache_s = decode_step_layerwise(src, cfg, cache_s,
                                                  toks[:, t:t + 1])
            assert _trees_exact(jnp.argmax(lg_r[:, 0], -1),
                                jnp.argmax(lg_s[:, 0], -1))
    finally:
        src.close()
