"""Unit tests for the unified runtime tracer (``runtime.telemetry``):
ring-buffer bounds, concurrent emission, the disabled no-op path,
Chrome-trace schema validity, exclusive-time stall attribution, clock
unification across subsystems, and the uniform stats surfaces."""
import json
import threading
import time

import numpy as np
import pytest

from repro.runtime.telemetry import (
    COMPONENTS, NULL_TRACER, CounterEvent, InstantEvent, SpanEvent,
    StallRecord, Tracer, clock, format_summary, stall_summary,
    validate_chrome_trace)


# --------------------------------------------------------------------------- #
#  ring buffer
# --------------------------------------------------------------------------- #

def test_ring_buffer_wraparound():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 8
    assert tr.evicted == 12
    # the ring keeps the NEWEST events
    assert [e.name for e in evs] == [f"e{i}" for i in range(12, 20)]


def test_stall_ring_bounded():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.token_step(i):
            pass
    assert len(tr.stalls()) == 4
    assert tr.stalls_evicted == 6
    assert [r.index for r in tr.stalls()] == [6, 7, 8, 9]


def test_deterministic_sampling():
    tr = Tracer(capacity=1000, sample=0.5)
    for i in range(100):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 50        # exactly 1-in-2, no RNG
    tr2 = Tracer(capacity=1000, sample=0.5)
    for i in range(100):
        tr2.instant(f"e{i}")
    assert [e.name for e in tr.events()] == [e.name for e in tr2.events()]


def test_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity=0)
    with pytest.raises(ValueError):
        Tracer(sample=0.0)
    with pytest.raises(ValueError):
        Tracer(sample=1.5)


# --------------------------------------------------------------------------- #
#  concurrency
# --------------------------------------------------------------------------- #

def test_concurrent_emit_from_many_threads():
    tr = Tracer(capacity=10_000)
    n_threads, per = 4, 100
    barrier = threading.Barrier(n_threads)

    def emit(k):
        barrier.wait()
        for i in range(per):
            with tr.span(f"w{k}/s{i}", track=f"worker-{k}"):
                pass
            tr.counter(f"w{k}/c", i, track=f"worker-{k}")

    threads = [threading.Thread(target=emit, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == n_threads * per * 2
    assert tr.evicted == 0
    # every thread's track is present and complete
    for k in range(n_threads):
        spans = [e for e in evs if isinstance(e, SpanEvent)
                 and e.track == f"worker-{k}"]
        assert len(spans) == per


def test_concurrent_token_steps_are_thread_local():
    """Two threads with open token steps attribute phases to their OWN
    step, not each other's."""
    tr = Tracer()
    out = {}

    def run(name, comp):
        with tr.token_step(0, track=name):
            with tr.phase(comp):
                time.sleep(0.01)
        out[name] = [r for r in tr.stalls()]

    t1 = threading.Thread(target=run, args=("a", "disk_wait"))
    t2 = threading.Thread(target=run, args=("b", "compute"))
    t1.start(); t2.start(); t1.join(); t2.join()
    recs = tr.stalls()
    assert len(recs) == 2
    by_track = {}
    for ev in tr.events():
        if isinstance(ev, SpanEvent) and ev.cat == "decode":
            by_track[ev.track] = ev
    assert set(by_track) == {"a", "b"}
    # each record has only its own component nonzero
    comps = sorted((r.disk_wait_s > 0, r.compute_s > 0) for r in recs)
    assert comps == [(False, True), (True, False)]


# --------------------------------------------------------------------------- #
#  disabled path
# --------------------------------------------------------------------------- #

def test_disabled_tracer_is_a_noop():
    tr = Tracer(enabled=False)
    tr.instant("x")
    tr.counter("c", 1.0)
    tr.span_event("s", 0.0, 1.0)
    with tr.span("s2"):
        pass
    with tr.token_step(0) as step:
        assert step is None
        with tr.phase("compute"):
            pass
    assert tr.events() == []
    assert tr.stalls() == []
    assert tr.current_step() is None


def test_null_tracer_shared_and_disabled():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.token_step(0):
        with NULL_TRACER.phase("compute"):
            pass
    assert NULL_TRACER.events() == []


# --------------------------------------------------------------------------- #
#  stall attribution
# --------------------------------------------------------------------------- #

def test_components_partition_wall_time():
    tr = Tracer()
    with tr.token_step(0):
        with tr.phase("compute"):
            time.sleep(0.02)
        with tr.phase("disk_wait"):
            time.sleep(0.01)
    (rec,) = tr.stalls()
    assert rec.compute_s >= 0.015
    assert rec.disk_wait_s >= 0.005
    # components sum to wall by construction (sched_idle absorbs the rest)
    assert rec.accounted_s == pytest.approx(rec.wall_s, rel=1e-6)
    assert rec.sched_idle_s >= 0.0


def test_nested_phase_is_exclusive():
    """disk_wait inside compute charges disk_wait, not both."""
    tr = Tracer()
    with tr.token_step(0):
        with tr.phase("compute"):
            time.sleep(0.01)
            with tr.phase("disk_wait"):
                time.sleep(0.02)
            time.sleep(0.01)
    (rec,) = tr.stalls()
    assert rec.disk_wait_s >= 0.015
    assert rec.compute_s >= 0.015
    # exclusive: compute does NOT include the nested disk wait
    assert rec.compute_s < rec.wall_s - rec.disk_wait_s + 1e-6
    assert rec.accounted_s == pytest.approx(rec.wall_s, rel=1e-6)


def test_noncanonical_phase_folds_into_other():
    tr = Tracer()
    with tr.token_step(0):
        with tr.phase("weird_custom_phase"):
            time.sleep(0.005)
    (rec,) = tr.stalls()
    assert rec.other_s >= 0.004
    assert rec.accounted_s == pytest.approx(rec.wall_s, rel=1e-6)


def test_phase_outside_step_still_emits_span():
    tr = Tracer()
    with tr.phase("compute", track="solo"):
        pass
    assert tr.stalls() == []
    (ev,) = tr.events()
    assert isinstance(ev, SpanEvent) and ev.track == "solo"


def test_abandoned_phase_closed_on_error():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.token_step(0):
            with tr.phase("compute"):
                raise RuntimeError("boom")
    (rec,) = tr.stalls()
    assert rec.accounted_s == pytest.approx(rec.wall_s, rel=1e-6)


def test_summary_and_format():
    tr = Tracer()
    for i in range(3):
        with tr.token_step(i):
            with tr.phase("compute"):
                time.sleep(0.002)
    summ = tr.summary()
    assert summ["n"] == 3.0
    assert summ["compute"] > 0.0
    assert set(COMPONENTS) <= set(summ)
    line = format_summary(summ)
    assert "tpot" in line and "compute" in line
    assert tr.summary(last_n=1)["n"] == 1.0
    empty = stall_summary([])
    assert empty["n"] == 0.0 and empty["wall"] == 0.0


def test_min_dur_suppresses_span_not_attribution():
    tr = Tracer()
    with tr.token_step(0):
        with tr.phase("disk_wait", min_dur=10.0):
            time.sleep(0.002)
    (rec,) = tr.stalls()
    assert rec.disk_wait_s > 0.0            # attribution always lands
    spans = [e for e in tr.events() if isinstance(e, SpanEvent)
             and e.name == "disk_wait"]
    assert spans == []                      # span suppressed under min_dur


# --------------------------------------------------------------------------- #
#  Chrome trace export + validator
# --------------------------------------------------------------------------- #

def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.token_step(0, track="decode"):
        with tr.phase("compute"):
            pass
    tr.span_event("layer_read[0]", clock(), clock() + 1e-3,
                  cat="prefetch", track="prefetcher", nbytes=123)
    tr.counter("resident", 2, track="prefetcher")
    tr.instant("fault:error:layer_read", cat="fault", track="faults")
    path = str(tmp_path / "trace.json")
    tr.export_chrome_trace(path)

    with open(path) as f:
        doc = json.load(f)
    assert "traceEvents" in doc and doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    phs = {e["ph"] for e in evs}
    assert {"M", "X", "C", "i"} <= phs
    for e in evs:
        assert e["pid"] == 1
        if e["ph"] != "M":
            assert e["ts"] >= 0.0            # normalized to the run start
        if e["ph"] == "X":
            assert e["dur"] >= 0.0

    info = validate_chrome_trace(path, require_tracks=("prefetcher",
                                                       "decode"))
    assert "prefetcher" in info["tracks"]
    assert info["phases"]["X"] >= 2


def test_validator_rejects_bad_traces(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"nope": []}))
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace(str(p))
    p.write_text(json.dumps({"traceEvents": []}))
    with pytest.raises(ValueError, match="empty"):
        validate_chrome_trace(str(p))
    p.write_text(json.dumps(
        {"traceEvents": [{"ph": "Z", "name": "x"}]}))
    with pytest.raises(ValueError, match="unknown ph"):
        validate_chrome_trace(str(p))

    tr = Tracer()
    tr.instant("x", track="decode")
    good = str(tmp_path / "good.json")
    tr.export_chrome_trace(good)
    with pytest.raises(ValueError, match="required tracks missing"):
        validate_chrome_trace(good, require_tracks=("prefetcher",))


# --------------------------------------------------------------------------- #
#  clock unification (satellite: one timeline across subsystems)
# --------------------------------------------------------------------------- #

def test_fired_faults_on_telemetry_clock():
    from repro.runtime.faults import FaultInjector, FaultSpec

    inj = FaultInjector([FaultSpec(op="layer_read", times=1)])
    t0 = clock()
    with pytest.raises(OSError):
        inj.check("layer_read", key=0)
    t1 = clock()
    (f,) = inj.fired
    assert t0 <= f.t <= t1


def test_worker_health_on_telemetry_clock():
    from repro.runtime.iopolicy import WorkerHealth

    t0 = clock()
    h = WorkerHealth(name="w")
    h.progress()
    t1 = clock()
    assert t0 <= h.last_progress_t <= t1
    assert 0.0 <= h.seconds_since_progress() <= clock() - t0 + 1e-6


def test_fault_injector_emits_live_instants():
    from repro.runtime.faults import FaultInjector, FaultSpec

    tr = Tracer()
    inj = FaultInjector([FaultSpec(op="layer_read", times=1)],
                        tracer=tr)
    with pytest.raises(OSError):
        inj.check("layer_read", key=2)
    (ev,) = tr.events()
    assert isinstance(ev, InstantEvent)
    assert ev.track == "faults" and "layer_read" in ev.name


# --------------------------------------------------------------------------- #
#  ingestion adapters (legacy-record subsumption)
# --------------------------------------------------------------------------- #

def test_ingest_prefetch_and_health_and_faults():
    from repro.runtime.iopolicy import WorkerHealth
    from repro.runtime.streaming import PrefetchEvent

    tr = Tracer()
    n = tr.ingest_prefetch_events(
        [PrefetchEvent(0, 1.0, 2.0, 100), PrefetchEvent(1, 2.0, 3.0, 100)])
    assert n == 2
    spans = [e for e in tr.events() if isinstance(e, SpanEvent)]
    assert [s.name for s in spans] == ["layer_read[0]", "layer_read[1]"]
    assert all(s.track == "prefetcher" for s in spans)

    h = WorkerHealth(name="LayerPrefetcher")
    h.retries = 3
    tr.ingest_worker_health(h)
    counters = [e for e in tr.events() if isinstance(e, CounterEvent)]
    assert any(c.name == "retries" and c.value == 3.0 for c in counters)


def test_ingest_failover_event_splits():
    from repro.runtime.failover import FailoverEvent

    tr = Tracer()
    ev = FailoverEvent(
        token_index=5, failed_stage=1, generation=1, n_stages_before=4,
        n_stages_after=3, plan={}, halda=None, detect_s=0.1,
        resolve_s=0.2, rebuild_s=0.3, replay_s=0.4, tokens_lost=0,
        replayed_tokens=7)
    t_end = 100.0
    tr.ingest_failover_event(ev, t_end=t_end)
    spans = [e for e in tr.events() if isinstance(e, SpanEvent)]
    assert [s.name for s in spans] == [
        "failover/detect", "failover/resolve", "failover/rebuild",
        "failover/replay"]
    # contiguous, ending at t_end, durations matching the splits
    assert spans[-1].t_end == pytest.approx(t_end)
    assert spans[0].t_start == pytest.approx(t_end - ev.recovery_s)
    for s, d in zip(spans, (0.1, 0.2, 0.3, 0.4)):
        assert s.duration == pytest.approx(d)
    for a, b in zip(spans[:-1], spans[1:]):
        assert a.t_end == pytest.approx(b.t_start)


# --------------------------------------------------------------------------- #
#  uniform stats surfaces (satellite: stall counters through stats())
# --------------------------------------------------------------------------- #

def test_block_offloader_uniform_stats():
    from repro.runtime.iopolicy import FAST_TEST_POLICY
    from repro.runtime.kvcache import BlockOffloader
    from repro.runtime.streaming import PrefetchStats

    tr = Tracer()
    off = BlockOffloader(policy=FAST_TEST_POLICY, tracer=tr)
    try:
        page = {"k": np.ones((2, 4), np.float32)}
        off.offload(7, page)
        off.schedule(7)
        off.get(7, timeout=10.0)
        st = off.stats()
        assert isinstance(st, PrefetchStats)
        assert st.layers_served == 1
        assert st.total_bytes_read == 32
        assert st.stall_s >= 0.0
        assert st.retries == 0
    finally:
        off.close()
    tracks = tr.tracks()
    assert "kv-offloader" in tracks
    names = [e.name for e in tr.events() if isinstance(e, SpanEvent)]
    assert any(n.startswith("kv_d2h") for n in names)
    assert any(n.startswith("kv_h2d") for n in names)


def test_kv_stats_carries_fetch_stall_fields():
    from repro.runtime.kvcache import KVStats

    st = KVStats(n_pages=4, page_tokens=8, page_bytes=64,
                 active_pages_highwater=2, active_tokens_highwater=16,
                 prefix_hits=0, cow_copies=0, evictions=0,
                 offloaded_bytes=0, fetched_bytes=0, fetch_events=[])
    assert st.fetch_stall_s == 0.0 and st.fetch_retries == 0


def test_stall_record_component_accessor():
    r = StallRecord(index=0, t_start=0.0, t_end=1.0, compute_s=0.5,
                    disk_wait_s=0.25, sched_idle_s=0.25)
    assert r.component("compute") == 0.5
    assert r.wall_s == 1.0
    assert r.accounted_s == pytest.approx(1.0)


# --------------------------------------------------------------------------- #
#  drift report (core.latency.telemetry_crosscheck)
# --------------------------------------------------------------------------- #

def _local_dev(bps=1e9):
    from repro.core.profiles import GiB, OS, QUANTS, DeviceProfile

    return DeviceProfile(name="t", os=OS.LINUX, ram_avail=8 * GiB,
                         cpu_flops={q: 50e9 for q in QUANTS},
                         disk_seq_bps=bps, disk_rand_bps=bps)


def test_telemetry_crosscheck_disk_term():
    from repro.core.latency import telemetry_crosscheck
    from repro.runtime.streaming import PrefetchEvent

    layer_bytes, n_layers = 1 << 20, 4
    dev = _local_dev(1e9)
    # per-pass modeled: 4 MiB / 1 GB/s ≈ 4.19 ms; make measured match
    per_layer = layer_bytes / 1e9
    evs = [PrefetchEvent(i, i * 1.0, i * 1.0 + per_layer, layer_bytes)
           for i in range(n_layers)]
    stalls = [StallRecord(index=0, t_start=0.0, t_end=0.01)]
    rep = telemetry_crosscheck(dev, layer_bytes, n_layers,
                               stalls=stalls, prefetch_events=evs)
    disk = rep.term("disk")
    assert disk is not None
    assert disk.ratio == pytest.approx(1.0, rel=1e-6)
    assert disk.consistent and rep.consistent
    assert rep.drifted == ()
    assert "disk" in rep.as_dict()
    assert "DRIFT" not in rep.report()


def test_telemetry_crosscheck_detects_drift():
    from repro.core.latency import telemetry_crosscheck
    from repro.runtime.streaming import PrefetchEvent

    layer_bytes, n_layers = 1 << 20, 4
    # model says 1 GB/s but the "disk" delivered 100x slower reads
    dev = _local_dev(1e9)
    per_layer = layer_bytes / 1e9 * 100
    evs = [PrefetchEvent(i, 0.0, per_layer, layer_bytes)
           for i in range(n_layers)]
    stalls = [StallRecord(index=0, t_start=0.0, t_end=1.0)]
    rep = telemetry_crosscheck(dev, layer_bytes, n_layers,
                               stalls=stalls, prefetch_events=evs)
    assert rep.drifted == ("disk",)
    assert not rep.consistent
    assert "DRIFT" in rep.report()


def test_telemetry_crosscheck_comms_term():
    from repro.core.latency import telemetry_crosscheck

    dev = _local_dev()
    stalls = [StallRecord(index=0, t_start=0.0, t_end=0.01,
                          comms_s=2 * dev.t_comm)]
    rep = telemetry_crosscheck(dev, 1024, 4, stalls=stalls, n_hops=2)
    comms = rep.term("comms")
    assert comms is not None
    assert comms.ratio == pytest.approx(1.0, rel=1e-6)
    assert rep.term("disk") is None      # no prefetch timeline given


# --------------------------------------------------------------------------- #
#  engine integration: token steps + telemetry() accessor
# --------------------------------------------------------------------------- #

def test_prefetcher_stats_surface_stall_uniformly(tmp_path):
    """RingBankPrefetcher.stats() reports measured stall_s (was a
    hardcoded 0.0) and LayerPrefetcher attributes waits to disk_wait."""
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.runtime.paramstore import ParamStore, save_param_store
    from repro.runtime.streaming import StreamingParamSource

    cfg = dc.replace(get_config("qwen2.5-14b").reduced(), n_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sdir = str(tmp_path / "store")
    save_param_store(params, cfg, sdir)

    tr = Tracer()
    with StreamingParamSource(ParamStore(sdir), window=2,
                              tracer=tr) as src:
        with tr.token_step(0):
            for i in range(cfg.n_layers):
                src.layer(i)
        st = src.stats()
    assert st.stall_s >= 0.0
    (rec,) = tr.stalls()
    # waiting on layer 0 before the worker staged it counts as disk_wait
    assert rec.disk_wait_s >= 0.0
    assert any(e.track == "prefetcher" for e in tr.events()
               if isinstance(e, SpanEvent))
