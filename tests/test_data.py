"""Data pipeline: determinism, host sharding, tokenizer roundtrip."""
import numpy as np

from repro.data import ByteTokenizer, RequestGenerator, SyntheticCorpus, \
    batches


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello, μπορώ — ok?"
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == s


def test_corpus_deterministic():
    c = SyntheticCorpus(vocab=1000, seed=3)
    a = [next(iter_) for iter_ in [c.stream(seed=5)] for _ in range(64)]
    b = [next(iter_) for iter_ in [c.stream(seed=5)] for _ in range(64)]
    assert a == b
    assert all(3 <= t < 1000 for t in a)


def test_host_sharding_distinct():
    c = SyntheticCorpus(vocab=1000)
    s0 = c.stream(host_id=0, n_hosts=2)
    s1 = c.stream(host_id=1, n_hosts=2)
    a = [next(s0) for _ in range(64)]
    b = [next(s1) for _ in range(64)]
    assert a != b


def test_batches_shift():
    c = SyntheticCorpus(vocab=500)
    it = batches(c, batch=2, seq_len=16)
    rec = next(it)
    assert rec["tokens"].shape == (2, 16)
    assert rec["labels"].shape == (2, 16)
    # labels are next-token of tokens within the same chunk
    np.testing.assert_array_equal(rec["tokens"][:, 1:], rec["labels"][:, :-1])


def test_request_generator():
    gen = RequestGenerator(vocab=1000, rate_per_s=10.0, seed=1)
    reqs = gen.generate(20)
    assert len(reqs) == 20
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr)
    assert all(16 <= len(r.prompt) < 256 for r in reqs)
    assert all(1 <= r.max_new_tokens <= 64 for r in reqs)
