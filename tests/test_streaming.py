"""Weight-streaming subsystem: layer-sharded param store, async
prefetcher (window bound + release-behind-front), layer-wise forward
parity, continuous-batching integration, and the streamed SPMD ring."""
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, decode_step_layerwise, forward,
                          forward_layerwise, init_cache, init_params,
                          prefill, prefill_layerwise)
from repro.runtime.paramstore import (ParamStore, ResidentSource,
                                      load_resident, save_param_store)
from repro.runtime.streaming import (LayerPrefetcher, PrefetchEvent,
                                     StreamingParamSource,
                                     make_streaming_engine)

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen2.5-14b", n_layers=4, **over):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers, **over)


@pytest.fixture()
def store_dir():
    d = tempfile.mkdtemp(prefix="test_paramstore_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _trees_equal(a, b):
    flags = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree.leaves(flags))


# --------------------------------------------------------------------------- #
#  store round-trip
# --------------------------------------------------------------------------- #

def test_store_roundtrip_exact(store_dir):
    cfg = _cfg()
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    with ParamStore(store_dir) as store:
        assert store.n_layers == cfg.n_layers
        assert store.layer_nbytes > 0
        assert _trees_equal(params, load_resident(store))


def test_store_roundtrip_bf16(store_dir):
    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY, dtype=jnp.bfloat16)
    save_param_store(params, cfg, store_dir)
    with ParamStore(store_dir) as store:
        back = load_resident(store)
        assert _trees_equal(params, back)
        leaf = jax.tree.leaves(back["blocks"])[0]
        assert leaf.dtype.name == "bfloat16"


def test_store_roundtrip_ssm(store_dir):
    cfg = _cfg("mamba2-780m", n_layers=2)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    with ParamStore(store_dir) as store:
        assert _trees_equal(params, load_resident(store))


def test_store_rejects_unsharded_family(store_dir):
    cfg = get_config("recurrentgemma-9b").reduced()   # hybrid: groups/tail
    params = init_params(cfg, KEY)
    with pytest.raises(ValueError):
        save_param_store(params, cfg, store_dir)


def test_store_release_is_safe(store_dir):
    cfg = _cfg(n_layers=2)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    with ParamStore(store_dir) as store:
        store.release(0)              # unmapped layer: no-op
        p0 = store.layer(0)
        ref = jax.tree.map(lambda a: np.array(a, copy=True), p0)
        store.release(0)              # mapped: pages dropped, refault on read
        assert _trees_equal(ref, store.layer(0))


# --------------------------------------------------------------------------- #
#  prefetcher: window bound + release behind the front
# --------------------------------------------------------------------------- #

def test_prefetcher_residency_bounded_by_window(store_dir):
    cfg = _cfg(n_layers=6)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    store = ParamStore(store_dir)
    pf = LayerPrefetcher(store, window=2, device_put=False)
    try:
        for _pass in range(2):                  # cyclic decode pattern
            for i in range(cfg.n_layers):
                p = pf.get(i)
                assert jax.tree.leaves(p)[0] is not None
        st = pf.stats()
        assert st.peak_resident_bytes <= 2 * store.layer_nbytes
        assert st.layers_served == 2 * cfg.n_layers
        # window < L forces re-reads every pass (plus up to one cyclic
        # speculative read past the final front position)
        assert 2 * cfg.n_layers <= len(st.events) <= 2 * cfg.n_layers + 2
        assert st.releases > 0                  # pages dropped behind front
    finally:
        pf.close()
        store.close()


def test_prefetcher_random_access_correct(store_dir):
    cfg = _cfg(n_layers=4)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    store = ParamStore(store_dir)
    pf = LayerPrefetcher(store, window=2, device_put=False)
    try:
        for i in (3, 0, 2, 1, 3):
            got = pf.get(i)
            want = jax.tree.map(lambda a: a[i], params["blocks"])
            assert _trees_equal(got, want)
    finally:
        pf.close()
        store.close()


def test_prefetcher_staging_failure_raises_not_hangs(store_dir):
    """A worker-thread failure must surface in get() as an error, never a
    deadlock (the store directory vanishing mid-serve, an IO error...)."""
    cfg = _cfg(n_layers=4)
    save_param_store(init_params(cfg, KEY), cfg, store_dir)
    store = ParamStore(store_dir)
    store.layer_nbytes = 1 << 40          # poison: reads past EOF
    pf = LayerPrefetcher(store, window=2, device_put=False)
    try:
        with pytest.raises(RuntimeError, match="prefetch of layer"):
            pf.get(0)
    finally:
        pf.close()
        store.close()


# --------------------------------------------------------------------------- #
#  layer-wise forward parity (the acceptance criterion)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mamba2-780m"])
def test_layerwise_matches_scan_resident(arch):
    cfg = _cfg(arch, n_layers=3)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab)
    full = forward(params, cfg, toks)
    lw = forward_layerwise(ResidentSource(params), cfg, toks)
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(full - lw))) / scale < 1e-5


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "minicpm3-4b",
                                  "mamba2-780m"])
def test_streamed_decode_matches_resident(arch, store_dir):
    """Window < L: streamed prefill + decode must equal the resident path
    within test tolerance, with residency bounded by the window."""
    cfg = _cfg(arch, n_layers=4)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    B, S, steps = 2, 8, 3
    toks = jax.random.randint(KEY, (B, S + steps), 0, cfg.vocab)

    cache_r = init_cache(cfg, B, 32, dtype=jnp.float32)
    lg_r, cache_r = prefill(params, cfg, toks[:, :S], cache_r)

    src = StreamingParamSource(ParamStore(store_dir), window=2)
    try:
        cache_s = init_cache(cfg, B, 32, dtype=jnp.float32)
        lg_s, cache_s = prefill_layerwise(src, cfg, toks[:, :S], cache_s)
        scale = float(jnp.max(jnp.abs(lg_r)))
        assert float(jnp.max(jnp.abs(lg_r - lg_s))) / scale < 1e-5
        for t in range(S, S + steps):
            lg_r, cache_r = decode_step(params, cfg, cache_r,
                                        toks[:, t:t + 1])
            lg_s, cache_s = decode_step_layerwise(src, cfg, cache_s,
                                                  toks[:, t:t + 1])
            rel = float(jnp.max(jnp.abs(lg_r - lg_s))) / scale
            assert rel < 1e-5, (arch, t, rel)
        st = src.stats()
        assert st.peak_resident_bytes <= 2 * src.store.layer_nbytes
    finally:
        src.close()


def test_streamed_multi_token_verify(store_dir):
    """T>1 speculative verify through the streamed path == resident."""
    cfg = _cfg(n_layers=3)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    B, T = 2, 3
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    cache_r = init_cache(cfg, B, 32, dtype=jnp.float32)
    lg_r, _ = decode_step(params, cfg, cache_r, toks)
    with StreamingParamSource(ParamStore(store_dir), window=2) as src:
        cache_s = init_cache(cfg, B, 32, dtype=jnp.float32)
        lg_s, _ = decode_step_layerwise(src, cfg, cache_s, toks)
    scale = float(jnp.max(jnp.abs(lg_r)))
    assert float(jnp.max(jnp.abs(lg_r - lg_s))) / scale < 1e-5


# --------------------------------------------------------------------------- #
#  continuous batching over a streamed source
# --------------------------------------------------------------------------- #

def test_engine_streamed_matches_resident(store_dir):
    from repro.data import RequestGenerator

    cfg = _cfg(n_layers=2)
    params = init_params(cfg, KEY)
    save_param_store(params, cfg, store_dir)
    B, ctx = 2, 64
    reqs = RequestGenerator(cfg.vocab, prompt_len=(4, 9), max_new=5,
                            seed=3).generate(4)

    eng_r = make_streaming_engine(ResidentSource(params), cfg, B, ctx)
    cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
    fin_r, _ = eng_r.run(cache, list(reqs))

    src = StreamingParamSource(ParamStore(store_dir), window=1)
    try:
        eng_s = make_streaming_engine(src, cfg, B, ctx)
        cache = init_cache(cfg, B, ctx, dtype=jnp.float32)
        fin_s, _ = eng_s.run(cache, list(reqs))
        assert {f.uid: f.tokens for f in fin_s} == \
               {f.uid: f.tokens for f in fin_r}
        st = eng_s.streaming_stats()
        assert st is not None
        assert st.peak_resident_bytes <= src.store.layer_nbytes
        assert eng_r.streaming_stats() is None   # ResidentSource: no stats
    finally:
        src.close()


# --------------------------------------------------------------------------- #
#  streamed SPMD ring
# --------------------------------------------------------------------------- #

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 CPU devices (conftest sets flag)")


def _ring_stream_parity(arch, *, n_layers=8, k=2, B=8, Smax=32, steps=3,
                        tol=2e-4, n_tokens=1):
    from repro.runtime import serve
    from repro.runtime.streaming import StreamingRingDriver

    cfg = _cfg(arch, n_layers=n_layers)
    params = init_params(cfg, KEY)
    T = n_tokens
    toks = jax.random.randint(KEY, (B, steps * T), 0, cfg.vocab)

    cache_r = init_cache(cfg, B, Smax, dtype=jnp.float32)
    refs = []
    for t in range(steps):
        lg, cache_r = decode_step(params, cfg, cache_r,
                                  toks[:, t * T:(t + 1) * T])
        refs.append(lg)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = serve.RingPlan.make(cfg, 4, k=k)
    pr = serve.pad_vocab(dict(params), cfg, 2)
    head = {kk: v for kk, v in pr.items() if kk != "blocks"}
    cache_s = init_cache(cfg, B, Smax, dtype=jnp.float32)
    cache_s["layers"] = serve.pad_and_permute(cache_s["layers"], cfg, 4, k)

    d = tempfile.mkdtemp(prefix="test_ringstore_")
    try:
        save_param_store(params, cfg, d)
        drv = StreamingRingDriver(cfg, mesh, plan, ParamStore(d),
                                  head_params=head, cache_like=cache_s,
                                  n_tokens=T)
        ln = jnp.zeros((B,), jnp.int32)
        scale = float(jnp.max(jnp.abs(refs[-1])))
        for t in range(steps):
            logits, cache_s = drv.step(toks[:, t * T:(t + 1) * T], ln,
                                       cache_s)
            ln = ln + T
            rel = float(jnp.max(jnp.abs(
                logits[:, :, :cfg.vocab] - refs[t]))) / scale
            assert rel < tol, (arch, k, t, rel)
        assert drv.stats().total_bytes_read > 0
        drv.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


@needs_8_devices
@pytest.mark.parametrize("k", [1, 2])
def test_ring_stream_dense(k):
    _ring_stream_parity("qwen2.5-14b", k=k)


@needs_8_devices
def test_ring_stream_verify_multi_token():
    _ring_stream_parity("qwen2.5-14b", k=2, n_tokens=2)


@needs_8_devices
def test_ring_stream_layer_padding():
    _ring_stream_parity("minitron-8b", n_layers=6, k=1)


# --------------------------------------------------------------------------- #
#  latency-model cross-check plumbing
# --------------------------------------------------------------------------- #

def test_streaming_crosscheck():
    from repro.core.latency import streaming_crosscheck, streaming_disk_term
    from repro.core.profiles import DeviceProfile

    dev = DeviceProfile(name="x", disk_seq_bps=1e9, disk_rand_bps=1e9)
    layer_bytes = 1e8                            # 0.1 s/layer predicted
    assert streaming_disk_term(dev, layer_bytes) == pytest.approx(0.1)
    events = [PrefetchEvent(layer=i, t_start=0.0, t_end=0.11,
                            nbytes=int(layer_bytes)) for i in range(5)]
    chk = streaming_crosscheck(dev, layer_bytes, events)
    assert chk.ratio == pytest.approx(1.1)
    assert chk.consistent
    assert chk.measured_bps == pytest.approx(1e8 / 0.11)
    # an order-of-magnitude drift flags as inconsistent
    slow = [PrefetchEvent(layer=0, t_start=0.0, t_end=2.0,
                          nbytes=int(layer_bytes))]
    assert not streaming_crosscheck(dev, layer_bytes, slow).consistent
