"""Checkpoint/restore: roundtrip, integrity checks, manager rotation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as C

TREE = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16),
              "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    p = C.save(str(tmp_path / "x.npz"), TREE, step=7)
    out = C.restore(p, jax.tree.map(jnp.zeros_like, TREE))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert C.read_step(p) == 7


def test_shape_mismatch_rejected(tmp_path):
    p = C.save(str(tmp_path / "x.npz"), TREE)
    bad = dict(TREE)
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        C.restore(p, bad)


def test_leaf_count_mismatch_rejected(tmp_path):
    p = C.save(str(tmp_path / "x.npz"), TREE)
    with pytest.raises(ValueError):
        C.restore(p, {"a": TREE["a"]})


def test_no_tmp_residue(tmp_path):
    C.save(str(tmp_path / "x.npz"), TREE)
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_manager_rotation_and_resume(tmp_path):
    mgr = C.CheckpointManager(str(tmp_path), keep=2)
    assert mgr.latest() is None
    for s in (1, 2, 3, 4):
        tree = jax.tree.map(lambda x: x + s, TREE)
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]         # rotated
    step, out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, TREE))
    assert step == 4
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(TREE["a"]) + 4)
