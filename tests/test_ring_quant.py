"""Quantized ring weight bank: int4 storage must reproduce the
dequantized-reference logits exactly (the only approximation is the
quantization itself, bounded by test_quant)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.runtime import serve

needs_8 = pytest.mark.skipif(jax.device_count() < 8,
                             reason="needs 8 CPU devices")


@needs_8
@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mixtral-8x7b"])
def test_ring_q4_matches_dequantized_reference(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), n_layers=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, Smax = 8, 32
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    toks = jax.random.randint(key, (B, 4), 0, cfg.vocab)

    # reference: plain decode with dequantized weights (same numerics
    # policy as the ring window body — qmm-consumed leaves f32, rest bf16)
    pq, skipped = serve.quantize_ring_params(dict(params), cfg, tp=2)
    assert skipped == []
    pd = dict(pq)
    pd["blocks"] = serve.dequant_ring_reference(pq["blocks"])
    cache_ref = init_cache(cfg, B, Smax, dtype=jnp.float32)
    refs = []
    for t in range(3):
        lg, cache_ref = decode_step(pd, cfg, cache_ref, toks[:, t:t + 1])
        refs.append(lg)

    plan = serve.RingPlan.make(cfg, 4, k=2)
    pr = serve.pad_vocab(dict(params), cfg, 2)
    pr["blocks"] = serve.pad_and_permute(params["blocks"], cfg, 4, 2)
    pr, _ = serve.quantize_ring_params(pr, cfg, tp=2)
    cache = init_cache(cfg, B, Smax, dtype=jnp.float32)
    cache["layers"] = serve.pad_and_permute(cache["layers"], cfg, 4, 2)
    step = serve.build_ring_serve_step(cfg, mesh, plan)(pr, cache)
    ln = jnp.zeros((B,), jnp.int32)
    for t in range(3):
        logits, cache = step(toks[:, t:t + 1], ln, pr, cache)
        ln = ln + 1
        rel = float(jnp.max(jnp.abs(logits[:, :, :cfg.vocab] - refs[t]))
                    ) / float(jnp.max(jnp.abs(refs[t])))
        assert rel < 2e-4, (arch, t, rel)


def test_quantize_ring_params_selective():
    cfg = get_config("qwen2.5-14b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pq, skipped = serve.quantize_ring_params(params, cfg, tp=2)
    assert skipped == []
    from repro.quant.grouped import QuantizedTensor
    flat = jax.tree_util.tree_flatten_with_path(
        pq["blocks"], is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
    kinds = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        kinds[name.split("'")[-2]] = isinstance(leaf, QuantizedTensor)
    assert kinds["wq"] and kinds["w_down"]
    assert not kinds["attn_norm"] and not kinds["bq"]


def test_prep_ring_layer_keeps_q4_packed_for_qmm():
    """The ring microstep must hand q4 matmul weights to ``ll.qmm`` still
    packed (fused dequant-matmul streams the int4 bytes; a bf16
    materialization would forfeit the 0.27x ring traffic) while
    non-matmul leaves (norms, biases, routers) dequantize up front."""
    from repro.quant.grouped import QuantizedTensor

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pq, skipped = serve.quantize_ring_params(dict(params), cfg, tp=2)
    assert skipped == []

    # slice layer 0 out of the stacked banks (member-wise for packed)
    def slice0(leaf):
        if isinstance(leaf, QuantizedTensor):
            return QuantizedTensor(packed=leaf.packed[0],
                                   scale=leaf.scale[0], bits=leaf.bits,
                                   group=leaf.group, shape=leaf.shape[1:])
        return leaf[0]
    layer0 = jax.tree.map(
        slice0, pq["blocks"],
        is_leaf=lambda x: isinstance(x, QuantizedTensor))
    prepped = serve._prep_ring_layer(layer0)

    def walk(tree, out, prefix=""):
        for k, v in tree.items():
            if isinstance(v, dict):
                walk(v, out, k)
            else:
                out[k] = v
        return out
    leaves = walk(prepped, {})
    for k in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
        assert isinstance(leaves[k], QuantizedTensor), k
        assert leaves[k].packed.dtype == jnp.int8   # packed int4 pairs
    # norms/biases were never quantized and pass through as plain arrays
    assert not isinstance(leaves["attn_norm"], QuantizedTensor)
    assert not isinstance(leaves["bq"], QuantizedTensor)


def test_quantize_ring_params_reports_skipped():
    """A leaf no group size fits must be surfaced, not silently left bf16
    (a hidden compression cap would skew the streamed-bytes accounting)."""
    import numpy as np
    from repro.quant.grouped import QuantizedTensor

    cfg = get_config("qwen2.5-14b").reduced()
    blocks = {"wq": jnp.asarray(np.zeros((4, 64, 64), np.float32)),
              # K=50: not divisible by 64/32/16 -> unquantizable
              "wo": jnp.asarray(np.zeros((4, 50, 64), np.float32))}
    pq, skipped = serve.quantize_ring_params({"blocks": blocks}, cfg, tp=2)
    assert isinstance(pq["blocks"]["wq"], QuantizedTensor)
    assert not isinstance(pq["blocks"]["wo"], QuantizedTensor)
    assert skipped == ["wo (K=50)"]
