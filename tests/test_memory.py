"""Unified tiered memory manager + KV disk tier + session parking.

The contract under test: every resident byte (prefetch staging, device
KV pool, host offload copies, disk page files, parked sessions) leases
from one ``TierManager`` whose audited high-water never exceeds the
configured budget; parked sessions restore byte-identically — including
through random admit/decode/park/restore schedules and through injected
transient faults on the new disk-tier ops.
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.runtime.faults import FaultInjector, FaultSpec
from repro.runtime.iopolicy import (FAST_TEST_POLICY, BudgetExceeded,
                                    IOPolicy)
from repro.runtime.kvcache import (BlockOffloader, PageFileStore,
                                   dequantize_page, is_quantized_page,
                                   make_paged_engine, quantize_page)
from repro.runtime.memory import MemoryBudget, TierManager
from repro.runtime.paramstore import ParamStore, save_param_store
from repro.runtime.streaming import LayerPrefetcher

KEY = jax.random.PRNGKey(0)
PT = 8          # page_tokens everywhere below


def _small(arch="qwen2.5-14b", n_layers=2):
    return dataclasses.replace(get_config(arch).reduced(),
                               n_layers=n_layers)


class _Req:
    def __init__(self, uid, prompt, max_new, session=None):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new
        self.session = session


# --------------------------------------------------------------------- #
# TierManager
# --------------------------------------------------------------------- #

class TestTierManager:
    def test_lease_release_audit(self):
        tm = TierManager(MemoryBudget(device=100, host=50))
        tm.lease("device", 60, "a")
        tm.lease("device", 40, "b")
        assert tm.used("device") == 100 and tm.available("device") == 0
        tm.release("device", 60, "a")
        tm.lease("host", 10, "a")
        tm.audit()
        st = tm.stats()
        assert st["device"].peak == 100
        assert st["device"].leased_bytes == 100
        assert st["device"].released_bytes == 60
        assert tm.owner_bytes("b", "device") == 40

    def test_refusal_and_raise(self):
        tm = TierManager(MemoryBudget(device=100))
        assert tm.try_lease("device", 80, "a")
        assert not tm.try_lease("device", 30, "a")
        with pytest.raises(BudgetExceeded) as ei:
            tm.lease("device", 30, "a")
        assert ei.value.tier == "device"
        assert ei.value.requested == 30
        assert tm.stats()["device"].refusals == 2
        # an unbounded tier never refuses
        assert tm.try_lease("host", 1 << 40, "a")

    def test_over_release_rejected(self):
        tm = TierManager()
        tm.lease("host", 10, "a")
        with pytest.raises(ValueError):
            tm.release("host", 20, "a")
        with pytest.raises(ValueError):
            tm.release("host", 5, "b")       # not the owner

    def test_move_and_resize(self):
        tm = TierManager(MemoryBudget(device=100, host=100, disk=100))
        tm.lease("host", 80, "kv")
        tm.move("host", "disk", 30, "kv")
        assert tm.used("host") == 50 and tm.used("disk") == 30
        tm.resize("host", "kv", 50, 20)
        assert tm.used("host") == 20
        tm.audit()

    def test_wait_unblocks_on_release(self):
        tm = TierManager(MemoryBudget(device=100))
        tm.lease("device", 100, "a")
        got = []

        def waiter():
            tm.lease("device", 50, "b", wait=True, timeout=5.0)
            got.append(True)

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.05)
        assert not got
        tm.release("device", 100, "a")
        th.join(5.0)
        assert got and tm.owner_bytes("b", "device") == 50

    def test_wait_timeout_raises(self):
        tm = TierManager(MemoryBudget(device=10))
        tm.lease("device", 10, "a")
        with pytest.raises(BudgetExceeded):
            tm.lease("device", 5, "b", wait=True, timeout=0.05)


# --------------------------------------------------------------------- #
# int8 pages + disk page files
# --------------------------------------------------------------------- #

class TestPages:
    def test_quantize_roundtrip_bounded_error(self):
        rng = np.random.default_rng(0)
        tree = {"k": rng.standard_normal((2, PT, 8)).astype(np.float32),
                "v": rng.standard_normal((2, PT, 8)).astype(np.float32)}
        q = quantize_page(tree)
        assert is_quantized_page(q)
        assert sum(a.nbytes for a in q.values()) < \
            0.55 * sum(a.nbytes for a in tree.values())
        d = dequantize_page(q, np.float32)
        for name in tree:
            amax = np.max(np.abs(tree[name]), axis=-1, keepdims=True)
            assert np.all(np.abs(d[name] - tree[name]) <= amax / 127 + 1e-7)

    def test_pagefile_store_byte_identical(self, tmp_path):
        rng = np.random.default_rng(1)
        store = PageFileStore(str(tmp_path), policy=FAST_TEST_POLICY)
        trees = {}
        for i in range(4):
            t = {"k": rng.standard_normal((2, PT, 4)).astype(np.float32),
                 "v": rng.integers(-5, 5, (2, PT, 4)).astype(np.int8)}
            trees[("sess", "s", i)] = t
            store.put(("sess", "s", i), t)
        for key, t in trees.items():
            got = store.get(key)
            for name in t:
                assert got[name].dtype == t[name].dtype
                assert np.array_equal(got[name], t[name])
        assert len(store) == 4
        dropped = store.drop(("sess", "s", 0))
        assert dropped == sum(a.nbytes for a in trees[("sess", "s", 0)]
                              .values())
        assert not store.holds(("sess", "s", 0))
        store.close()
        assert len(store) == 0

    def test_pagefile_faults_retry_and_fatal(self, tmp_path):
        tree = {"k": np.ones((1, PT, 4), np.float32)}
        inj = FaultInjector([FaultSpec(op="kv_d2disk", times=2),
                             FaultSpec(op="kv_disk2h", times=2)])
        store = PageFileStore(str(tmp_path), policy=FAST_TEST_POLICY,
                              injector=inj)
        store.put(("p",), tree)              # retries absorb the faults
        got = store.get(("p",))
        assert np.array_equal(got["k"], tree["k"])
        assert len(inj.fired) == 4
        # a permanent fault exhausts retries and surfaces
        inj2 = FaultInjector([FaultSpec(op="kv_disk2h", times=-1)])
        store2 = PageFileStore(str(tmp_path), policy=FAST_TEST_POLICY,
                               injector=inj2)
        store2.put(("q",), tree)
        from repro.runtime.iopolicy import FatalIOError
        with pytest.raises(FatalIOError):
            store2.get(("q",))


# --------------------------------------------------------------------- #
# offloader under a host cap: refusal -> spill -> disk recall
# --------------------------------------------------------------------- #

class TestOffloaderBudget:
    def _tree(self, rng):
        return {"k": rng.standard_normal((1, PT, 4)).astype(np.float32)}

    def test_host_cap_without_disk_raises_retryable(self):
        rng = np.random.default_rng(2)
        nbytes = self._tree(rng)["k"].nbytes
        tm = TierManager(MemoryBudget(host=2 * nbytes))
        off = BlockOffloader(policy=FAST_TEST_POLICY, memory=tm)
        try:
            off.offload(0, self._tree(rng))
            off.offload(1, self._tree(rng))
            # the refusal is a classified *transient* condition — the
            # policy retries it (leases may free up), and only after the
            # retry budget does it surface, with the refusal as cause
            assert IOPolicy().classify(
                BudgetExceeded("x", tier="host")) == "transient"
            from repro.runtime.iopolicy import FatalIOError, find_cause
            with pytest.raises(FatalIOError) as ei:
                off.offload(2, self._tree(rng))
            assert find_cause(ei.value, BudgetExceeded) is not None
            assert tm.stats()["host"].refusals >= 1
        finally:
            off.close()
        assert tm.used("host") == 0

    def test_host_cap_spills_to_disk_and_recalls(self, tmp_path):
        rng = np.random.default_rng(3)
        trees = [self._tree(rng) for _ in range(4)]
        nbytes = trees[0]["k"].nbytes
        tm = TierManager(MemoryBudget(host=2 * nbytes))
        disk = PageFileStore(str(tmp_path), policy=FAST_TEST_POLICY)
        off = BlockOffloader(policy=FAST_TEST_POLICY, memory=tm,
                             disk=disk)
        try:
            for i, t in enumerate(trees):
                off.offload(i, t)            # 2 spill through to disk
            assert tm.used("host") <= 2 * nbytes
            assert tm.used("disk") == 2 * nbytes
            assert len(disk) == 2
            for i, t in enumerate(trees):    # oldest went to disk
                assert off.holds(i)
                off.schedule(i)
                got = off.get(i, timeout=5.0)
                assert np.array_equal(np.asarray(got["k"]), t["k"])
            st = off.stats()
            assert st.budget_refusals >= 2
        finally:
            off.close()
        assert tm.used("host") == 0 and tm.used("disk") == 0


# --------------------------------------------------------------------- #
# prefetcher: shared budget + advisory-release accounting
# --------------------------------------------------------------------- #

class TestPrefetcherBudget:
    def test_staging_leases_and_release_counter(self, tmp_path):
        cfg = _small(n_layers=4)
        params = init_params(cfg, KEY)
        save_param_store(params, cfg, str(tmp_path))
        store = ParamStore(str(tmp_path))
        tm = TierManager(MemoryBudget(host=2 * store.layer_nbytes))
        pf = LayerPrefetcher(store, window=2, device_put=False,
                             policy=FAST_TEST_POLICY, memory=tm)
        try:
            for i in range(cfg.n_layers):   # window slides behind get()
                pf.get(i)
                assert tm.used("host") <= 2 * store.layer_nbytes
            st = pf.stats()
            # ParamStore.release() is advisory (madvise) — the actual
            # bytes it returned must still be *accounted*: the stats
            # surface what was handed back so a tier audit can balance
            assert st.released_bytes > 0
            assert st.released_bytes % store.layer_nbytes == 0
        finally:
            pf.close()
            store.close()
        assert tm.used("host") == 0
        tm.audit()


# --------------------------------------------------------------------- #
# property-style: randomized admit/decode/park/restore schedules
# --------------------------------------------------------------------- #

def _run_schedule(seed, tmp_path, *, chaos=False):
    """Random multi-turn sessions through a budgeted engine; returns
    (per-session concatenated stream, uninterrupted reference stream,
    tier stats, kv stats)."""
    cfg = _small()
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(seed)
    B, ctx = 2, 64
    dense_pages = B * (-(-ctx // PT))

    sessions = {}
    for s in range(3):
        total = int(rng.integers(4, 9))
        # each turn >= 2: an admit with max_new=1 over-emits by one in
        # the seed engine (prefill token + one mandatory decode step),
        # which is orthogonal to the park/restore contract under test
        cut = int(rng.integers(2, total - 1))
        sessions[f"s{seed}-{s}"] = {
            "prompt": rng.integers(0, cfg.vocab, int(rng.integers(4, 18))),
            "turns": [cut, total - cut],
        }

    # uninterrupted references, one engine run each
    eng, kv = make_paged_engine(params, cfg, B, ctx,
                                n_pages=dense_pages + 2, page_tokens=PT)
    refs = {}
    for uid, (sid, spec) in enumerate(sessions.items()):
        fin, _ = eng.run(kv.init_cache(),
                         [_Req(uid, spec["prompt"], sum(spec["turns"]))])
        refs[sid] = [f for f in fin if f.uid == uid][0].tokens
    kv.close()

    injector = None
    if chaos:
        injector = FaultInjector(
            [FaultSpec(op="kv_d2disk", times=2),
             FaultSpec(op="kv_disk2h", times=2)], seed=seed)
    budget = MemoryBudget(device=12 * 4096 * 1024,  # generous device
                          host=None, disk=None)
    tm = TierManager()
    eng, kv = make_paged_engine(
        params, cfg, B, ctx, n_pages=dense_pages + 2, page_tokens=PT,
        memory=tm, disk_dir=str(tmp_path), park_idle_s=0.0,
        io_policy=FAST_TEST_POLICY, injector=injector)
    cache = kv.init_cache()
    got = {sid: [] for sid in sessions}
    # interleave turns in random global order, park between turns
    order = [(sid, t) for sid in sessions for t in range(2)]
    by_turn = {sid: 0 for sid in sessions}
    uid = 100
    while order:
        # a session's turn 1 only runs after its turn 0 finished
        ready = [(sid, t) for sid, t in order if t == by_turn[sid]]
        sid, t = ready[int(rng.integers(len(ready)))]
        order.remove((sid, t))
        by_turn[sid] += 1
        spec = sessions[sid]
        fin, _ = eng.run(cache, [_Req(uid, spec["prompt"],
                                      spec["turns"][t], sid)])
        got[sid].extend([f for f in fin if f.uid == uid][0].tokens)
        uid += 1
    st = kv.stats()
    tiers = tm.stats()
    tm.audit()
    kv.close()
    return got, refs, tiers, st, tm


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_park_restore_schedule(seed, tmp_path):
    got, refs, tiers, st, tm = _run_schedule(seed, tmp_path)
    for sid in refs:
        assert got[sid] == refs[sid], \
            f"session {sid}: split stream diverged from uninterrupted run"
    assert st.parked_sessions >= 3 and st.restored_sessions >= 3
    # idle parks demote to disk (park_idle_s=0) before their restore
    assert st.disk_bytes_written > 0 and st.disk_bytes_read > 0
    for tier, s in tiers.items():
        assert s.capacity is None or s.peak <= s.capacity
    # every byte returned: the manager drains to zero after close
    for tier in ("device", "host", "disk"):
        assert tm.used(tier) == 0, f"{tier} leaked {tm.used(tier)}B"


def test_random_schedule_chaos_disk_faults(tmp_path):
    got, refs, _, st, tm = _run_schedule(7, tmp_path, chaos=True)
    for sid in refs:
        assert got[sid] == refs[sid], \
            f"session {sid}: stream diverged through injected disk faults"
    assert st.disk_bytes_written > 0
    for tier in ("device", "host", "disk"):
        assert tm.used(tier) == 0


# --------------------------------------------------------------------- #
# budgeted pool sizing + high-water under a hard device cap
# --------------------------------------------------------------------- #

def test_device_budget_sizes_pool_and_bounds_highwater(tmp_path):
    cfg = _small()
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(11)
    B, ctx = 2, 64
    _, kv0 = make_paged_engine(params, cfg, B, ctx, n_pages=4,
                               page_tokens=PT)
    pb = kv0.page_bytes
    kv0.close()

    tm = TierManager(MemoryBudget(device=10 * pb, host=4 * pb))
    eng, kv = make_paged_engine(params, cfg, B, ctx, n_pages=None,
                                page_tokens=PT, memory=tm,
                                disk_dir=str(tmp_path))
    try:
        assert kv.pool.n_pages == 10          # sized from the budget
        reqs = [_Req(i, rng.integers(0, cfg.vocab,
                                     int(rng.integers(4, 14))), 4)
                for i in range(6)]
        eng.run(kv.init_cache(), reqs)
        tm.audit()
        stats = tm.stats()
        assert stats["device"].peak <= 10 * pb
        assert stats["host"].peak <= 4 * pb
    finally:
        kv.close()
    for tier in ("device", "host", "disk"):
        assert tm.used(tier) == 0
