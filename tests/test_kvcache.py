"""Paged KV cache allocator: BlockPool invariants, prefix cache, offload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from conftest import hypothesis_fallback as _hf
    given, settings, st = _hf.given, _hf.settings, _hf.st

from repro.runtime.kvcache import (SINK_PAGE, BlockOffloader, BlockPool,
                                   PoolExhausted, chain_key)


def test_pool_alloc_release_roundtrip():
    pool = BlockPool(8, 16)
    pids = [pool.alloc() for _ in range(7)]
    assert len(set(pids)) == 7 and SINK_PAGE not in pids
    assert pool.n_free == 0
    with pytest.raises(PoolExhausted):
        pool.alloc()
    for p in pids:
        pool.release(p)
    assert pool.n_free == 7 and pool.n_active == 0
    pool.check()


def test_pool_double_free_raises():
    pool = BlockPool(4, 8)
    p = pool.alloc()
    pool.release(p)
    with pytest.raises(ValueError, match="double free"):
        pool.release(p)
    pool.check()


def test_pool_refcount_share_and_release():
    pool = BlockPool(4, 8)
    p = pool.alloc()
    pool.register(123, p)
    pool.retain(p)                       # second owner (prefix share)
    assert pool.refcount(p) == 2
    pool.release(p)
    assert pool.refcount(p) == 1         # still active for the sharer
    pool.release(p)
    # hashed page at refcount 0 parks in the prefix cache, not free list
    assert pool.n_cached == 1 and pool.refcount(p) == 0
    assert pool.lookup(123) == p
    pool.retain(p)                       # cache hit revives it
    assert pool.refcount(p) == 1 and pool.n_cached == 0
    pool.check()


def test_pool_lru_eviction_order():
    pool = BlockPool(4, 8)               # 3 usable pages
    pages = []
    for h in (1, 2, 3):
        p = pool.alloc()
        pool.register(h, p)
        pages.append(p)
    for p in pages:
        pool.release(p)                  # all cached, LRU order 1,2,3
    evicted = []
    pool.alloc(evict_cb=lambda pid, h: evicted.append(h))
    pool.alloc(evict_cb=lambda pid, h: evicted.append(h))
    assert evicted == [1, 2]             # least-recently-cached first
    assert pool.lookup(3) is not None    # newest survivor
    pool.check()


def test_pool_unregister_blocks_future_lookup():
    pool = BlockPool(4, 8)
    p = pool.alloc()
    pool.register(77, p)
    pool.unregister(p)                   # page about to be written
    assert pool.lookup(77) is None
    pool.release(p)
    assert pool.n_free == 3              # unhashed -> free list, not cache
    pool.check()


def test_chain_key_partial_vs_full_distinct():
    toks = list(range(16))
    assert chain_key((), toks, 16) != chain_key((), toks, 8)
    assert chain_key((), toks, 16) != chain_key(((), 8, (1,)), toks, 16)
    # the key is the exact token chain — equality, not a digest, so a
    # prefix-cache hit can never be a hash collision
    assert chain_key((), toks, 16) == chain_key((), list(range(16)), 16)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_pool_random_churn_keeps_invariants(seed):
    """Random admit/share/finish churn: refcounts balance, no page is
    ever in two states, and releasing every owner empties the pool."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(int(rng.integers(3, 12)), 8)
    owners = []                          # list of (pid, hashed)
    next_h = 1
    for _ in range(200):
        op = rng.random()
        if op < 0.45:
            try:
                pid = pool.alloc(evict_cb=lambda *_: None)
            except PoolExhausted:
                continue
            if rng.random() < 0.5:
                pool.register(next_h, pid)
                next_h += 1
            owners.append(pid)
        elif op < 0.7 and owners:
            pid = owners[int(rng.integers(len(owners)))]
            pool.retain(pid)
            owners.append(pid)
        elif owners:
            pid = owners.pop(int(rng.integers(len(owners))))
            pool.release(pid)
        pool.check()
    for pid in owners:
        pool.release(pid)
    pool.check()
    assert pool.n_active == 0


def test_offloader_roundtrip_and_events():
    off = BlockOffloader()
    try:
        tree = {"k": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
                "v": np.ones((2, 3, 4), np.float32)}
        off.offload(99, tree)
        assert off.holds(99)
        assert off.offloaded_bytes == 2 * 24 * 4
        off.schedule(99)
        staged = off.get(99)
        np.testing.assert_array_equal(np.asarray(staged["k"]), tree["k"])
        np.testing.assert_array_equal(np.asarray(staged["v"]), tree["v"])
        assert not off.holds(99)                 # back on device
        assert len(off.events) == 1
        assert off.events[0].nbytes == 2 * 24 * 4
        assert off.fetched_bytes == off.events[0].nbytes
    finally:
        off.close()


def test_offloader_get_unscheduled_after_close_raises():
    off = BlockOffloader()
    off.close()
    with pytest.raises(RuntimeError):
        off.get(42)


def test_paged_cache_admit_finish_refcount_balance():
    """Manager-level churn: every admit's pages are returned on finish;
    hashed prompt pages park in the prefix cache, the rest free."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    kv = PagedKVCache(cfg, batch=2, ctx=64, n_pages=24, page_tokens=8)
    cache = kv.init_cache()
    rng = np.random.default_rng(0)
    L = cfg.n_layers
    hk, hd = cfg.kv_heads, cfg.head_dim
    fake = {"k": np.zeros((L, 1, 64, hk, hd), np.float32),
            "v": np.zeros((L, 1, 64, hk, hd), np.float32)}
    try:
        for round_ in range(6):
            prompts = [rng.integers(0, 100, int(rng.integers(3, 20)))
                       for _ in range(2)]
            for slot, p in enumerate(prompts):
                kv.plan_admit(cache, slot, [int(t) for t in p], 8)
                cache = kv.install(cache, slot, fake, len(p))
            cache = kv.begin_step(cache, [0, 1], 1)
            kv.advance(0), kv.advance(1)
            kv.pool.check()
            kv.release_slot(0), kv.release_slot(1)
            kv.pool.check()
            assert kv.pool.n_active == 0
    finally:
        kv.close()


def test_paged_cache_plan_admit_rejected_leaves_pool_clean():
    """An admit the pool cannot carry is rejected whole at reservation
    time — no page leaks, no garbage page left hash-addressable, and a
    fitting request still admits cleanly afterwards."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    kv = PagedKVCache(cfg, batch=1, ctx=64, n_pages=5, page_tokens=8,
                      offload=False)
    cache = kv.init_cache()
    try:
        with pytest.raises(PoolExhausted):
            kv.plan_admit(cache, 0, list(range(30)), 4)   # worst 6 > 4
        kv.pool.check()
        assert kv.pool.n_active == 0 and kv.pool.n_free == 4
        # the rejected prompt's pages must not be prefix-addressable
        h = chain_key((), list(range(8)), 8)
        assert kv.pool.lookup(h) is None
        # a fitting request still admits cleanly afterwards
        kv.plan_admit(cache, 0, list(range(10)), 4)
        kv.pool.check()
        assert kv.pool.n_active == 2
    finally:
        kv.close()


def test_paged_cache_abort_admit_releases_planned_pages():
    """A prefill that fails between plan_admit and install must not leak
    the slot's pages, reservation, or prefix-cache entries."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    kv = PagedKVCache(cfg, batch=1, ctx=64, n_pages=8, page_tokens=8,
                      offload=False)
    cache = kv.init_cache()
    try:
        prompt = list(range(14))
        kv.plan_admit(cache, 0, prompt, 8)
        assert kv.pool.n_active == 2
        kv.abort_admit(0)
        kv.pool.check()
        assert kv.pool.n_active == 0 and kv.pool.n_free == 7
        assert kv.pool.lookup(chain_key((), prompt[:8], 8)) is None
        # the slot is immediately reusable
        kv.plan_admit(cache, 0, prompt, 8)
        kv.pool.check()
        kv.abort_admit(0)
        kv.abort_admit(0)                      # idempotent no-op
        kv.pool.check()
    finally:
        kv.close()


def test_paged_engine_admit_failure_does_not_leak_pages():
    """Engine-level: an exception out of prefill_one rolls the planned
    pages back and leaves the engine serviceable."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import init_params
    from repro.runtime.kvcache import make_paged_engine

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng, kv = make_paged_engine(params, cfg, 2, 64, n_pages=16,
                                page_tokens=8, offload=False)
    try:
        boom = {"n": 0}
        real_prefill = eng.prefill_one

        def flaky_prefill(prompt):
            if boom["n"] == 0:
                boom["n"] += 1
                raise RuntimeError("transient prefill failure")
            return real_prefill(prompt)
        eng.prefill_one = flaky_prefill

        class Req:
            uid = 0
            prompt = np.arange(12)
            max_new_tokens = 4
        with pytest.raises(RuntimeError, match="transient"):
            eng.run(kv.init_cache(), [Req()])
        kv.pool.check()
        assert kv.pool.n_active == 0           # nothing leaked
        fin, _ = eng.run(kv.init_cache(), [Req()])   # retry succeeds
        assert len(fin) == 1 and len(fin[0].tokens) == 4
    finally:
        kv.close()


def test_paged_cache_admission_reservation_prevents_growth_death():
    """Worst-case reservation at admit: once admitted, growth across
    every decode step (up to prompt + max_new) always finds a page —
    ``begin_step`` can never die mid-decode."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    L, hk, hd = cfg.n_layers, cfg.kv_heads, cfg.head_dim
    fake = {"k": np.zeros((L, 1, 64, hk, hd), np.float32),
            "v": np.zeros((L, 1, 64, hk, hd), np.float32)}
    kv = PagedKVCache(cfg, batch=2, ctx=64, n_pages=8, page_tokens=8,
                      offload=False)
    cache = kv.init_cache()
    try:
        kv.plan_admit(cache, 0, list(range(14)), 10)      # worst 4
        cache = kv.install(cache, 0, fake, 14)
        # second admit of the same shape must be refused (4 + 4 > 7)...
        with pytest.raises(PoolExhausted, match="oversubscribe"):
            kv.plan_admit(cache, 1, list(range(50, 64)), 10)
        # ...so slot 0 can always grow to its full budget
        for step in range(10):
            cache = kv.begin_step(cache, [0], 1)
            kv.advance(0)
        kv.pool.check()
        kv.release_slot(0)
        # and the refused request fits once the slot frees
        kv.plan_admit(cache, 1, list(range(50, 64)), 10)
        kv.pool.check()
    finally:
        kv.close()


def test_paged_cache_trim_frees_growth_pages():
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    cfgL = cfg.n_layers
    kv = PagedKVCache(cfg, batch=1, ctx=64, n_pages=16, page_tokens=8)
    cache = kv.init_cache()
    hk, hd = cfg.kv_heads, cfg.head_dim
    fake = {"k": np.zeros((cfgL, 1, 64, hk, hd), np.float32),
            "v": np.zeros((cfgL, 1, 64, hk, hd), np.float32)}
    try:
        kv.plan_admit(cache, 0, list(range(6)), 20)
        cache = kv.install(cache, 0, fake, 6)
        n0 = kv.pool.n_active
        cache = kv.begin_step(cache, [0], 12)      # crosses 2 boundaries
        assert kv.pool.n_active == n0 + 2
        kv.trim_to(0, 7)                           # accept 1 of 12
        assert kv.pool.n_active == n0
        assert kv.length(0) == 7
        kv.pool.check()
    finally:
        kv.close()


def test_paged_cache_rejects_oversized_request():
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    cfg = dataclasses.replace(get_config("qwen2.5-14b").reduced(),
                              n_layers=2)
    kv = PagedKVCache(cfg, batch=1, ctx=32, n_pages=16, page_tokens=8)
    try:
        with pytest.raises(ValueError, match="paged slot addresses"):
            kv.plan_admit(kv.init_cache(), 0, list(range(20)), 20)
    finally:
        kv.close()


def test_paged_cache_rejects_recurrent_and_int8_mla():
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.kvcache import PagedKVCache

    ssm = get_config("mamba2-780m").reduced()
    with pytest.raises(ValueError, match="unsupported for family"):
        PagedKVCache(ssm, batch=1, ctx=32, n_pages=8)
    # dense int8 KV is supported: quantized k/v leaves plus per-(pos,
    # kv-head) scale leaves, read by the fused paged kernels
    q = get_config("qwen2.5-14b").reduced()
    q8 = dataclasses.replace(q, kv_dtype="int8")
    kv = PagedKVCache(q8, batch=1, ctx=32, n_pages=8)
    cache = kv.init_cache()
    assert set(cache["pages"]) == {"k", "v", "k_scale", "v_scale"}
    assert cache["pages"]["k"].dtype == jnp.int8
    assert cache["pages"]["k_scale"].dtype != jnp.int8
    # the MLA latent is already compressed — int8 on top stays rejected
    mla = get_config("minicpm3-4b").reduced()
    mla8 = dataclasses.replace(mla, kv_dtype="int8")
    with pytest.raises(NotImplementedError):
        PagedKVCache(mla8, batch=1, ctx=32, n_pages=8)
