"""Halda scheduler: optimality vs brute force, solver-backend agreement,
feasibility on random clusters, and the paper-cluster structure."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container lacks hypothesis
    from conftest import hypothesis_fallback as _hf
    given, settings, st = _hf.given, _hf.settings, _hf.st

from repro.core import baselines, halda
from repro.core.latency import classify_device, token_latency
from repro.core.profiles import (GiB, OS, Case, DeviceProfile, ModelProfile,
                                 QUANTS, divisors, paper_table2_cluster)


def small_model(n_layers=12, layer_gib=0.4, n_kv=256) -> ModelProfile:
    return ModelProfile(
        name="m", n_layers=n_layers, layer_bytes=layer_gib * GiB,
        input_bytes=0.2 * GiB, output_bytes=0.2 * GiB, embed_dim=4096,
        vocab=32000, kv_heads=8, head_dim=128, n_kv=n_kv,
        flops_layer={"q4k": 2 * layer_gib * GiB / 0.5625},
        flops_output={"q4k": 2 * 4096 * 32000})


def linux_dev(name, ram_gib, flops, disk_gbps, vram_gib=0.0):
    return DeviceProfile(
        name=name, os=OS.LINUX, ram_avail=ram_gib * GiB,
        vram_avail=vram_gib * GiB, has_cuda=vram_gib > 0,
        cpu_flops={q: flops for q in QUANTS},
        gpu_flops={q: flops * 8 for q in QUANTS} if vram_gib else {},
        cpu_membw=30e9, gpu_membw=300e9 if vram_gib else 0.0,
        disk_seq_bps=disk_gbps * 1e9, disk_rand_bps=disk_gbps * 0.6e9,
        t_comm=1e-3)


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6]
    assert divisors(12, exclude_self=False) == [1, 2, 3, 4, 6, 12]
    assert divisors(1) == [1]


def test_single_device_degenerates_to_llamacpp():
    devs = [linux_dev("a", 32, 200e9, 3.0, vram_gib=8)]
    mp = small_model()
    sol = halda.solve(devs, mp)
    assert sol.w == [mp.n_layers]
    assert sol.k == 1


def test_halda_beats_or_matches_baselines_on_paper_cluster():
    devs = paper_table2_cluster()
    mp = small_model(n_layers=80, layer_gib=0.48, n_kv=1024)
    sol = halda.solve(devs, mp)
    for name, strat in baselines.STRATEGIES.items():
        base = strat(devs, mp)
        assert sol.latency <= base.latency * 1.001, (name, sol, base)


def test_exact_improves_on_stuck_alg1():
    """The published calibration step cannot fire when all GPUs are full;
    the exact case enumeration must not be worse."""
    devs = paper_table2_cluster()
    mp = small_model(n_layers=80, layer_gib=0.48, n_kv=1024)
    alg1 = halda.solve(devs, mp, paper_faithful=True)
    exact = halda.solve(devs, mp)
    assert exact.latency <= alg1.latency + 1e-9


def test_exact_matches_brute_force_small():
    devs = [linux_dev("a", 3, 100e9, 2.0, vram_gib=2),
            linux_dev("b", 6, 300e9, 3.0)]
    mp = small_model(n_layers=8, layer_gib=0.5)
    bf = halda.brute_force(devs, mp)
    sol = halda.solve(devs, mp)
    assert sol.latency <= bf.latency * 1.05, (sol, bf)


def test_solver_backends_agree():
    devs = [linux_dev("a", 4, 100e9, 2.0, vram_gib=3),
            linux_dev("b", 8, 250e9, 3.0)]
    mp = small_model(n_layers=12, layer_gib=0.45)
    s1 = halda.solve(devs, mp)
    s2 = halda.solve(devs, mp, force_fallback=True)
    assert abs(s1.latency - s2.latency) <= 1e-6 * max(s1.latency, 1e-9)


def test_homogeneous_cluster_uniform_windows():
    devs = [linux_dev(f"d{i}", 16, 200e9, 2.5) for i in range(4)]
    mp = small_model(n_layers=12, layer_gib=0.1)
    sol = halda.solve(devs, mp)
    assert len(set(sol.w)) == 1, sol.w


def test_slow_disk_device_forced_m4():
    slow = linux_dev("slow", 2, 50e9, 0.1)     # below threshold
    assert classify_device(slow, 1, small_model(), 6, 0, 2) == Case.M4


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 4), st.integers(0, 10_000))
def test_halda_feasible_on_random_clusters(m, seed):
    rng = np.random.default_rng(seed)
    devs = []
    for i in range(m):
        vram = float(rng.choice([0, 0, 4, 8]))
        devs.append(linux_dev(f"d{i}", float(rng.uniform(2, 16)),
                              float(rng.uniform(50e9, 400e9)),
                              float(rng.uniform(0.5, 4.0)), vram_gib=vram))
    L = int(rng.choice([8, 12, 16, 24]))
    mp = small_model(n_layers=L, layer_gib=float(rng.uniform(0.1, 0.6)))
    sol = halda.solve(devs, mp)
    # feasibility invariants
    assert sum(sol.w) * sol.k == L or sum(sol.w) == L  # Assumption 1
    assert all(w >= 1 for w in sol.w)
    assert all(0 <= n <= w for n, w in zip(sol.n, sol.w))
    assert math.isfinite(sol.latency) and sol.latency > 0
    # objective consistency: reported latency == analytic latency
    lat = token_latency(devs, mp, sol.w, sol.n, sol.cases)
    assert abs(lat - sol.latency) < 1e-9 + 1e-6 * lat


def test_gpu_preferred_when_fast():
    devs = [linux_dev("gpu", 16, 100e9, 3.0, vram_gib=8),
            linux_dev("cpu", 16, 100e9, 3.0)]
    mp = small_model(n_layers=12, layer_gib=0.2)
    sol = halda.solve(devs, mp)
    assert sol.n[0] > 0          # layers land on the fast GPU
    assert sol.w[0] >= sol.w[1]  # and the GPU device carries more


def test_speculative_post_pass_reports_candidates():
    """solve(spec=...) prices every visited assignment with and without
    speculation; the chosen assignment is flagged and the speculative
    TPOT beats vanilla when verify amortizes (streamed-heavy cluster)."""
    devs = [linux_dev("a", 2.0, 80e9, 2.0), linux_dev("b", 2.0, 80e9, 2.0)]
    mp = small_model(n_layers=12)
    spec = halda.SpecPostPass(gamma=4, acceptance=0.8,
                              draft_token_latency=1e-3)
    sol = halda.solve(devs, mp, spec=spec)
    assert sol.candidates                       # search trace recorded
    report = sol.spec_report
    assert report and len(report) <= spec.top
    assert any(r["chosen"] for r in report)
    for r in report:
        assert r["tpot_vanilla"] > 0 and r["tpot_spec"] > 0
        assert r["tokens_per_cycle"] > 1.0
    # vanilla ordering: report sorted by tpot_vanilla
    vals = [r["tpot_vanilla"] for r in report]
    assert vals == sorted(vals)
    # memory-overloaded cluster: weight streaming dominates, so the
    # gamma+1-token verify amortizes and speculation wins on the winner
    chosen = next(r for r in report if r["chosen"])
    assert chosen["tpot_spec"] < chosen["tpot_vanilla"]


def test_solve_without_spec_has_no_report():
    devs = [linux_dev("a", 64.0, 80e9, 2.0), linux_dev("b", 64.0, 80e9, 2.0)]
    sol = halda.solve(devs, small_model())
    assert sol.spec_report is None
    assert sol.candidates


# ---------------------------------------------------------------- chunked TTFT

def test_chunked_prefill_ttft_reduces_to_ttft_when_unchunked():
    from repro.core.latency import chunked_prefill_ttft, ttft
    devs = [linux_dev("a", 64.0, 80e9, 2.0), linux_dev("b", 64.0, 80e9, 2.0)]
    mp = small_model()
    w, n = [6, 6], [0, 0]
    base = ttft(devs, mp, w, n, prompt_len=32)
    # chunk=0 disables chunking; chunk >= prompt means a single chunk
    assert chunked_prefill_ttft(devs, mp, w, n, 32, chunk=0) == base
    assert chunked_prefill_ttft(devs, mp, w, n, 32, chunk=32) == base
    assert chunked_prefill_ttft(devs, mp, w, n, 32, chunk=64) == base


def test_chunked_prefill_ttft_charges_per_extra_chunk():
    """TTFT_chunked = TTFT + (chunks-1) * (L/W * xi + t_step): each extra
    chunk re-pays the per-pass window overhead plus one interleaved
    decode step, so the penalty is linear in the chunk count."""
    from repro.core.latency import chunked_prefill_ttft, ttft
    devs = [linux_dev("a", 64.0, 80e9, 2.0), linux_dev("b", 64.0, 80e9, 2.0)]
    mp = small_model()
    w, n = [6, 6], [0, 0]
    base = ttft(devs, mp, w, n, prompt_len=64)
    step = 1e-3
    t8 = chunked_prefill_ttft(devs, mp, w, n, 64, chunk=8,
                              decode_step_s=step)    # 8 chunks
    t16 = chunked_prefill_ttft(devs, mp, w, n, 64, chunk=16,
                               decode_step_s=step)   # 4 chunks
    assert base < t16 < t8
    # per-chunk penalty is constant: (t8-base)/7 == (t16-base)/3
    assert (t8 - base) / 7 == pytest.approx((t16 - base) / 3, rel=1e-9)
    # with a measured step override, doubling the step adds exactly
    # (chunks-1) * step on top
    t8b = chunked_prefill_ttft(devs, mp, w, n, 64, chunk=8,
                               decode_step_s=2 * step)
    assert t8b - t8 == pytest.approx(7 * step, rel=1e-9)


def test_chunked_prefill_crosscheck_per_step_convention():
    """Both sides of the interleave drift term are per-step: the measured
    total stall divides by chunks-1 so the ratio compares one decode
    step against one observed interleave gap."""
    from repro.core.latency import chunked_prefill_crosscheck
    d = chunked_prefill_crosscheck(2e-3, measured_stall_s=6e-3, chunks=4)
    assert d.term == "interleave"
    assert d.measured_s == pytest.approx(2e-3)
    assert d.ratio == pytest.approx(1.0)
    assert d.consistent
    # >10x skew (e.g. eager chunk dispatch dwarfing the decode step)
    # falls outside the order-of-magnitude band
    bad = chunked_prefill_crosscheck(2e-3, measured_stall_s=0.3, chunks=4)
    assert not bad.consistent
    # single-chunk admit has no interleave; divisor clamps to 1
    one = chunked_prefill_crosscheck(2e-3, measured_stall_s=5e-4, chunks=1)
    assert one.measured_s == pytest.approx(5e-4)
