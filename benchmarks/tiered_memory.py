"""Tiered memory manager: one device/host/disk budget for weights + KV.

Measures, on the real subsystem (``runtime.memory`` + ``runtime.kvcache``
+ the engine park path) rather than the analytic model:

  * budget enforcement — a working set larger than the device budget
    runs OOM-free: the pool sizes itself to the budget, evictions spill
    through host to disk, and the tier manager's audited high-water
    never exceeds any configured cap (device AND host), with the token
    streams still byte-identical to the dense reference;
  * session parking — a conversation split across two engine runs
    (finish → park → demote to disk → restore) emits exactly the token
    stream of one uninterrupted run;
  * cost-model eviction — on a skewed-access trace (one hot prefix
    re-admitted between cold churn) pricing victims by expected recall
    seconds keeps the hot pages resident, so recall stalls and refetched
    bytes both drop vs plain LRU;
  * int8 KV pages — quantize-on-write at least halves offloaded page
    bytes while a decode step over round-tripped KV stays within logit
    tolerance of the unquantized cache.

Emits ``BENCH_tiered_memory.json`` via ``benchmarks/run.py`` or directly
(``python -m benchmarks.tiered_memory``), which gates on its own claims.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile

from .common import header, row

ARCH = "qwen2.5-14b"
N_LAYERS = 4
BATCH = 2
CTX = 64
PAGE_TOKENS = 8
MAX_NEW = 6


class _Req:
    def __init__(self, uid, prompt, max_new, session=None):
        self.uid = uid
        self.prompt = prompt
        self.max_new_tokens = max_new
        self.session = session


def _budgeted(params, cfg, *, n_pages_budget, host_pages, disk_dir,
              evict_policy="lru", offload_quant=False,
              park_idle_s=None, page_bytes=None):
    from repro.runtime.kvcache import make_paged_engine
    from repro.runtime.memory import MemoryBudget, TierManager

    budget = MemoryBudget(device=n_pages_budget * page_bytes,
                          host=host_pages * page_bytes)
    memory = TierManager(budget)
    eng, kv = make_paged_engine(
        params, cfg, BATCH, CTX, n_pages=None, page_tokens=PAGE_TOKENS,
        memory=memory, evict_policy=evict_policy,
        offload_quant=offload_quant, disk_dir=disk_dir,
        park_idle_s=park_idle_s)
    return eng, kv, memory


def main() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core.latency import kv_recall_costs
    from repro.models import init_cache, init_params
    from repro.runtime.engine import make_dense_engine
    from repro.runtime.kvcache import (dequantize_page, make_paged_engine,
                                       quantize_page)

    header("Tiered memory: budgeted weights+KV, parking, cost eviction")
    cfg = dataclasses.replace(get_config(ARCH).reduced(),
                              n_layers=N_LAYERS)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # probe the page size once (budgets below are denominated in pages)
    _, kv0 = make_paged_engine(params, cfg, BATCH, CTX, n_pages=4,
                               page_tokens=PAGE_TOKENS)
    page_bytes = kv0.page_bytes
    kv0.close()

    # workload: 8 requests through 2 slots; a shared 2-page prefix on the
    # even uids makes the working set overlap but exceed the device cap
    shared = rng.integers(0, cfg.vocab, 2 * PAGE_TOKENS)
    prompts = []
    for i in range(8):
        if i % 2 == 0:
            p = np.concatenate([shared, rng.integers(0, cfg.vocab, 3)])
        else:
            p = rng.integers(0, cfg.vocab, int(rng.integers(4, 14)))
        prompts.append(p)
    reqs = [_Req(i, p, MAX_NEW) for i, p in enumerate(prompts)]

    eng_d = make_dense_engine(params, cfg, BATCH, CTX)
    fin_d, _ = eng_d.run(init_cache(cfg, BATCH, CTX, dtype=jnp.float32),
                         reqs)
    dense_toks = {f.uid: f.tokens for f in fin_d}

    # ---- (a) working set > device budget, OOM-free, peaks <= caps ---- #
    dense_pages = BATCH * (-(-CTX // PAGE_TOKENS))      # dense envelope
    dev_pages = 10                                      # < working set
    ddir = tempfile.mkdtemp(prefix="bench_kvdisk_")
    try:
        eng, kv, mem = _budgeted(params, cfg, n_pages_budget=dev_pages,
                                 host_pages=4, disk_dir=ddir,
                                 page_bytes=page_bytes)
        fin, _ = eng.run(kv.init_cache(), reqs)
        toks = {f.uid: f.tokens for f in fin}
        st_a = kv.stats()
        mem.audit()
        stats = mem.stats()
        kv.close()
        budget_parity = toks == dense_toks and not eng.rejected
        caps_ok = all(
            s.capacity is None or s.peak <= s.capacity
            for s in stats.values())
        budget_ok = budget_parity and caps_ok \
            and kv.pool.n_pages <= dev_pages < dense_pages
        row("tiered/budget_pages", kv.pool.n_pages,
            f"device cap {dev_pages} pages vs dense envelope "
            f"{dense_pages} pages")
        row("tiered/device_peak", stats["device"].peak,
            f"cap={stats['device'].capacity} "
            f"host_peak={stats['host'].peak} "
            f"(cap={stats['host'].capacity}) "
            f"disk_peak={stats['disk'].peak}")
        row("tiered/claim/budget_enforced", budget_ok,
            f"parity={budget_parity} caps={caps_ok} "
            f"refusals={st_a.budget_refusals} "
            f"spilled={st_a.spilled_pages}")

        # ---- (b) park -> demote to disk -> restore, byte-identical -- #
        prompt = prompts[0]
        eng_f, kv_f = make_paged_engine(params, cfg, BATCH, CTX,
                                        n_pages=dense_pages + 2,
                                        page_tokens=PAGE_TOKENS)
        full, _ = eng_f.run(kv_f.init_cache(),
                            [_Req(90, prompt, 2 * MAX_NEW)])
        kv_f.close()
        eng_s, kv_s = make_paged_engine(params, cfg, BATCH, CTX,
                                        n_pages=dense_pages + 2,
                                        page_tokens=PAGE_TOKENS,
                                        disk_dir=ddir, park_idle_s=0.0)
        cache = kv_s.init_cache()
        f1, _ = eng_s.run(cache, [_Req(91, prompt, MAX_NEW, "conv")])
        parked_tier = kv_s._parked["conv"].tier if kv_s.is_parked("conv") \
            else "none"
        f2, _ = eng_s.run(cache, [_Req(92, prompt, MAX_NEW, "conv")])
        st_b = kv_s.stats()
        kv_s.close()
        got = f1[0].tokens + [f for f in f2 if f.uid == 92][0].tokens
        park_ok = got == full[0].tokens and parked_tier == "disk" \
            and st_b.restored_sessions == 1
        row("tiered/park_roundtrip", park_ok,
            f"{len(got)} tokens, parked tier={parked_tier}, disk "
            f"written={st_b.disk_bytes_written}B "
            f"read={st_b.disk_bytes_read}B")

        # ---- (c) cost-model vs LRU eviction on a skewed trace -------- #
        hot = rng.integers(0, cfg.vocab, 2 * PAGE_TOKENS)
        trace = []
        uid = 0
        for burst in range(6):
            trace.append(_Req(uid, hot, 2)); uid += 1      # hot prefix
            for _ in range(2):                              # cold churn
                trace.append(_Req(uid, rng.integers(
                    0, cfg.vocab, 2 * PAGE_TOKENS), 2))
                uid += 1
        runs = {}
        for policy in ("lru", "cost"):
            e, k = make_paged_engine(params, cfg, 1, CTX, n_pages=6,
                                     page_tokens=PAGE_TOKENS,
                                     evict_policy=policy)
            fin_t, _ = e.run(k.init_cache(),
                             [_Req(r.uid, r.prompt, r.max_new_tokens)
                              for r in trace])
            runs[policy] = (k.stats(), {f.uid: f.tokens for f in fin_t})
            k.close()
        st_lru, toks_lru = runs["lru"]
        st_cost, toks_cost = runs["cost"]
        cost_ok = (toks_lru == toks_cost
                   and st_cost.fetched_bytes < st_lru.fetched_bytes
                   and st_cost.fetch_stall_s <= st_lru.fetch_stall_s)
        row("tiered/evict_lru",
            f"{st_lru.fetch_stall_s * 1e3:.2f} ms stall",
            f"refetched={st_lru.fetched_bytes}B "
            f"evictions={st_lru.evictions}")
        row("tiered/evict_cost",
            f"{st_cost.fetch_stall_s * 1e3:.2f} ms stall",
            f"refetched={st_cost.fetched_bytes}B "
            f"evictions={st_cost.evictions}")
        row("tiered/claim/cost_beats_lru", cost_ok,
            "hot prefix stays resident under recall-cost pricing")

        # ---- (d) int8 offload tier: bytes halved, drift bounded ------ #
        churn = [_Req(0, hot, 4)] + \
            [_Req(i, rng.integers(0, cfg.vocab, 2 * PAGE_TOKENS), 4)
             for i in range(1, 5)] + [_Req(6, hot.copy(), 4)]
        offl = {}
        for quant in (False, True):
            e, k = make_paged_engine(params, cfg, 1, CTX, n_pages=6,
                                     page_tokens=PAGE_TOKENS,
                                     offload_quant=quant)
            e.run(k.init_cache(),
                  [_Req(r.uid, r.prompt, r.max_new_tokens)
                   for r in churn])
            offl[quant] = k.stats()
            k.close()
        ratio = offl[True].offloaded_bytes \
            / max(offl[False].offloaded_bytes, 1)
        # logit drift: one decode step over quantize-round-tripped KV
        from repro.models import prefill
        c1 = init_cache(cfg, 1, CTX, dtype=jnp.float32)
        lg, c1 = prefill(params, cfg, jnp.asarray(hot)[None, :], c1)
        tok = jnp.argmax(lg[0, -1])[None, None].astype(jnp.int32)
        from repro.models import decode_step
        lg_ref, _ = decode_step(params, cfg, c1, tok)
        c2 = dict(c1)
        c2["layers"] = jax.tree.map(
            lambda a: jnp.asarray(dequantize_page(
                quantize_page({"x": np.asarray(a)}), np.float32)["x"]),
            c1["layers"])
        lg_q, _ = decode_step(params, cfg, c2, tok)
        drift = float(jnp.max(jnp.abs(lg_q - lg_ref)))
        scale = float(jnp.max(jnp.abs(lg_ref)))
        quant_ok = ratio <= 0.55 and drift <= 0.05 * max(scale, 1.0) \
            and offl[True].offloaded_bytes > 0
        row("tiered/int8_offload_ratio", f"{ratio:.2f}x",
            f"{offl[True].offloaded_bytes}B vs "
            f"{offl[False].offloaded_bytes}B raw")
        row("tiered/int8_logit_drift", f"{drift:.4f}",
            f"tolerance {0.05 * max(scale, 1.0):.4f} "
            f"(5% of max |logit| {scale:.2f})")
        row("tiered/claim/int8_halves_bytes", quant_ok, "")
    finally:
        shutil.rmtree(ddir, ignore_errors=True)

    costs = kv_recall_costs(page_bytes)
    return {
        "arch": ARCH,
        "note": "smoke scale: the claims under test are budget-bounded "
                "residency with dense-parity tokens, byte-identical "
                "park/restore across engine runs, recall-cost eviction "
                "beating LRU on a skewed trace, and int8 halving "
                "offloaded bytes; absolute times are dispatch dominated",
        "n_layers": cfg.n_layers,
        "batch": BATCH,
        "ctx": CTX,
        "page_tokens": PAGE_TOKENS,
        "page_bytes": int(page_bytes),
        "budget": {
            "device_pages": dev_pages,
            "dense_envelope_pages": dense_pages,
            "device_peak": int(stats["device"].peak),
            "host_peak": int(stats["host"].peak),
            "disk_peak": int(stats["disk"].peak),
            "refusals": int(st_a.budget_refusals),
            "spilled_pages": int(st_a.spilled_pages),
        },
        "budget_enforced": bool(budget_ok),
        "park": {
            "tier_at_restore": parked_tier,
            "disk_bytes_written": int(st_b.disk_bytes_written),
            "disk_bytes_read": int(st_b.disk_bytes_read),
            "parked": int(st_b.parked_sessions),
            "restored": int(st_b.restored_sessions),
        },
        "park_roundtrip": bool(park_ok),
        "evict": {
            "lru_stall_s": st_lru.fetch_stall_s,
            "cost_stall_s": st_cost.fetch_stall_s,
            "lru_refetched_bytes": int(st_lru.fetched_bytes),
            "cost_refetched_bytes": int(st_cost.fetched_bytes),
        },
        "cost_beats_lru": bool(cost_ok),
        "int8": {
            "offload_ratio": ratio,
            "logit_drift": drift,
            "logit_scale": scale,
        },
        "int8_halves_bytes": bool(quant_ok),
        "recall_costs": {
            "host_s": costs.host_s,
            "disk_s": costs.disk_s,
        },
    }


if __name__ == "__main__":
    import sys

    from . import common

    payload = main()
    print(f"# wrote {common.write_bench_json('tiered_memory', payload)}")
    # the CLI run IS the gate (CI's tiered-memory step): a payload
    # failing its own claims must fail the process, not just record it
    gates = ["budget_enforced", "park_roundtrip", "cost_beats_lru",
             "int8_halves_bytes"]
    failed = [g for g in gates if not payload.get(g)]
    if failed:
        print(f"# GATE FAILED: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)
