"""Paper Table 6 (Appendix A.4): hot models beyond Llama."""
from __future__ import annotations

from repro.core import baselines, halda
from repro.core.profiles import paper_table2_cluster
from repro.core.simulator import simulate_ring

from .common import header, row
from .paper_models import TABLE6, profile


def main() -> None:
    header("Table 6: Qwen / QwQ / R1-distill latency (ms/token)")
    devs = paper_table2_cluster()
    for label, cid in TABLE6:
        mp = profile(cid)
        sol = halda.solve(devs, mp)
        res = simulate_ring(devs, mp, sol.w, sol.n)
        base = baselines.llama_cpp(devs, mp)
        active = [i for i, w in enumerate(base.w) if w > 0]
        bres = simulate_ring([devs[i] for i in active], mp,
                             [base.w[i] for i in active],
                             [base.n[i] for i in active])
        row(f"table6/{label}/prima", f"{res.token_latency * 1e3:.0f}",
            f"w={sol.w} n={sol.n} k={sol.k}")
        row(f"table6/{label}/llama.cpp", f"{bres.token_latency * 1e3:.0f}",
            "")


if __name__ == "__main__":
    main()
