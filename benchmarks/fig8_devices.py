"""Paper Figure 8 / A.5: token latency and layer assignment as the device
pool changes; automated best-subset selection."""
from __future__ import annotations

from repro.core import cluster, halda
from repro.core.profiles import paper_table2_cluster, paper_table2_extra
from repro.core.simulator import simulate_ring

from .common import header, row
from .paper_models import profile


def main() -> None:
    header("Figure 8 / A.5: device subsets on Llama 3-70B")
    mp = profile("llama3-70b")
    all_devs = paper_table2_cluster() + paper_table2_extra()
    names = [d.name for d in all_devs]
    subsets = {
        "D1-D4": [0, 1, 2, 3],
        "D1-D6": [0, 1, 2, 3, 4, 5],
        "D2,D3,D5": [1, 2, 4],
        "D2,D3": [1, 2],
        "D3": [2],
    }
    for label, idx in subsets.items():
        devs = [all_devs[i] for i in idx]
        sol = halda.solve(devs, mp)
        res = simulate_ring(devs, mp, sol.w, sol.n)
        row(f"fig8/{label}", f"{res.token_latency * 1e3:.0f}",
            f"w={sol.w} k={sol.k}")

    choice = cluster.select_cluster(all_devs, mp)
    row("fig8/auto-selected", f"{choice.solution.latency * 1e3:.0f}",
        "devices=" + "+".join(names[i] for i in choice.devices))


if __name__ == "__main__":
    main()
