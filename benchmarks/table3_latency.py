"""Paper Table 3: token latency + TTFT for llama.cpp / exo / dllama /
prima.cpp (full, w/o halda, w/o prefetch) across the Llama grid, on the
Table-2 home cluster, via the event-driven simulator.

The reproduction target is the paper's *orderings and ablation effects*
(absolute ms depend on device constants we can only approximate):
  C1: prima < llama.cpp for >= 30B;
  C2: exo/dllama OOM (or are slower) at 70B-scale;
  C3: w/o halda >> full prima at >= 45B;
  C4: w/o prefetch is 0-25% slower than full prima on large models.
"""
from __future__ import annotations

from repro.core import baselines, halda
from repro.core.profiles import paper_table2_cluster
from repro.core.simulator import simulate_ring, simulate_tp

from .common import header, row
from .paper_models import TABLE3, profile


def run_system(devs, mp, system: str):
    """Returns (latency_s, ttft_s, oom)."""
    if system == "llama.cpp":
        sol = baselines.llama_cpp(devs, mp)
        active = [i for i, w in enumerate(sol.w) if w > 0]
        sub = [devs[i] for i in active]
        res = simulate_ring(sub, mp, [sol.w[i] for i in active],
                            [sol.n[i] for i in active])
        return res.token_latency, res.ttft, res.oom
    if system == "exo":
        # exo decodes fp16/fp32 on the Linux/tinygrad path (paper Fig. 9b:
        # 4x RAM / 8x VRAM vs the Q4K footprint) -> scale resident bytes.
        import dataclasses
        mp16 = dataclasses.replace(
            mp, layer_bytes=mp.layer_bytes * 16 / 4.5,
            input_bytes=mp.input_bytes * 16 / 4.5,
            output_bytes=mp.output_bytes * 16 / 4.5)
        sol = baselines.exo(devs, mp16)
        res = simulate_ring(devs, mp16, sol.w, sol.n, resident_weights=True)
        return res.token_latency, res.ttft, res.oom
    if system == "dllama":
        res = simulate_tp(devs, mp)
        return res.token_latency, res.ttft, res.oom
    if system == "prima(w/o halda)":
        sol = baselines.prima_no_halda(devs, mp)
        res = simulate_ring(devs, mp, sol.w, sol.n)
        return res.token_latency, res.ttft, res.oom
    if system == "prima(w/o prefetch)":
        sol = halda.solve(devs, mp)
        res = simulate_ring(devs, mp, sol.w, sol.n, prefetch=False)
        return res.token_latency, res.ttft, res.oom
    if system == "prima":
        sol = halda.solve(devs, mp)
        res = simulate_ring(devs, mp, sol.w, sol.n)
        return res.token_latency, res.ttft, res.oom
    raise KeyError(system)


SYSTEMS = ["llama.cpp", "exo", "dllama", "prima(w/o halda)",
           "prima(w/o prefetch)", "prima"]


def main() -> dict:
    header("Table 3: token latency / TTFT (ms), Table-2 cluster")
    devs = paper_table2_cluster()
    results = {}
    for label, cid in TABLE3:
        mp = profile(cid)
        for system in SYSTEMS:
            lat, ttft, oom = run_system(devs, mp, system)
            results[(label, system)] = (lat, ttft, oom)
            val = "OOM" if oom and system in ("exo", "dllama") \
                else f"{lat * 1e3:.0f}"
            row(f"table3/{label}/{system}", val,
                f"ttft_ms={ttft * 1e3:.0f}")

    # claim checks
    header("Table 3 claim checks")
    for label in ("Llama 1-30B", "Llama 3-45B", "Llama 3-60B",
                  "Llama 1-65B", "Llama 3-70B"):
        p = results[(label, "prima")][0]
        l = results[(label, "llama.cpp")][0]
        row(f"claim/C1/{label}/prima<llama.cpp", p < l,
            f"{p*1e3:.0f}ms vs {l*1e3:.0f}ms")
    for label in ("Llama 3-70B",):
        e_oom = results[(label, "exo")][2]
        d_oom = results[(label, "dllama")][2]
        row(f"claim/C2/{label}/exo,dllama-OOM", e_oom and d_oom, "")
    for label in ("Llama 3-45B", "Llama 3-60B", "Llama 1-65B",
                  "Llama 3-70B"):
        nh = results[(label, "prima(w/o halda)")][0]
        p = results[(label, "prima")][0]
        row(f"claim/C3/{label}/no-halda-worse", nh > p * 1.2,
            f"ratio={nh / p:.2f}")
    for label in ("Llama 3-60B", "Llama 1-65B", "Llama 3-70B"):
        np_ = results[(label, "prima(w/o prefetch)")][0]
        p = results[(label, "prima")][0]
        row(f"claim/C4/{label}/prefetch-helps", np_ >= p,
            f"gain={100 * (np_ - p) / max(np_, 1e-9):.1f}%")

    return {f"{label}/{system}": {"ms_per_token": lat * 1e3,
                                  "tps": (0.0 if oom else 1.0 / lat),
                                  "ttft_ms": t * 1e3, "oom": oom}
            for (label, system), (lat, t, oom) in results.items()}


if __name__ == "__main__":
    main()
